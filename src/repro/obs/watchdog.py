"""Online consensus-invariant watchdog over the protocol journal.

Subscribes to `ProtocolJournal` and checks, on every entry, the
per-range invariants the replication protocol promises (paper §4-§8):

``single_leader_per_epoch``
    At most one node ever takes over a (range, epoch) pair — epochs are
    minted by an atomic counter, so two takeovers with the same epoch
    mean the fencing broke.
``lease_disjoint``
    Leader leases for a range never overlap across nodes: a node may
    not acquire a still-live lease while another node's skew-adjusted
    expiry is in the future (split-brain precursor).
``quorum_intersection``
    Elections are decided by a strict majority of the cohort, and the
    winner carries the maximal last-LSN among the candidates — the
    Paxos condition that makes any two quorums share a voter.
``takeover_completeness``
    A takeover's re-proposal queue covers every durable, never-truncated
    record of the unresolved window (cmt, lst]; a gap (``missing`` > 0)
    is the PR 6 "takeover wedge" — acked records the new regime will
    never re-commit.
``acked_durable``
    A follower's ack watermark never runs ahead of its own
    durable/committed evidence (WAL forces, completed catch-up, applied
    commit index) — an early ack is a durability lie the commit rule
    then counts.
``acked_committed_majority``
    The leader only advances the commit index to an LSN backed by
    durable/committed evidence on a strict majority of the cohort.
``commit_monotonic``
    A replica's applied commit index never regresses while the node
    stays up (crash recovery may lawfully rewind to the durable
    marker).
``log_matching``
    Same (range, lsn) ⇒ same record content on every replica that ever
    appends it (digest comparison; LSNs embed the epoch so a new
    regime can never lawfully reuse one).
``txn_decision_stable``
    A 2PC transaction's outcome never flips: every decision minted,
    applied, or resolved for a txid agrees with the first.
``gc_floor_safe``
    The WAL GC floor never passes — and is never released under — an
    unresolved committed TXN_PREPARE still awaiting its outcome.
``catchup_progress``
    A replica stuck in CATCHUP that keeps hearing leader lease beats
    (so the leader is alive and reachable) must be re-requesting data —
    beats without retries for `catchup_stall_s` is the PR 6 catch-up
    starvation shape.

Violations are structured dicts carrying the invariant name, the
entry that tripped it, a human-readable detail, and the implicated
journal window.  The watchdog is pure measurement: it never touches
the simulator clock or RNG, so enabling it keeps runs bit-identical.
"""

from __future__ import annotations

from typing import Optional

from .journal import ProtocolJournal


class InvariantWatchdog:
    MAX_VIOLATIONS = 1000
    # a session-fenced (flapped/crashed) leader may lawfully re-extend its
    # stale-epoch lease for a moment after the successor's takeover — the
    # renewal raced the followers' epoch switch; epoch fencing plus
    # depose-on-contact make the window unservable, so such claims are
    # exempt from lease_disjoint while the fence is fresh
    LEASE_HANDOFF_S = 5.0

    def __init__(self, journal: Optional[ProtocolJournal] = None,
                 enabled: bool = True,
                 catchup_stall_s: float = 2.0):
        self.enabled = enabled
        self.catchup_stall_s = catchup_stall_s
        self.violations: list[dict] = []
        self.entries_checked = 0
        # per-range protocol state rebuilt from the journal stream
        self._leaders: dict[tuple[int, int], dict] = {}   # (rid,epoch)->entry
        self._leases: dict[tuple[int, int], dict] = {}    # (rid,node)->entry
        self._commit_idx: dict[tuple[int, int], dict] = {}  # (node,rid)->entry
        self._digests: dict[tuple[int, int], dict] = {}   # (rid,lsn)->entry
        # (rid,node) -> highest durable/committed evidence: WAL flushes,
        # completed catch-up, applied commit index, takeover last-LSN.
        # Deliberately NOT fed by acks — acks are the claim under test.
        self._evidence: dict[tuple[int, int], int] = {}
        self._cohort_n: dict[int, int] = {}               # rid -> cohort size
        self._decisions: dict[str, dict] = {}             # txid -> entry
        # (node,rid) -> {txid: prepare lsn} committed-but-unresolved 2PC
        # prepares; uncommitted ones are dropped without a resolve entry
        # and must not pin anything, so only `txn_prepared` feeds this.
        self._prepares: dict[tuple[int, int], dict] = {}
        self._catchup: dict[tuple[int, int], dict] = {}   # (node,rid)->state
        self._regime: dict[int, int] = {}    # rid -> highest takeover epoch
        self._fence: dict[int, float] = {}   # node -> last flap/crash time
        self._fired: set = set()    # dedup key per violation site
        if journal is not None and self.enabled:
            journal.listeners.append(self.observe)

    # -- reporting ----------------------------------------------------------
    def _violate(self, invariant: str, entry: dict, detail: str,
                 window: Optional[list] = None, dedup=None) -> None:
        key = (invariant, dedup) if dedup is not None \
            else (invariant, len(self.violations))
        if key in self._fired:
            return
        self._fired.add(key)
        if len(self.violations) >= self.MAX_VIOLATIONS:
            return
        self.violations.append({
            "t": entry["t"],
            "invariant": invariant,
            "rid": entry.get("rid"),
            "node": entry.get("node"),
            "kind": entry["kind"],
            "detail": detail,
            "window": [dict(e) for e in (window or [entry])],
        })

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> dict:
        by_inv: dict[str, int] = {}
        for v in self.violations:
            by_inv[v["invariant"]] = by_inv.get(v["invariant"], 0) + 1
        return {"ok": self.ok,
                "entries_checked": self.entries_checked,
                "n_violations": len(self.violations),
                "by_invariant": dict(sorted(by_inv.items())),
                "violations": self.violations[:20]}

    @classmethod
    def replay(cls, entries, **kw) -> "InvariantWatchdog":
        """Offline mode: run the same checks over a journal dump
        (`ProtocolJournal.load_jsonl` output or live entries)."""
        wd = cls(None, enabled=True, **kw)
        for e in entries:
            wd.observe(e)
        return wd

    # -- the state machine --------------------------------------------------
    def observe(self, e: dict) -> None:
        if not self.enabled:
            return
        self.entries_checked += 1
        handler = getattr(self, "_on_" + e["kind"], None)
        if handler is not None:
            handler(e)

    def _bump_evidence(self, rid: int, node: int, lsn: int) -> None:
        key = (rid, node)
        if lsn > self._evidence.get(key, 0):
            self._evidence[key] = lsn

    # leadership / elections
    def _on_takeover(self, e: dict) -> None:
        rid, epoch = e["rid"], e["epoch"]
        if "n_cohort" in e:
            self._cohort_n[rid] = e["n_cohort"]
        prev = self._leaders.get((rid, epoch))
        if prev is not None and prev["node"] != e["node"]:
            self._violate(
                "single_leader_per_epoch", e,
                f"range {rid} epoch {epoch}: node {e['node']} took over "
                f"but node {prev['node']} already leads this epoch",
                window=[prev, e], dedup=(rid, epoch))
        else:
            self._leaders[(rid, epoch)] = e
        # the re-proposal queue must cover every durable record of the
        # unresolved window (cmt, lst] — a gap is the takeover wedge
        if e.get("missing", 0) > 0:
            self._violate(
                "takeover_completeness", e,
                f"range {rid} epoch {epoch}: takeover re-proposal queue "
                f"is missing {e['missing']} durable record(s) of the "
                f"unresolved window (cmt {e.get('cmt')}, lst "
                f"{e.get('lst')}] — acked records would be lost or "
                f"wedge the range (takeover wedge)",
                dedup=(rid, epoch, "takeover_gap"))
        # forced_upto jumps to lst at takeover: the local log is durable
        if e.get("lst"):
            self._bump_evidence(rid, e["node"], e["lst"])
        if epoch > self._regime.get(rid, 0):
            self._regime[rid] = epoch
            # a superseded regime whose holder's session provably expired
            # (the lawful election trigger) may still hold a live window;
            # it is fenced, so it no longer counts as a conflicting claim
            for (r, other), prev in list(self._leases.items()):
                if r == rid and prev.get("epoch", epoch) < epoch \
                        and self._fenced(other, e["t"]):
                    del self._leases[(r, other)]

    def _on_elect_decide(self, e: dict) -> None:
        rid = e["rid"]
        n = e.get("n_cohort")
        cands = e.get("candidates") or []
        if n:
            self._cohort_n[rid] = n
            if 2 * len(cands) <= n:
                self._violate(
                    "quorum_intersection", e,
                    f"range {rid}: election decided by {len(cands)} of "
                    f"{n} cohort members — not a strict majority, two "
                    f"such quorums need not intersect",
                    dedup=(rid, e.get("round")))
        w_lst, m_lst = e.get("winner_lst"), e.get("max_lst")
        if w_lst is not None and m_lst is not None and w_lst < m_lst:
            self._violate(
                "quorum_intersection", e,
                f"range {rid}: election winner {e.get('winner')} has "
                f"lst {w_lst} < candidate max {m_lst}; acked records "
                f"on the longer log would be lost",
                dedup=(rid, e.get("round"), "lst"))

    # leases
    def _fenced(self, node: int, t: float) -> bool:
        fence = self._fence.get(node)
        return fence is not None and 0.0 <= t - fence <= self.LEASE_HANDOFF_S

    def _on_lease_acquire(self, e: dict) -> None:
        rid, node = e["rid"], e["node"]
        if e.get("epoch", 0) < self._regime.get(rid, 0) \
                and self._fenced(node, e["t"]):
            # stale-regime renewal raced the epoch switch after this
            # node's session fence — lawful handoff noise, not a claim
            return
        if e["until"] <= e["t"] + 1e-9:
            # a delayed ack can grant an already-expired window (e.g. a
            # slow link stretching the round past duration - skew); the
            # holder never serves on it, so it is not a live claim
            return
        for (r, other), prev in list(self._leases.items()):
            if r != rid or other == node:
                continue
            if prev["until"] > e["t"] + 1e-9:
                self._violate(
                    "lease_disjoint", e,
                    f"range {rid}: node {node} acquired a lease at "
                    f"t={e['t']:.6f} while node {other}'s lease runs "
                    f"until {prev['until']:.6f} — overlapping leases "
                    f"allow two serving leaders (split-brain precursor)",
                    window=[prev, e],
                    dedup=(rid, node, other, round(prev["until"], 6)))
        cur = self._leases.get((rid, node))
        if cur is None or e["until"] >= cur["until"]:
            self._leases[(rid, node)] = e

    def _on_lease_lapse(self, e: dict) -> None:
        self._leases.pop((e["rid"], e["node"]), None)

    def _on_abdicate(self, e: dict) -> None:
        self._leases.pop((e["rid"], e["node"]), None)

    def _on_lease_heard(self, e: dict) -> None:
        if e.get("role") != "CATCHUP":
            return
        st = self._catchup.get((e["node"], e["rid"]))
        if st is None:
            return
        st["beats"] += 1
        ref = max(st["t_enter"], st["t_retry"])
        if e["t"] - ref > self.catchup_stall_s and st["beats"] >= 3:
            self._violate(
                "catchup_progress", e,
                f"range {e['rid']}: node {e['node']} has sat in CATCHUP "
                f"for {e['t'] - st['t_enter']:.2f}s hearing "
                f"{st['beats']} leader lease beats without re-requesting "
                f"data — catch-up retries are being starved",
                window=[st["enter"], e],
                dedup=(e["rid"], e["node"], round(st["t_enter"], 6)))

    # catch-up lifecycle
    def _on_catchup_enter(self, e: dict) -> None:
        self._catchup[(e["node"], e["rid"])] = {
            "t_enter": e["t"], "t_retry": e["t"], "beats": 0, "enter": e}

    def _on_catchup_retry(self, e: dict) -> None:
        st = self._catchup.get((e["node"], e["rid"]))
        if st is not None:
            st["t_retry"] = e["t"]

    def _on_catchup_exit(self, e: dict) -> None:
        self._catchup.pop((e["node"], e["rid"]), None)
        if e.get("lsn"):
            self._bump_evidence(e["rid"], e["node"], e["lsn"])

    # log / commit path
    def _on_append(self, e: dict) -> None:
        if "digest" not in e or e.get("lsn") is None:
            return
        key = (e["rid"], e["lsn"])
        prev = self._digests.get(key)
        if prev is None:
            self._digests[key] = e
        elif prev["digest"] != e["digest"]:
            self._violate(
                "log_matching", e,
                f"range {e['rid']} lsn {e['lsn']}: node {e['node']} "
                f"appended digest {e['digest']} but node "
                f"{prev['node']} holds {prev['digest']} — replicas "
                f"diverge at the same log position",
                window=[prev, e], dedup=key)

    def _on_flush(self, e: dict) -> None:
        self._bump_evidence(e["rid"], e["node"], e["lsn"])

    def _on_ack(self, e: dict) -> None:
        key = (e["rid"], e["node"])
        lsn = e["lsn"]
        if lsn > self._evidence.get(key, 0):
            self._violate(
                "acked_durable", e,
                f"range {e['rid']}: node {e['node']} acked watermark "
                f"{lsn} beyond its durable/committed evidence "
                f"{self._evidence.get(key, 0)} — a crash now loses an "
                f"acked record",
                dedup=key)

    def _support(self, rid: int, lsn: int) -> int:
        return sum(1 for (r, _m), wm in self._evidence.items()
                   if r == rid and wm >= lsn)

    def _on_commit(self, e: dict) -> None:
        n = e.get("n_cohort") or self._cohort_n.get(e["rid"])
        if not n:
            return
        support = self._support(e["rid"], e["lsn"])
        if 2 * support <= n:
            self._violate(
                "acked_committed_majority", e,
                f"range {e['rid']}: leader {e['node']} committed lsn "
                f"{e['lsn']} with durable evidence on only {support} of "
                f"{n} cohort members — acks are outrunning durability",
                dedup=(e["rid"], e["node"]))

    def _on_commit_idx(self, e: dict) -> None:
        key = (e["node"], e["rid"])
        prev = self._commit_idx.get(key)
        if prev is not None and e["lsn"] < prev["lsn"]:
            self._violate(
                "commit_monotonic", e,
                f"range {e['rid']}: node {e['node']} commit index "
                f"regressed {prev['lsn']} -> {e['lsn']} without a "
                f"crash",
                window=[prev, e], dedup=key)
        if prev is None or e["lsn"] >= prev["lsn"]:
            self._commit_idx[key] = e
        # committed-on-a-majority state is as good as durable: a dup
        # re-ack may advertise cmt before the local force lands
        self._bump_evidence(e["rid"], e["node"], e["lsn"])

    # membership
    def _on_member_change(self, e: dict) -> None:
        members = e.get("members")
        if members:
            self._cohort_n[e["rid"]] = len(members)

    def _on_split(self, e: dict) -> None:
        if e.get("n_cohort") and e.get("child") is not None:
            self._cohort_n[e["child"]] = e["n_cohort"]

    # 2PC
    def _on_txn_decide(self, e: dict) -> None:
        self._check_decision(e)

    def _on_txn_decision(self, e: dict) -> None:
        self._check_decision(e)

    def _on_txn_resolve(self, e: dict) -> None:
        self._check_decision(e)
        self._prepares.get((e["node"], e["rid"]), {}).pop(e["txid"], None)

    def _check_decision(self, e: dict) -> None:
        txid, outcome = e["txid"], e["outcome"]
        prev = self._decisions.get(txid)
        if prev is None:
            self._decisions[txid] = e
        elif prev["outcome"] != outcome:
            self._violate(
                "txn_decision_stable", e,
                f"txn {txid}: decision flipped "
                f"{prev['outcome']} -> {outcome} (first decided by node "
                f"{prev['node']}, contradicted by node {e['node']})",
                window=[prev, e], dedup=txid)

    # GC floor vs unresolved committed 2PC prepares
    def _on_txn_prepared(self, e: dict) -> None:
        self._prepares.setdefault((e["node"], e["rid"]), {})[
            e["txid"]] = e["lsn"]

    def _check_floor(self, e: dict, floor: int, tag: str) -> None:
        live = self._prepares.get((e["node"], e["rid"])) or {}
        if live and floor > min(live.values()):
            txid = min(live, key=live.get)
            self._violate(
                "gc_floor_safe", e,
                f"range {e['rid']} node {e['node']}: GC floor pinned at "
                f"{floor} above unresolved committed prepare of txn "
                f"{txid} at lsn {live[txid]} — the log could collect an "
                f"in-doubt transaction",
                dedup=(e["node"], e["rid"], txid, tag))

    def _check_release(self, e: dict, tag: str) -> None:
        live = self._prepares.get((e["node"], e["rid"])) or {}
        if live:
            txid = min(live, key=live.get)
            self._violate(
                "gc_floor_safe", e,
                f"range {e['rid']} node {e['node']}: GC pin released "
                f"while committed prepare of txn {txid} at lsn "
                f"{live[txid]} is still unresolved",
                dedup=(e["node"], e["rid"], txid, tag))

    def _on_txn_pin(self, e: dict) -> None:
        self._check_floor(e, e["lsn"], "pin")

    def _on_txn_unpin(self, e: dict) -> None:
        self._check_release(e, "unpin")

    def _on_gc_floor_pin(self, e: dict) -> None:
        if e.get("lsn") is not None:
            self._check_floor(e, e["lsn"], "wal_pin")

    def _on_gc_floor_release(self, e: dict) -> None:
        self._check_release(e, "wal_release")

    # node / replica lifecycle: volatile state resets
    def _on_node_crash(self, e: dict) -> None:
        node = e["node"]
        self._fence[node] = e["t"]
        for key in [k for k in self._commit_idx if k[0] == node]:
            del self._commit_idx[key]
        for key in [k for k in self._leases if k[1] == node]:
            del self._leases[key]
        for key in [k for k in self._catchup if k[0] == node]:
            del self._catchup[key]
        if e.get("lose_disk"):
            for key in [k for k in self._evidence if k[1] == node]:
                del self._evidence[key]
            for key in [k for k in self._prepares if k[0] == node]:
                del self._prepares[key]

    def _on_session_flap(self, e: dict) -> None:
        # the flapped node's ephemerals (leader claim included) vanish;
        # its lease window cannot fence anyone and it abdicates on
        # reconnect — do not hold the stale window against a successor
        node = e["node"]
        self._fence[node] = e["t"]
        for key in [k for k in self._leases if k[1] == node]:
            del self._leases[key]

    def _on_replica_retired(self, e: dict) -> None:
        node, rid = e["node"], e["rid"]
        self._commit_idx.pop((node, rid), None)
        self._leases.pop((rid, node), None)
        self._catchup.pop((node, rid), None)
        self._evidence.pop((rid, node), None)
        self._prepares.pop((node, rid), None)
