"""Structured cluster event log.

One append-only list of dict events — elections, range splits, replica
migrations, 2PC recovery, WAL GC-floor pin/release, node crashes — plus
the fault-schedule DSL's fire log (merged in via `FaultSchedule.install
(on_event=...)`).  The merged stream is what annotates fig9/10-style
timelines: every throughput dip lines up with the regime change that
caused it.
"""

from __future__ import annotations

import json
from typing import Optional


class EventLog:
    def __init__(self, sim, cap: int = 100_000):
        self.sim = sim
        self.cap = cap
        self.events: list[dict] = []
        self.dropped = 0

    def emit(self, kind: str, **fields) -> None:
        if len(self.events) >= self.cap:
            self.dropped += 1
            return
        ev = {"t": self.sim.now, "kind": kind}
        ev.update(fields)
        self.events.append(ev)

    def export(self, t0: float = 0.0, kinds: Optional[set] = None
               ) -> list[dict]:
        """Events at/after `t0`, times shifted to be relative to `t0`."""
        out = []
        for ev in self.events:
            if ev["t"] < t0:
                continue
            if kinds is not None and ev["kind"] not in kinds:
                continue
            e = dict(ev)
            e["t"] = round(e["t"] - t0, 6)
            out.append(e)
        return out

    def to_jsonl(self, t0: float = 0.0, kinds: Optional[set] = None) -> str:
        """One JSON object per line with stable field ordering (`t`,
        `kind`, then remaining fields sorted by name), so exports diff
        cleanly run-to-run.  Non-JSON field values fall back to `str`."""
        lines = []
        for ev in self.export(t0=t0, kinds=kinds):
            rest = {k: ev[k] for k in sorted(ev) if k not in ("t", "kind")}
            ordered = {"t": ev["t"], "kind": ev["kind"], **rest}
            lines.append(json.dumps(ordered, default=str))
        return "\n".join(lines) + ("\n" if lines else "")
