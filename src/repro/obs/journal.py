"""Protocol flight recorder: a journal of consensus-relevant transitions.

Every replica-protocol state change — proposal append / ack / commit /
apply, election phases, lease acquire / renew / lapse / depose, CATCHUP
enter / exit, membership and split barriers, 2PC prepare / vote / decide
/ resolve, GC-floor pin / release — is recorded as one structured entry
keyed by ``(node, rid, epoch, lsn)`` plus kind-specific fields.

Like the tracer and the profiler, journaling is *pure measurement*: it
models zero sim-time cost and draws nothing from the simulator RNG, so a
journaled run is bit-identical to an un-journaled one.  The journal is
the substrate for two consumers:

- the online invariant watchdog (`obs/watchdog.py`) subscribes via
  `listeners` and checks per-range consensus invariants on every entry;
- the offline replayer/explainer (`benchmarks/explain.py`) reconstructs
  per-range timelines from a JSONL dump and renders root-cause
  narratives.

Journal entry kinds (producer sites in core/replica.py, core/txn.py,
core/node.py):

=================  ==========================================================
kind               meaning / extra fields
=================  ==========================================================
append             record entered a replica's log (leader mint, follower
                   on_propose, catch-up install); ``digest`` fingerprints
                   the record content for the log-matching invariant
flush              a replica's durable watermark advanced (WAL force done)
ack                follower sent a cumulative ack watermark to the leader
commit             leader advanced the commit decision to ``lsn`` via
                   majority acks
commit_idx         a replica's applied/committed index reached ``lsn``
elect_start        node entered candidacy (``round``, ``lst``)
elect_decide       election evaluated: ``candidates``, ``winner``,
                   ``n_cohort``, ``winner_lst``, ``max_lst``
takeover           new leader took over (``cmt``, ``lst``, ``have`` =
                   contiguous unresolved-window coverage, ``n_cohort``)
leader_open        leader re-opened the range for writes
abdicate           leader stepped down (``why``)
deposed            follower deposed a silent leader
lease_renew        leader sent a lease renewal round (``seq``)
lease_acquire      renewal reached a majority; ``until`` is the skew-safe
                   expiry the leader now trusts, ``grace`` marks the
                   takeover grace lease
lease_heard        follower refreshed its leader-liveness clock from a
                   lease beat (``role`` — CATCHUP beats feed the
                   starvation monitor)
lease_lapse        leader's lease expired without majority renewal
catchup_enter      replica entered CATCHUP (``leader``)
catchup_retry      CATCHUP replica re-requested missing data
catchup_exit       replica completed catch-up at ``lsn``
split              SPLIT barrier applied (``child``, ``split_key``)
member_change      MEMBER_CHANGE barrier applied (``members``)
txn_prepare        participant received a 2PC prepare (``txid``)
txn_prepared       participant's PREPARE record committed at ``lsn``
txn_vote           participant voted (``txid``, ``vote``)
txn_decide         a decision was minted (``txid``, ``outcome``, ``by``)
txn_decision       a decision record was applied (``txid``, ``outcome``)
txn_resolve        participant resolved staged state (``txid``,
                   ``outcome``)
txn_pin            2PC state pinned a WAL record against GC (``why``)
txn_unpin          the pin was released
gc_floor_pin       WAL GC floor pinned at ``lsn``  (from wal.on_gc_event)
gc_floor_release   WAL GC floor released
node_crash         node crashed (volatile replica state lost)
node_restart       node restarted
=================  ==========================================================
"""

from __future__ import annotations

import json
import zlib
from typing import Callable, Optional

# Kinds worth surfacing verbatim when annotating a latency window: the
# regime-change / fault / repair transitions.  Steady-state traffic
# (append/flush/ack/commit churn) is only counted, never listed.
NOTABLE_KINDS = frozenset((
    "elect_start", "elect_decide", "takeover", "leader_open", "abdicate",
    "deposed", "lease_lapse", "catchup_enter", "catchup_retry",
    "catchup_exit", "split", "member_change", "node_crash", "node_restart",
    "session_flap", "txn_decide", "gc_floor_pin", "replica_retired",
))


def record_digest(rec) -> int:
    """Stable content fingerprint of a log record for the log-matching
    invariant (same (rid, lsn) ⇒ same digest on every replica).  Uses
    crc32 over a canonical repr — `hash()` is salted per process and
    would break run-to-run comparability of exported journals."""
    txn = rec.txn
    if txn is not None:
        txn = repr(txn)
    canon = (rec.range_id, rec.lsn, rec.op.name, rec.key,
             repr(rec.columns), rec.txn_tail, txn)
    return zlib.crc32(repr(canon).encode())


class ProtocolJournal:
    """Append-only, bounded journal of protocol transitions.

    `record()` is the single producer entry point; `listeners` receive
    every entry (even past the storage cap, so the watchdog never goes
    blind on a long run)."""

    def __init__(self, sim, enabled: bool = True, cap: int = 400_000):
        self.sim = sim
        self.enabled = enabled
        self.cap = cap
        self.entries: list[dict] = []
        self.dropped = 0
        self.listeners: list[Callable[[dict], None]] = []

    def record(self, kind: str, node: int, rid: Optional[int] = None,
               epoch: Optional[int] = None, lsn: Optional[int] = None,
               **fields) -> None:
        if not self.enabled:
            return
        e = {"t": self.sim.now, "kind": kind, "node": node}
        if rid is not None:
            e["rid"] = rid
        if epoch is not None:
            e["epoch"] = epoch
        if lsn is not None:
            e["lsn"] = lsn
        e.update(fields)
        if len(self.entries) < self.cap:
            self.entries.append(e)
        else:
            self.dropped += 1
        for fn in self.listeners:
            fn(e)

    # -- consumers ----------------------------------------------------------
    def export(self, t0: float = 0.0, rid: Optional[int] = None,
               kinds: Optional[set] = None) -> list[dict]:
        """Entries at/after `t0` (times shifted relative to `t0`),
        optionally filtered to one range / a kind set."""
        out = []
        for e in self.entries:
            if e["t"] < t0:
                continue
            if rid is not None and e.get("rid") != rid:
                continue
            if kinds is not None and e["kind"] not in kinds:
                continue
            d = dict(e)
            d["t"] = round(d["t"] - t0, 6)
            out.append(d)
        return out

    def window(self, t_lo: float, t_hi: float,
               rid: Optional[int] = None) -> list[dict]:
        """Entries with t in [t_lo, t_hi] (absolute sim time, unshifted):
        the 'implicated journal window' attached to violations and used
        to annotate slow traces."""
        return [e for e in self.entries
                if t_lo <= e["t"] <= t_hi
                and (rid is None or e.get("rid") == rid)]

    def window_summary(self, t_lo: float, t_hi: float,
                       rid: Optional[int] = None,
                       max_notable: int = 8) -> dict:
        """Compact annotation of a latency window: per-kind entry counts
        plus the notable (regime-change / fault / repair) entries
        verbatim.  This is what `--report` prints under a slow trace."""
        win = self.window(t_lo, t_hi, rid)
        by_kind: dict[str, int] = {}
        notable = []
        for e in win:
            by_kind[e["kind"]] = by_kind.get(e["kind"], 0) + 1
            if e["kind"] in NOTABLE_KINDS and len(notable) < max_notable:
                notable.append(dict(e))
        return {"n_entries": len(win),
                "by_kind": dict(sorted(by_kind.items())),
                "notable": notable}

    def txn_entries(self, txid: str) -> list[dict]:
        """Every journal entry of one 2PC transaction, in order — the
        txid-keyed chain annotation for slow-transaction reports."""
        return [e for e in self.entries if e.get("txid") == txid]

    def to_jsonl(self, t0: float = 0.0, rid: Optional[int] = None,
                 kinds: Optional[set] = None) -> str:
        """One JSON object per line, stable field order (`t`, `kind`,
        `node`, `rid`, `epoch`, `lsn`, then the rest sorted by name) so
        dumps diff cleanly run-to-run — same contract as
        `EventLog.to_jsonl`."""
        head = ("t", "kind", "node", "rid", "epoch", "lsn")
        lines = []
        for e in self.export(t0=t0, rid=rid, kinds=kinds):
            ordered = {k: e[k] for k in head if k in e}
            ordered.update({k: e[k] for k in sorted(e) if k not in head})
            lines.append(json.dumps(ordered, default=str))
        return "\n".join(lines) + ("\n" if lines else "")

    @staticmethod
    def load_jsonl(text: str) -> list[dict]:
        """Parse a dump produced by `to_jsonl` back into entry dicts."""
        return [json.loads(line) for line in text.splitlines() if line.strip()]
