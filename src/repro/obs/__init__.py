"""Observability layer: sim-time tracing, per-node metrics, event log.

One `Observability` instance hangs off each cluster (`cluster.obs`);
components reach it as `node.cluster.obs`.  Everything here is pure
measurement — no modeled sim-time cost, no simulator-RNG draws — so a
run with observability on is bit-identical to one with it off.
"""

from __future__ import annotations

from dataclasses import dataclass

from .events import EventLog
from .journal import ProtocolJournal, record_digest
from .metrics import MetricsRegistry
from .profile import Profiler, format_profile_report
from .trace import (OpTrace, Tracer, TxnTrace, stage_breakdown,
                    CASSANDRA_CHAIN, SPINNAKER_CHAIN)
from .watchdog import InvariantWatchdog

__all__ = [
    "ObsConfig", "Observability", "Tracer", "OpTrace", "TxnTrace",
    "EventLog", "MetricsRegistry", "Profiler", "format_profile_report",
    "stage_breakdown", "ProtocolJournal", "InvariantWatchdog",
    "record_digest",
    "SPINNAKER_CHAIN", "CASSANDRA_CHAIN", "install_node_gauges",
]


@dataclass
class ObsConfig:
    """Knobs carried by the cluster config.

    `trace_sample` is the fraction of client ops traced (error-diffusion
    sampling — see `Tracer`); 2PC chains are always traced when enabled
    since the completeness audit must see *every* committed transaction.
    `metrics_interval` <= 0 leaves the scrape ticker unarmed (on-demand
    `scrape()` only), so plain unit-test clusters carry no timers.

    `profile` enables the component-attributed resource profiler (pure
    accounting — a profiled run is bit-identical to an unprofiled one);
    `profile_interval` > 0 additionally records a per-interval
    utilization timeline (one timer, no RNG draws).

    `journal` enables the protocol flight recorder (obs/journal.py);
    `watchdog` additionally runs the online invariant checker over it —
    both pure measurement, bit-identical on/off."""
    enabled: bool = True
    trace_sample: float = 1.0
    metrics_interval: float = 0.0
    profile: bool = True
    profile_interval: float = 0.0
    journal: bool = True
    watchdog: bool = True


class Observability:
    def __init__(self, sim, system: str, cfg: ObsConfig | None = None):
        self.cfg = cfg or ObsConfig()
        self.sim = sim
        self.tracer = Tracer(sim, system, sample=self.cfg.trace_sample,
                             enabled=self.cfg.enabled)
        self.events = EventLog(sim)
        self.metrics = MetricsRegistry(sim, interval=self.cfg.metrics_interval)
        self.profiler = Profiler(sim, system,
                                 enabled=self.cfg.enabled and self.cfg.profile,
                                 interval=self.cfg.profile_interval)
        self.journal = ProtocolJournal(
            sim, enabled=self.cfg.enabled and self.cfg.journal)
        self.watchdog = InvariantWatchdog(
            self.journal,
            enabled=self.cfg.enabled and self.cfg.journal
            and self.cfg.watchdog)

    def start(self) -> None:
        if self.cfg.enabled and self.cfg.metrics_interval > 0:
            self.metrics.start()
        self.profiler.start()

    def stop(self) -> None:
        """End-of-run flush: final metrics scrape + final profiler
        utilization snapshot.  Idempotent."""
        self.metrics.stop()
        self.profiler.stop()


def install_node_gauges(obs: Observability, node) -> None:
    """Register the per-node gauge set for a Spinnaker node.

    Gauges close over the live node object, so they keep reporting across
    crash/restart cycles (a crashed node reads as an idle one)."""
    m = obs.metrics
    nid = node.node_id
    sim = node.sim
    m.add_gauge(nid, "cpu_queue_s", node.cpu.queue_delay)
    m.add_gauge(nid, "disk_queue", node.disk.queue_depth)
    m.add_gauge(nid, "wal_forces", lambda: node.disk.forces)
    m.add_gauge(nid, "wal_bytes_forced", lambda: node.disk.bytes_forced)
    m.add_gauge(nid, "gc_floor_pins",
                lambda: len(getattr(node.wal, "gc_floor", {})))
    m.add_gauge(nid, "commit_queue_lag", lambda: sum(
        sum(1 for l in rep.queue if l > rep.cmt)
        for rep in node.replicas.values()))
    m.add_gauge(nid, "lock_table_keys", lambda: sum(
        len(rep.txn.locks) for rep in node.replicas.values()
        if getattr(rep, "txn", None) is not None))
    m.add_gauge(nid, "indoubt_2pc", lambda: sum(
        len(rep.txn.prepared) + len(rep.txn.active)
        for rep in node.replicas.values()
        if getattr(rep, "txn", None) is not None))
