"""Sim-time cluster resource profiler (zero modeled cost).

Every unit of CPU work, disk force, and network message in the simulator
carries a component label (``paxos.propose``, ``wal.force``,
``txn.prepare``, ``lease.heartbeat``, ``catchup``, ``client.read``, ...)
and, where applicable, a range id.  The profiler accumulates
per-node x per-component busy-time / message / byte totals, per-interval
utilization timelines, and per-range *heat* (ops, bytes, lock-wait) that
the `RangeBalancer` consumes directly instead of per-leader counters.

Discipline (same as the span tracer): accounting only.  The profiler
never draws from the simulator RNG and never adds modeled time, so a
profiled run is bit-identical to an unprofiled one.  The only events it
schedules are optional utilization-snapshot ticks, which make no RNG
draws of their own.

Attribution invariant: the per-component CPU/disk busy-time sums equal
the measured `FifoServer.total_busy` / `Disk.total_busy` of each node
(the dispatch sites are the only producers of that busy time), which the
``--scenario profile`` check asserts to within 5%.
"""

from __future__ import annotations

from typing import Optional


class Profiler:
    """Per-node x per-component resource accounting + per-range heat."""

    def __init__(self, sim, system: str, enabled: bool = True,
                 interval: float = 0.0):
        self.sim = sim
        self.system = system
        self.enabled = enabled
        self.interval = interval
        self.t0 = sim.now
        # (node, component) -> mutable [busy_s, msgs]
        self.cpu: dict[tuple, list] = {}
        # (node, component) -> [wait_s_total, samples]
        self.queue_wait: dict[tuple, list] = {}
        # (node, component) -> [busy_s, forces, bytes]
        self.disk: dict[tuple, list] = {}
        # (node, component) -> [msgs, bytes]
        self.net: dict[tuple, list] = {}
        # rid -> [ops, bytes, lock_wait_s]
        self.heat: dict[int, list] = {}
        # node_id -> (FifoServer cpu, Disk disk) for measured-busy readback
        self._nodes: dict = {}
        self.timeline: list[dict] = []
        self._prev_busy: dict = {}
        self._running = False

    # -- wiring ---------------------------------------------------------------
    def attach_node(self, node_id, cpu=None, disk=None) -> None:
        """Register a node's resources; tags the disk so group-commit
        batches can attribute their latency back through the profiler."""
        if not self.enabled:
            return
        self._nodes[node_id] = (cpu, disk)
        if disk is not None:
            disk.profiler = self
            disk.profiler_node = node_id

    def attach_network(self, net) -> None:
        if self.enabled:
            net.profiler = self

    # -- accounting hooks (pure bookkeeping: no RNG, no modeled time) ---------
    def cpu_work(self, node, component: str, service_s: float,
                 rid: Optional[int] = None,
                 queue_wait_s: Optional[float] = None) -> None:
        ent = self.cpu.get((node, component))
        if ent is None:
            ent = self.cpu[(node, component)] = [0.0, 0]
        ent[0] += service_s
        ent[1] += 1
        if queue_wait_s is not None:
            qw = self.queue_wait.get((node, component))
            if qw is None:
                qw = self.queue_wait[(node, component)] = [0.0, 0]
            qw[0] += queue_wait_s
            qw[1] += 1

    def disk_busy(self, node, component: str, busy_s: float, nbytes: int,
                  rid: Optional[int] = None) -> None:
        ent = self.disk.get((node, component))
        if ent is None:
            ent = self.disk[(node, component)] = [0.0, 0, 0]
        ent[0] += busy_s
        ent[1] += 1
        ent[2] += nbytes

    def net_msg(self, node, component: str, nbytes: int,
                rid: Optional[int] = None) -> None:
        ent = self.net.get((node, component))
        if ent is None:
            ent = self.net[(node, component)] = [0, 0]
        ent[0] += 1
        ent[1] += nbytes

    def range_op(self, rid: int, nbytes: int = 0) -> None:
        """One served client op on `rid` (bumped at the same semantic sites
        as the replica serve counters, but cluster-global — leader changes
        do not corrupt the balancer's deltas)."""
        ent = self.heat.get(rid)
        if ent is None:
            ent = self.heat[rid] = [0, 0, 0.0]
        ent[0] += 1
        ent[1] += nbytes

    def lock_wait(self, rid: int, wait_s: float) -> None:
        ent = self.heat.get(rid)
        if ent is None:
            ent = self.heat[rid] = [0, 0, 0.0]
        ent[2] += wait_s

    def range_ops(self, rid: int) -> int:
        """Cumulative served ops for `rid` (the balancer's load signal)."""
        ent = self.heat.get(rid)
        return ent[0] if ent is not None else 0

    def heat_snapshot(self, rid: Optional[int] = None):
        """JSON-ready heat reading(s): {ops, bytes, lock_wait_s}."""
        def one(ent):
            return {"ops": ent[0], "bytes": ent[1],
                    "lock_wait_s": round(ent[2], 9)}
        if rid is not None:
            ent = self.heat.get(rid)
            return one(ent) if ent is not None else \
                {"ops": 0, "bytes": 0, "lock_wait_s": 0.0}
        return {r: one(e) for r, e in sorted(self.heat.items())}

    # -- interval utilization timeline ---------------------------------------
    def start(self) -> None:
        if not (self.enabled and self.interval > 0) or self._running:
            return
        self._running = True
        self._prev_busy = {nid: (cpu.total_busy if cpu else 0.0,
                                 disk.total_busy if disk else 0.0)
                           for nid, (cpu, disk) in self._nodes.items()}
        self._prev_t = self.sim.now
        self.sim.schedule(self.interval, self._tick)

    def stop(self) -> None:
        if self._running and self.sim.now > self._prev_t:
            self._snapshot()
        self._running = False

    def _tick(self) -> None:
        if not self._running:
            return
        self._snapshot()
        self.sim.schedule(self.interval, self._tick)

    def _snapshot(self) -> None:
        dt = max(self.sim.now - self._prev_t, 1e-12)
        cpu_util, disk_util = {}, {}
        for nid, (cpu, disk) in sorted(self._nodes.items()):
            pc, pd = self._prev_busy.get(nid, (0.0, 0.0))
            c = cpu.total_busy if cpu else 0.0
            d = disk.total_busy if disk else 0.0
            cpu_util[str(nid)] = round((c - pc) / dt, 6)
            disk_util[str(nid)] = round((d - pd) / dt, 6)
            self._prev_busy[nid] = (c, d)
        self.timeline.append({"t": round(self.sim.now, 6),
                              "cpu_util": cpu_util, "disk_util": disk_util})
        self._prev_t = self.sim.now

    # -- rollups --------------------------------------------------------------
    def _by_component(self, table: dict, node, idx: int, nd: int = 9) -> dict:
        # table keys mix int node ids and str client ids: filter first,
        # then sort by component only
        items = [(c, v) for (n, c), v in table.items() if n == node]
        return {c: round(v[idx], nd) for c, v in sorted(items)}

    def summary(self) -> dict:
        """JSON-ready rollup: per-node measured vs attributed busy time,
        per-component splits, cluster-wide shares, and per-range heat."""
        elapsed = max(self.sim.now - self.t0, 1e-12)
        nodes = {}
        tot_cpu_comp: dict[str, float] = {}
        tot_cpu_busy = 0.0
        for nid, (cpu, disk) in sorted(self._nodes.items()):
            cpu_comp = self._by_component(self.cpu, nid, 0)
            disk_comp = self._by_component(self.disk, nid, 0)
            measured_cpu = cpu.total_busy if cpu else 0.0
            measured_disk = disk.total_busy if disk else 0.0
            tot_cpu_busy += measured_cpu
            for c, v in cpu_comp.items():
                tot_cpu_comp[c] = tot_cpu_comp.get(c, 0.0) + v
            nodes[str(nid)] = {
                "cpu_busy_s": round(measured_cpu, 9),
                "cpu_attributed_s": round(sum(cpu_comp.values()), 9),
                "cpu_util": round(measured_cpu / elapsed, 6),
                "cpu_by_component": cpu_comp,
                "cpu_msgs_by_component": self._by_component(self.cpu, nid, 1),
                "queue_wait_s_by_component": self._by_component(
                    self.queue_wait, nid, 0),
                "disk_busy_s": round(measured_disk, 9),
                "disk_attributed_s": round(sum(disk_comp.values()), 9),
                "disk_util": round(measured_disk / elapsed, 6),
                "disk_by_component": disk_comp,
                "disk_bytes_by_component": self._by_component(
                    self.disk, nid, 2, nd=0),
                "net_msgs_by_component": self._by_component(self.net, nid, 0),
                "net_bytes_by_component": self._by_component(
                    self.net, nid, 1),
            }
        shares = {c: round(v / tot_cpu_busy, 6)
                  for c, v in sorted(tot_cpu_comp.items())} \
            if tot_cpu_busy > 0 else {}
        return {
            "system": self.system,
            "elapsed_s": round(elapsed, 6),
            "nodes": nodes,
            "cpu_share_by_component": shares,
            "cluster_cpu_busy_s": round(tot_cpu_busy, 9),
            "heat": {str(r): h for r, h in self.heat_snapshot().items()},
            "timeline": self.timeline,
        }


def _tree(by_component: dict) -> dict:
    """Group dotted component labels into a top-level -> leaf tree."""
    out: dict[str, dict] = {}
    for comp, v in by_component.items():
        top = comp.split(".", 1)[0]
        out.setdefault(top, {})[comp] = v
    return out


def format_profile_report(profile: dict, width: int = 32) -> list[str]:
    """Text flamegraph-style rollup (node -> component -> sub-stage) of a
    `Profiler.summary()` block; returned as printable lines."""
    lines = []
    for nid, nb in sorted(profile.get("nodes", {}).items(),
                          key=lambda kv: str(kv[0])):
        busy = nb["cpu_busy_s"]
        lines.append(
            f"node {nid}: cpu {100 * nb['cpu_util']:.1f}% util "
            f"({busy * 1e3:.1f} ms busy), disk {100 * nb['disk_util']:.1f}% "
            f"({nb['disk_busy_s'] * 1e3:.1f} ms)")
        total = max(busy, 1e-12)
        for top, leaves in sorted(_tree(nb["cpu_by_component"]).items(),
                                  key=lambda kv: -sum(kv[1].values())):
            tv = sum(leaves.values())
            bar = "#" * int(round(width * tv / total))
            lines.append(f"  {top:<16} {tv * 1e3:9.3f} ms "
                         f"{100 * tv / total:5.1f}%  {bar}")
            if len(leaves) > 1 or next(iter(leaves)) != top:
                for comp, v in sorted(leaves.items(), key=lambda kv: -kv[1]):
                    lines.append(f"    {comp:<18} {v * 1e3:9.3f} ms "
                                 f"{100 * v / total:5.1f}%")
        dtot = max(nb["disk_busy_s"], 1e-12)
        for comp, v in sorted(nb["disk_by_component"].items(),
                              key=lambda kv: -kv[1]):
            lines.append(f"  disk:{comp:<13} {v * 1e3:9.3f} ms "
                         f"{100 * v / dtot:5.1f}%")
    heat = profile.get("heat", {})
    if heat:
        lines.append("range heat (ops / bytes / lock-wait):")
        for rid, h in sorted(heat.items(), key=lambda kv: -kv[1]["ops"]):
            lines.append(f"  range {rid:>3}: {h['ops']:>8} ops  "
                         f"{h['bytes']:>10} B  "
                         f"{h['lock_wait_s'] * 1e3:8.2f} ms lock-wait")
    return lines
