"""Sim-time distributed tracing for the write path and 2PC.

A trace is born at the client (`Tracer.maybe_start`), rides the request
payload to the leader, and collects milestone timestamps as the op moves
through the pipeline.  Milestones are virtual-clock stamps only — tracing
adds zero modeled sim-time cost, so a traced run is bit-identical to an
untraced one (sampling is decided by a deterministic accumulator, never
by the simulator RNG).

Milestones for a Spinnaker strong write::

    t_issue   client accepts the op (includes retries/backoff thereafter)
    t_send    last attempt leaves the client
    t_recv    leader node receives the request
    t_cpu     CPU service done; replica handler runs (record admitted)
    t_flush   proposal batch holding the record is flushed to followers
    t_forced  leader's WAL force covering the record is durable
    t_commit  commit rule satisfied (leader force + majority ack); applied
    t_acked   ack handed to the per-client reply envelope (coalesced acks
              for one batch leave as one message; the flush is same-instant,
              so this stage measures coalescing delay — by design ~0)
    t_done    client receives the ack

Consecutive milestones define stages that sum exactly to end-to-end
latency: client_queue, net_req, cpu, batch_wait, wal_force, commit_wait,
ack_coalesce, reply_net.  The Cassandra baseline uses a shorter chain (no
proposal batch / quorum round): client_queue, net_req, cpu, durable_wait,
reply_net.

2PC transactions get a parallel txid-keyed chain (`TxnTrace`):
prepare_sent → vote → decide → per-participant resolve.  The chains
double as a correctness audit: `audit_writes` / `audit_txns` verify that
every acked traced write (and every committed 2PC txn) carries the full
chain — a structural check that survives leader kills because the trace
objects live outside any node.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

# stage name -> (start milestone, end milestone), in pipeline order
SPINNAKER_CHAIN = (
    ("client_queue", "t_issue", "t_send"),
    ("net_req", "t_send", "t_recv"),
    ("cpu", "t_recv", "t_cpu"),
    ("batch_wait", "t_cpu", "t_flush"),
    ("wal_force", "t_flush", "t_forced"),
    ("commit_wait", "t_forced", "t_commit"),
    ("ack_coalesce", "t_commit", "t_acked"),
    ("reply_net", "t_acked", "t_done"),
)

CASSANDRA_CHAIN = (
    ("client_queue", "t_issue", "t_send"),
    ("net_req", "t_send", "t_recv"),
    ("cpu", "t_recv", "t_cpu"),
    ("durable_wait", "t_cpu", "t_commit"),
    ("reply_net", "t_commit", "t_done"),
)

_CHAINS = {"spinnaker": SPINNAKER_CHAIN, "cassandra": CASSANDRA_CHAIN}

# client paths whose acked ops must carry the full server-side chain
_WRITE_PATHS = ("write", "txn")


@dataclass
class OpTrace:
    """One sampled client operation; all times are sim-time seconds."""
    trace_id: int
    kind: str                 # workload label ("write", "rmw", "txn_cross"…)
    path: str                 # client path: "write" | "read" | "txn"
    key: str
    system: str               # "spinnaker" | "cassandra"
    t_issue: float
    t_send: Optional[float] = None
    t_recv: Optional[float] = None
    t_cpu: Optional[float] = None
    t_flush: Optional[float] = None
    t_forced: Optional[float] = None
    t_commit: Optional[float] = None
    t_acked: Optional[float] = None
    t_done: Optional[float] = None
    attempts: int = 0
    node: Optional[int] = None      # node that served the final attempt
    lsn: Optional[int] = None
    ok: Optional[bool] = None
    code: Optional[str] = None

    def mark_recv(self, t: float, node_id: int) -> None:
        self.t_recv = t
        self.node = node_id

    @property
    def e2e(self) -> Optional[float]:
        if self.t_done is None:
            return None
        return self.t_done - self.t_issue

    def _chain(self):
        chain = _CHAINS[self.system]
        if self.path not in _WRITE_PATHS:
            # reads never touch the WAL: everything past the server's
            # receive collapses into one "server" stage
            return chain[:2] + (("server", "t_recv", "t_done"),)
        return chain

    def missing(self) -> list[str]:
        """Milestones the op's chain requires but that were never marked."""
        need = {m for _, a, b in self._chain() for m in (a, b)}
        return sorted(m for m in need if getattr(self, m) is None)

    def complete(self) -> bool:
        return not self.missing()

    def stages(self) -> Optional[dict[str, float]]:
        """Per-stage durations; None unless every milestone is present.

        Durations are clamped at 0 (a retried op can leave a stale earlier
        mark) but always rescaled nowhere — they sum to e2e exactly when
        the milestones are monotone, which is the steady-state case the
        breakdown report runs under."""
        if not self.complete():
            return None
        out = {}
        for name, a, b in self._chain():
            out[name] = max(0.0, getattr(self, b) - getattr(self, a))
        return out


@dataclass
class TxnTrace:
    """Chain of one 2PC transaction, keyed by txid (cluster-global, so it
    survives coordinator crashes and observes the recovery re-drive)."""
    txid: str
    t_start: float
    coordinator: int
    participants: tuple[int, ...]
    prepare_sent: dict[int, float] = field(default_factory=dict)
    voted: dict[int, float] = field(default_factory=dict)
    t_decided: Optional[float] = None
    outcome: Optional[str] = None          # "commit" | "abort"
    resolved: dict[int, float] = field(default_factory=dict)
    t_client_ack: Optional[float] = None

    def missing(self) -> list[str]:
        out = []
        for rid in self.participants:
            if rid not in self.prepare_sent:
                out.append(f"prepare_sent[{rid}]")
            if rid not in self.voted:
                out.append(f"vote[{rid}]")
        if self.t_decided is None:
            out.append("decide")
        for rid in self.participants:
            if rid not in self.resolved:
                out.append(f"resolve[{rid}]")
        return out

    def complete(self) -> bool:
        return not self.missing()


# Hard ceiling on retained traces: a leaked unbounded list would defeat
# the "cheap enough to leave on" goal.  Drops are counted, never silent.
MAX_TRACES = 200_000


class Tracer:
    """Per-cluster trace collector.

    Sampling is an error-diffusion accumulator over the op sequence
    (``acc += rate; sample when acc >= 1``): deterministic, rate-exact in
    the long run, and independent of the simulator RNG stream, so
    enabling or disabling tracing cannot perturb the simulation."""

    def __init__(self, sim, system: str, sample: float = 1.0,
                 enabled: bool = True):
        self.sim = sim
        self.system = system
        self.sample = max(0.0, min(1.0, sample))
        self.enabled = enabled
        self.traces: list[OpTrace] = []      # finished ops
        self.txns: dict[str, TxnTrace] = {}
        self.dropped = 0
        self._acc = 0.0
        self._next_id = 0

    # -- client ops ---------------------------------------------------

    def maybe_start(self, kind: str, path: str, key: str
                    ) -> Optional[OpTrace]:
        if not self.enabled or self.sample <= 0.0:
            return None
        self._acc += self.sample
        if self._acc < 1.0:
            return None
        self._acc -= 1.0
        self._next_id += 1
        return OpTrace(trace_id=self._next_id, kind=kind, path=path,
                       key=key, system=self.system, t_issue=self.sim.now)

    def finish(self, tr: OpTrace, ok: bool, code: Optional[str]) -> None:
        tr.t_done = self.sim.now
        tr.ok = ok
        tr.code = code
        if len(self.traces) >= MAX_TRACES:
            self.dropped += 1
            return
        self.traces.append(tr)

    # -- 2PC chains ---------------------------------------------------

    def txn_begin(self, txid: str, coordinator: int,
                  participants) -> Optional[TxnTrace]:
        if not self.enabled:
            return None
        tr = TxnTrace(txid=txid, t_start=self.sim.now,
                      coordinator=coordinator,
                      participants=tuple(sorted(participants)))
        self.txns[txid] = tr
        return tr

    def txn_mark(self, txid: str, what: str, rid: Optional[int] = None
                 ) -> None:
        tr = self.txns.get(txid)
        if tr is None:
            return
        now = self.sim.now
        if what == "prepare_sent":
            tr.prepare_sent[rid] = now
        elif what == "vote":
            tr.voted[rid] = now
        elif what in ("commit", "abort"):
            tr.t_decided = now if tr.t_decided is None else tr.t_decided
            tr.outcome = what
        elif what == "resolve":
            tr.resolved[rid] = now
        elif what == "client_ack":
            tr.t_client_ack = now

    # -- audits -------------------------------------------------------

    def audit_writes(self) -> dict:
        """Every acked traced write must carry the full milestone chain."""
        acked = [t for t in self.traces
                 if t.ok and t.path in _WRITE_PATHS]
        bad = [{"trace_id": t.trace_id, "kind": t.kind, "key": t.key,
                "missing": t.missing()}
               for t in acked if not t.complete()]
        return {"acked_writes_traced": len(acked),
                "incomplete": len(bad),
                "violations": bad[:20],
                "dropped": self.dropped,
                "ok": not bad}

    def audit_txns(self) -> dict:
        """Every *committed* 2PC txn must show prepare → vote → decide →
        per-participant resolve.  Stronger than "every acked txn": after
        the post-run settle even orphaned decisions must have re-driven
        resolution on all participants."""
        committed = [t for t in self.txns.values()
                     if t.outcome == "commit"]
        bad = [{"txid": t.txid, "missing": t.missing()}
               for t in committed if not t.complete()]
        return {"committed_txns": len(committed),
                "acked_txns": sum(1 for t in committed
                                  if t.t_client_ack is not None),
                "incomplete": len(bad),
                "violations": bad[:20],
                "ok": not bad}


# -- breakdown report -------------------------------------------------


def _percentile(sorted_vals, p: float) -> float:
    if not sorted_vals:
        return float("nan")
    i = min(len(sorted_vals) - 1, int(p / 100.0 * len(sorted_vals)))
    return sorted_vals[i]


def stage_breakdown(traces, kind: str = "write",
                    band: tuple[float, float] = (45.0, 55.0),
                    top_n: int = 10) -> dict:
    """Decompose the p50 of `kind` ops into per-stage contributions.

    Stage means are taken over the traces whose end-to-end latency falls
    in the [p45, p55) rank band, so the stage sums reconstruct the median
    op (a plain mean over all traces would reconstruct the *mean*, which
    p99 stragglers dominate).  Returns stage means in ms plus the top
    `top_n` slowest complete traces with their own stage splits."""
    done = [t for t in traces
            if t.kind == kind and t.ok and t.complete()
            and t.e2e is not None]
    if not done:
        return {"kind": kind, "n_traces": 0}
    done.sort(key=lambda t: (t.e2e, t.trace_id))
    n = len(done)
    lo = int(band[0] / 100.0 * n)
    hi = max(lo + 1, int(band[1] / 100.0 * n))
    mid = done[lo:hi]
    stage_names = [s for s, _, _ in mid[0]._chain()]
    sums = {s: 0.0 for s in stage_names}
    for t in mid:
        for s, v in t.stages().items():
            sums[s] += v
    stages_ms = {s: sums[s] / len(mid) * 1e3 for s in stage_names}
    e2es = [t.e2e for t in done]
    slowest = [{
        "trace_id": t.trace_id, "key": t.key, "node": t.node,
        "attempts": t.attempts, "e2e_ms": t.e2e * 1e3,
        # absolute sim-time bounds, so consumers can pull the implicated
        # protocol-journal window for root-cause annotation
        "t_issue": t.t_issue, "t_done": t.t_done,
        "stages_ms": {s: v * 1e3 for s, v in t.stages().items()},
    } for t in done[-top_n:]][::-1]
    return {
        "kind": kind,
        "n_traces": n,
        "p50_ms": _percentile(e2es, 50) * 1e3,
        "p99_ms": _percentile(e2es, 99) * 1e3,
        "stages_p50_ms": stages_ms,
        "stage_sum_p50_ms": sum(stages_ms.values()),
        "band_mean_e2e_ms": sum(t.e2e for t in mid) / len(mid) * 1e3,
        "top_slowest": slowest,
    }
