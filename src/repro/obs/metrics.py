"""Per-node metrics registry: counters + gauges scraped into time series.

Counters are bumped at the instrumentation site (`inc`); gauges are
callbacks registered once (`add_gauge`) and evaluated on a sim-time
scrape tick; histograms are log-binned distributions fed by `observe`
(queue waits, lock holds) that scrape their cumulative sample count
like a counter and export full percentiles in `summary()`.  Each scrape
appends one `(t, value)` sample per metric to its series, which is what
the fig9/10-style timeline plots want.

Metric names are flat strings; the exported key is ``n<node>.<name>``
(e.g. ``n2.wal_forces``).  Counters are exported cumulatively — rates
are a post-processing step, like any scrape-based system.

The scrape tick is only armed when `start()` is called (the experiment
runner does this when `metrics_interval > 0`), so clusters built by unit
tests carry no perpetual timers and `run_until_idle` still terminates.
"""

from __future__ import annotations

from typing import Callable, Optional


class MetricsRegistry:
    def __init__(self, sim, interval: float = 0.0):
        self.sim = sim
        self.interval = interval
        self.counters: dict[tuple, float] = {}       # (node, name) -> value
        self.gauges: dict[tuple, Callable[[], float]] = {}
        self.histograms: dict[tuple, object] = {}    # (node, name) -> hist
        self.series: dict[tuple, list] = {}          # (node, name) -> [(t,v)]
        self._running = False
        self._last_scrape_t = -1.0

    # -- instrumentation surface --------------------------------------

    def inc(self, node, name: str, v: float = 1.0) -> None:
        key = (node, name)
        self.counters[key] = self.counters.get(key, 0.0) + v

    def add_gauge(self, node, name: str, fn: Callable[[], float]) -> None:
        self.gauges[(node, name)] = fn

    def observe(self, node, name: str, v: float) -> None:
        """Record one sample into a log-binned histogram metric."""
        key = (node, name)
        h = self.histograms.get(key)
        if h is None:
            # lazy import: obs must not import the workload package at
            # module load (workload -> experiment -> obs would cycle)
            from ..workload.metrics import LatencyHistogram
            h = self.histograms[key] = LatencyHistogram()
        h.add(v)

    # -- scraping -----------------------------------------------------

    def start(self, interval: Optional[float] = None) -> None:
        if interval is not None:
            self.interval = interval
        if self._running or self.interval <= 0:
            return
        self._running = True
        self.sim.schedule(self.interval, self._tick)

    def stop(self) -> None:
        """Disarm the ticker, emitting one final scrape first so short
        runs and the tail interval aren't dropped from the series."""
        if self._running and self.interval > 0 \
                and self.sim.now > self._last_scrape_t:
            self.scrape()
        self._running = False

    def _tick(self) -> None:
        if not self._running:
            return
        self.scrape()
        self.sim.schedule(self.interval, self._tick)

    def scrape(self) -> None:
        """Append one sample per metric at the current sim time."""
        now = self.sim.now
        self._last_scrape_t = now
        for key, val in self.counters.items():
            self.series.setdefault(key, []).append((now, val))
        for key, h in self.histograms.items():
            self.series.setdefault(key, []).append((now, h.total))
        for key, fn in self.gauges.items():
            try:
                v = float(fn())
            except Exception:
                continue        # a gauge over crashed-node state is absent
            self.series.setdefault(key, []).append((now, v))

    # -- export -------------------------------------------------------

    def export(self) -> dict[str, list]:
        return {f"n{node}.{name}": [(round(t, 6), v) for t, v in pts]
                for (node, name), pts in sorted(self.series.items(),
                                                key=lambda kv: str(kv[0]))}

    def summary(self) -> dict[str, dict]:
        """Mean/max per series — the compact form for JSON artifacts."""
        out = {}
        for (node, name), pts in sorted(self.series.items(),
                                        key=lambda kv: str(kv[0])):
            vals = [v for _, v in pts]
            if not vals:
                continue
            out[f"n{node}.{name}"] = {
                "last": vals[-1],
                "mean": sum(vals) / len(vals),
                "max": max(vals),
            }
        for (node, name), h in sorted(self.histograms.items(),
                                      key=lambda kv: str(kv[0])):
            if not h.total:
                continue
            s = h.summary()
            out[f"n{node}.{name}"] = {
                "count": s["count"], "mean_ms": s["mean_ms"],
                "p50_ms": s["p50_ms"], "p99_ms": s["p99_ms"],
            }
        return out
