"""Elastic range management: live splits, snapshot-based replica
migration, and hotspot-driven rebalancing.

The paper's §4 key-range partitioning is static (a uniform pre-split at
cluster build time).  This module makes range movement a first-class,
availability-preserving operation on top of the existing Paxos cohorts:

- **Metadata** lives in the coordination service under ``/ranges/<rid>``:
  a ``meta`` znode holding ``(lo, hi, members)``, the existing ``epoch``
  counter and election znodes, and a cluster-wide ``/ranges/version``
  counter bumped on every table change (its data-change watch is the
  client cache-invalidation signal).  A ``migration`` znode records an
  in-flight replica move so a freshly elected leader resumes it unaided.

- **Live split** (CohortReplica.propose_split): the leader runs a SPLIT
  record through the normal replication pipeline as a barrier.  Applying
  it forks the child range locally on every replica with zero data copy
  (Store.detach_range) and registers fresh child metadata here; the child
  cohort then elects a leader of its own.  The child's epoch counter is
  seeded at the parent's epoch so child LSNs order after all forked data.

- **Replica migration** (CohortReplica.start_migration): two-phase and
  log-committed — first a MEMBER_CHANGE adds the destination (cohort
  briefly 4-wide; quorum rules generalize), the destination installs a
  snapshot + WAL catch-up via the §6 follower-recovery path, and only
  once it is in-sync does a second MEMBER_CHANGE retire the source.
  Majorities of the old and new member sets always intersect, so a
  leader kill at any point fails over correctly and the new leader picks
  the migration back up from the intent znode.

- **Hotspot rebalancing** (RangeBalancer): a periodic tick samples
  per-range served-op deltas from the leader replicas and triggers a
  split when one range runs hot, or a follower-replica move when node
  load is skewed.

Clients route through a RangeTable cache of the metadata and re-route on
WRONG_RANGE redirects or a version-watch fire (cluster.Client wires it).
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Optional, TYPE_CHECKING

from .coordination import Coordination, NodeExists, NoNode

if TYPE_CHECKING:
    from .cluster import SpinnakerCluster

RANGES_ROOT = "/ranges"
VERSION_PATH = f"{RANGES_ROOT}/version"
NEXT_RID_PATH = f"{RANGES_ROOT}/next_rid"


# ---------------------------------------------------------------------------
# Metadata schema helpers
# ---------------------------------------------------------------------------

def meta_path(rid: int) -> str:
    return f"{RANGES_ROOT}/{rid}/meta"


def migration_path(rid: int) -> str:
    return f"{RANGES_ROOT}/{rid}/migration"


def get_range_meta(zk: Coordination, rid: int
                   ) -> Optional[tuple[str, str, tuple[int, ...]]]:
    """(lo, hi, members) or None if the range is not registered."""
    try:
        lo, hi, members = zk.get(meta_path(rid))
        return lo, hi, tuple(members)
    except NoNode:
        return None


def set_range_meta(zk: Coordination, rid: int, lo: str, hi: str,
                   members: tuple[int, ...]) -> None:
    """Idempotent create-or-update + table-version bump."""
    data = (lo, hi, tuple(members))
    try:
        if zk.get(meta_path(rid)) == data:
            return  # no-op: don't bump the version for identical state
        zk.set_data(meta_path(rid), data)
    except NoNode:
        try:
            zk.create(meta_path(rid), data=data)
        except NodeExists:
            zk.set_data(meta_path(rid), data)
    bump_table_version(zk)


def unregister_range(zk: Coordination, rid: int) -> None:
    try:
        zk.delete(meta_path(rid))
    except NoNode:
        return
    bump_table_version(zk)


def bump_table_version(zk: Coordination) -> None:
    zk.fetch_and_add(VERSION_PATH, 1, initial=0)


def table_version(zk: Coordination) -> int:
    try:
        return zk.get(VERSION_PATH)
    except NoNode:
        return 0


def alloc_range_id(zk: Coordination, initial: int) -> int:
    """Fresh range id for a split child (atomic counter; `initial` is the
    number of pre-split base ranges, so child ids never collide)."""
    return zk.fetch_and_add(NEXT_RID_PATH, 1, initial=initial - 1)


def seed_child_epoch(zk: Coordination, child_rid: int,
                     parent_epoch: int) -> None:
    """Start the child's epoch counter at the parent's current epoch so the
    child leader's first epoch exceeds it: every LSN the child cohort mints
    orders after the LSNs baked into the forked cells (App. B's
    epoch-in-the-high-bits trick doing double duty)."""
    try:
        zk.create(f"{RANGES_ROOT}/{child_rid}/epoch", data=parent_epoch)
    except NodeExists:
        pass


def load_range_map(zk: Coordination
                   ) -> dict[int, tuple[str, str, tuple[int, ...]]]:
    """rid -> (lo, hi, members) for every registered range."""
    out: dict[int, tuple[str, str, tuple[int, ...]]] = {}
    for name in zk.get_children(RANGES_ROOT):
        if not name.isdigit():
            continue
        meta = get_range_meta(zk, int(name))
        if meta is not None:
            out[int(name)] = meta
    return out


# ---------------------------------------------------------------------------
# Client-side range table cache
# ---------------------------------------------------------------------------

class RangeTable:
    """Client-side cache of the range table.

    Loaded lazily from the ``/ranges/*/meta`` znodes; invalidated by a
    data-change watch on ``/ranges/version`` (armed at load time) or
    explicitly when a WRONG_RANGE redirect proves the cache stale.  Lookups
    between invalidation and the next load pay one metadata scan — the
    read/write path itself never touches coordination (§4.2).
    """

    def __init__(self, zk: Coordination):
        self.zk = zk
        self._los: list[str] = []
        self._rids: list[int] = []
        self._members: dict[int, tuple[int, ...]] = {}
        self._loaded = False
        self.loads = 0            # stats: metadata scans paid
        self.invalidations = 0

    def invalidate(self, _path: str = "") -> None:
        if self._loaded:
            self.invalidations += 1
        self._loaded = False

    def _load(self) -> None:
        rmap = load_range_map(self.zk)
        table = sorted((lo, rid) for rid, (lo, _hi, _m) in rmap.items())
        self._los = [lo for lo, _ in table]
        self._rids = [rid for _, rid in table]
        self._members = {rid: m for rid, (_lo, _hi, m) in rmap.items()}
        self._loaded = True
        self.loads += 1
        # one-shot watch: any later table change flips the cache stale
        self.zk.watch_exists(VERSION_PATH, self.invalidate)

    def lookup(self, key: str) -> Optional[int]:
        """rid owning `key`, or None when no range table is registered."""
        if not self._loaded:
            self._load()
        if not self._los:
            return None
        idx = bisect.bisect_right(self._los, key) - 1
        return self._rids[max(0, idx)]

    def members(self, rid: int) -> tuple[int, ...]:
        if not self._loaded:
            self._load()
        return self._members.get(rid, ())


# ---------------------------------------------------------------------------
# Hotspot-driven rebalancing
# ---------------------------------------------------------------------------

@dataclass
class BalancerConfig:
    period: float = 0.5            # sampling tick
    split_threshold: float = 4000.0  # ops/s on one range before splitting
    move_imbalance: float = 2.0    # max/min node load ratio before a move
    min_node_load: float = 500.0   # don't chase noise on an idle cluster
    cooldown: float = 2.0          # min time between actions
    max_ranges: int = 64           # hard cap: stop splitting past this


class RangeBalancer:
    """Control-plane singleton sampling per-range throughput from node
    stats and shedding hotspots via split/move.

    One action per tick at most, with a cooldown, so the cluster settles
    between moves instead of thrashing.  Decisions use the resource
    profiler's per-range heat (cluster-global served-op counts, so a
    leader change between ticks cannot corrupt the delta); when the
    profiler is disabled they fall back to leader-side served-op
    counters, the closest sim analogue of the per-range load stats a
    real master would scrape.
    """

    def __init__(self, cluster: "SpinnakerCluster",
                 cfg: Optional[BalancerConfig] = None):
        self.cluster = cluster
        self.cfg = cfg or BalancerConfig()
        self.sim = cluster.sim
        self._last: dict[int, int] = {}      # rid -> last sampled op count
        self._last_action_t = -1e9
        self._timer = None
        self.running = False
        self.actions: list[str] = []         # human-readable audit log

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        if self.running:
            return
        self.running = True
        self._arm()

    def stop(self) -> None:
        self.running = False
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def _arm(self) -> None:
        self._timer = self.sim.schedule(self.cfg.period, self._tick)

    # -- sampling -----------------------------------------------------------
    def _sample_loads(self) -> dict[int, float]:
        """ops/s served per range since the last tick (profiler heat, or
        leader counters when the profiler is off)."""
        prof = self.cluster.obs.profiler
        loads: dict[int, float] = {}
        for rid in list(self.cluster.ranges):
            rep = self.cluster.leader_replica(rid)
            if rep is None:
                continue
            if prof.enabled:
                total = prof.range_ops(rid)
            else:
                total = rep.writes_served + rep.reads_served
            prev = self._last.get(rid)
            self._last[rid] = total
            if prev is None:
                continue
            loads[rid] = max(0, total - prev) / self.cfg.period
        return loads

    def _heat_reading(self, rid: int) -> dict:
        """The heat snapshot that triggered a decision (for the event)."""
        return self.cluster.obs.profiler.heat_snapshot(rid)

    def _node_loads(self, loads: dict[int, float]) -> dict[int, float]:
        """Per-node hosted load: leaders carry the full range load,
        followers roughly half of it (log + apply work, no serving)."""
        out: dict[int, float] = {n: 0.0 for n, node in
                                 self.cluster.nodes.items() if node.up}
        for rid, load in loads.items():
            rep = self.cluster.leader_replica(rid)
            if rep is None:
                continue
            for m in self.cluster.members.get(rid, ()):
                if m in out:
                    out[m] += load if m == rep.node.node_id else 0.5 * load
        return out

    # -- decision -----------------------------------------------------------
    def _tick(self) -> None:
        if not self.running:
            return
        loads = self._sample_loads()
        now = self.sim.now
        if loads and now - self._last_action_t >= self.cfg.cooldown:
            if self._maybe_split(loads) or self._maybe_move(loads):
                self._last_action_t = now
        self._arm()

    def _maybe_split(self, loads: dict[int, float]) -> bool:
        if len(self.cluster.ranges) >= self.cfg.max_ranges:
            return False
        for rid, load in sorted(loads.items(), key=lambda kv: -kv[1]):
            if load < self.cfg.split_threshold:
                return False
            self.cluster.obs.events.emit(
                "balancer_split_decision", rid=rid,
                load_ops_s=round(load, 3),
                threshold=self.cfg.split_threshold,
                heat=self._heat_reading(rid))
            if self.cluster.admin_split(rid):
                self.actions.append(
                    f"t={self.sim.now:.2f}: split range {rid} "
                    f"(load {load:.0f}/s)")
                return True
        return False

    def _maybe_move(self, loads: dict[int, float]) -> bool:
        """Shed follower work: move the hottest range's most-loaded
        follower replica to the least-loaded node outside its cohort.
        (Leaders are never moved — leadership follows data via the normal
        election once a migrated replica catches up.)"""
        node_loads = self._node_loads(loads)
        if len(node_loads) < 2:
            return False
        cold = min(node_loads, key=node_loads.get)
        for rid, load in sorted(loads.items(), key=lambda kv: -kv[1]):
            if load < self.cfg.min_node_load:
                return False   # sorted: nothing hotter follows
            members = self.cluster.members.get(rid, ())
            rep = self.cluster.leader_replica(rid)
            if rep is None or cold in members or len(members) != 3:
                continue
            followers = [m for m in members
                         if m != rep.node.node_id and m in node_loads]
            if not followers:
                continue
            src = max(followers, key=node_loads.get)
            if node_loads[src] < self.cfg.min_node_load \
                    or node_loads[src] < self.cfg.move_imbalance * max(
                        node_loads[cold], 1e-9):
                continue
            self.cluster.obs.events.emit(
                "balancer_move_decision", rid=rid, src=src, dst=cold,
                load_ops_s=round(load, 3),
                src_node_load=round(node_loads[src], 3),
                dst_node_load=round(node_loads[cold], 3),
                heat=self._heat_reading(rid))
            if self.cluster.admin_move(rid, src, cold):
                self.actions.append(
                    f"t={self.sim.now:.2f}: move range {rid} replica "
                    f"n{src} -> n{cold} (node load "
                    f"{node_loads[src]:.0f} vs {node_loads[cold]:.0f})")
                return True
        return False
