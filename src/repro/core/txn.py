"""Cross-range transactions: a Paxos-backed 2PC coordinator with
per-range lock tables and log-based recovery.

The paper's §8.2 transactions are single-cohort (one Paxos round, no
locks).  This module layers classic two-phase commit over the per-range
Paxos cohorts so a transaction can span ranges, with one structural rule:
**every 2PC state transition is made durable by proposing it through the
participant's existing replication pipeline**.  Nothing about 2PC lives
outside the logs and the coordination service, so every failover inherits
exactly the state it needs:

- **PREPARE** (participant leader): validate conditionals, acquire
  per-key entries in a leader-side lock table, and log-commit a
  ``TXN_PREPARE`` record carrying the staged writes (values + versions
  assigned at prepare time, so all replicas stage identical state).  The
  YES vote is sent only once the record commits — a follower promoted
  mid-transaction replays the record and inherits both the locks and the
  staged writes from its log.

- **DECIDE** (coordinator = leader of the first participant range): on a
  full set of YES votes it log-commits a ``TXN_DECISION`` record in its
  own range's log — that commit is the transaction's commit point and
  the client is acked when it applies.  Abort decisions are *not* logged
  (presumed abort): an intent znode ``/txn/<txid>`` written before any
  prepare is the only trace, and a freshly elected leader of the
  coordinator range resolves every intent unaided — decision in the log
  ⇒ re-drive the commit; no decision ⇒ abort.

- **COMMIT/ABORT** (participant leader): log-committed ``TXN_COMMIT`` /
  ``TXN_ABORT`` records.  Applying a commit installs the staged writes
  into the store atomically (one record, one apply sweep — strong and
  timeline reads never observe a torn prefix within a range) and
  releases the locks on every replica at the same log position.

Concurrency control is **no-wait**: a write or prepare that hits a held
lock is refused immediately (``ErrorCode.LOCKED`` / a NO vote) instead of
queueing, which makes deadlock impossible by construction — the client's
jittered backoff breaks livelock symmetry.  Strong reads of a locked key
*defer* until the lock resolves (readers hold nothing, so waiting is
safe) which keeps in-doubt data invisible; timeline reads serve the last
committed state without waiting.

Log GC is the one part of the substrate that must cooperate: an
unresolved prepare (or a decision not yet acked by every participant)
pins a per-range GC floor in the WAL so the records a promoted leader
needs are never rolled away, and snapshot catch-up ships the same records
alongside SSTable data (`catchup_extras`).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, TYPE_CHECKING

from .coordination import NoNode, NodeExists
from .types import ErrorCode, LogRecord, OpType, Result, WriteOp

if TYPE_CHECKING:
    from .replica import CohortReplica

TXN_ROOT = "/txn"


def intent_path(txid: str) -> str:
    return f"{TXN_ROOT}/{txid}"


@dataclass
class PreparedTxn:
    """Participant-side prepared state, reconstructible from the log."""
    txid: str
    coord_rid: int
    record: LogRecord      # the TXN_PREPARE record (re-shipped on catch-up)
    staged: tuple          # ((key, ((colname, value, version), ...)), ...)
    committed: bool = False  # record quorum-committed (vs merely proposed)

    @property
    def keys(self) -> tuple[str, ...]:
        return tuple(k for k, _cols in self.staged)

    @property
    def versions(self) -> tuple[tuple[str, str, int], ...]:
        return tuple((k, c, v) for k, cols in self.staged for c, _val, v in cols)


@dataclass
class _Coord:
    """One in-flight coordinator instance (volatile; an instance lost to a
    crash is resolved from the intent znode + the decision log instead)."""
    txid: str
    groups: dict                      # rid -> list[WriteOp]
    reply: Optional[Callable]
    t0: float
    state: str = "preparing"          # preparing | deciding
    votes: dict = field(default_factory=dict)   # rid -> versions tuple
    trace: Any = None                 # client OpTrace riding this txn


class TxnManager:
    """Per-replica transaction state machine: participant lock table and
    prepared set, plus the coordinator role when this replica's leader
    coordinates (the leader of a transaction's first participant range).
    Wired into CohortReplica's lifecycle/apply hooks."""

    def __init__(self, rep: "CohortReplica"):
        self.rep = rep
        # participant state
        self.locks: dict[str, str] = {}            # key -> owning txid
        self.prepared: dict[str, PreparedTxn] = {}
        self.resolved: dict[str, tuple[str, int]] = {}  # txid -> (outcome, coord_rid)
        self.deciding: set[str] = set()            # TXN_COMMIT/ABORT in flight
        self.deferred: dict[str, list[tuple]] = {}  # txid -> [(key, col, reply, t0)]
        # coordinator state
        self.active: dict[str, _Coord] = {}
        self.decided: dict[str, tuple[str, tuple[int, ...]]] = {}
        self.unacked: dict[str, set[int]] = {}
        self._decision_rec: dict[str, LogRecord] = {}
        self._next_txn = 0
        self._timer = None
        # stats
        self.prepares = 0
        self.commits = 0
        self.aborts = 0
        self.votes_no = 0
        self.lock_conflicts = 0
        self.reads_deferred = 0

    @property
    def tracer(self):
        return self.rep.obs.tracer

    # ------------------------------------------------------------- lifecycle
    def reset(self) -> None:
        """Fresh replica start: all volatile state dropped; `recover`
        rebuilds the durable part from the log scan."""
        self._cancel_timer()
        self.locks.clear()
        self.prepared.clear()
        self.resolved.clear()
        self.deciding.clear()
        self.deferred.clear()
        self.active.clear()
        self.decided.clear()
        self.unacked.clear()
        self._decision_rec.clear()

    def stop(self) -> None:
        self._cancel_timer()
        self._fail_deferred()

    def _cancel_timer(self) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def recover(self, records: list[LogRecord], cmt: int,
                flushed: int) -> None:
        """Rebuild prepared/decided state from the committed log prefix
        (start()'s recovery scan).  A commit whose effects already reached
        SSTables (lsn <= flushed) only resolves bookkeeping — re-applying
        staged cells to the memtable would be redundant but harmless."""
        for rec in sorted((r for r in records if r.txn is not None
                           and r.lsn <= cmt), key=lambda r: r.lsn):
            if rec.op is OpType.TXN_PREPARE:
                txid, coord_rid, staged = rec.txn
                p = PreparedTxn(txid, coord_rid, rec, staged, committed=True)
                self.prepared[txid] = p
                for k in p.keys:
                    self.locks[k] = txid
            elif rec.op in (OpType.TXN_COMMIT, OpType.TXN_ABORT):
                self._resolve(rec, apply_staged=rec.lsn > flushed)
            elif rec.op is OpType.TXN_DECISION:
                txid, outcome, participants = rec.txn
                self.decided[txid] = (outcome, participants)
                self._decision_rec[txid] = rec
        self._set_gc_floor()

    def stage_from_record(self, rec: LogRecord) -> None:
        """Takeover rebuild: a not-yet-committed TXN record sits in the
        unresolved queue — restore the gating state it implies (locks for
        prepares, in-flight flags for decisions) before reopening."""
        if rec.op is OpType.TXN_PREPARE:
            txid, coord_rid, staged = rec.txn
            if txid not in self.prepared:
                self.prepared[txid] = PreparedTxn(txid, coord_rid, rec, staged)
            for k in self.prepared[txid].keys:
                self.locks[k] = txid
        elif rec.op in (OpType.TXN_COMMIT, OpType.TXN_ABORT):
            self.deciding.add(rec.txn[0])
        self._set_gc_floor()

    def on_leader_open(self) -> None:
        """The replica just opened for writes as leader: resume coordinator
        duties (presumed-abort orphan intents, re-drive logged decisions —
        resend duty is leader-only bookkeeping, rebuilt here from the
        surviving intents) and re-vote any in-doubt prepared txns."""
        rep = self.rep
        for name, (data, _cz) in rep.zk.get_children(TXN_ROOT).items():
            coord_rid, participants = data
            if coord_rid != rep.rid:
                continue
            if name in self.active or self._queued_decision(name):
                continue
            if name in self.decided:
                # logged decision with a live intent: some participant has
                # not acked yet — adopt the resend duty (the tick drives it)
                self.unacked.setdefault(name, set(participants))
                continue
            # intent with no logged decision: presumed abort (§ module doc)
            rep.log(f"txn {name}: presumed abort (intent without decision)")
            rep.obs.events.emit("txn_presumed_abort", txid=name,
                                rid=rep.rid, node=rep.node.node_id)
            rep._jrec("txn_decide", epoch=rep.epoch, txid=name,
                      outcome="abort", reason="presumed_abort")
            self.tracer.txn_mark(name, "abort")
            self.aborts += 1
            for rid in participants:
                self._send_decide(name, rid, commit=False)
            try:
                rep.zk.delete(intent_path(name))
            except NoNode:
                pass
        self._set_gc_floor()
        self._arm()

    def on_step_down(self) -> None:
        """Leader demoted: fail volatile coordinator instances (clients
        retry; undecided ⇒ the next leader presume-aborts the intent) and
        deferred reads.  Prepared state is NOT dropped — it is log-backed
        and this replica keeps maintaining it as a follower."""
        self._cancel_timer()
        for inst in list(self.active.values()):
            if inst.reply is not None:
                inst.reply(Result(ErrorCode.UNAVAILABLE))
        self.active.clear()
        self._fail_deferred()

    def drop_uncommitted(self) -> None:
        """Regime change truncated the unresolved queue tail: any prepare
        that was only *proposed* no longer gates anything (if it was in
        fact durable on a quorum the new regime re-delivers it).  Resend
        duty (`unacked`) belongs to whoever leads now, not to a joining
        follower — dropping it also releases this node's decision GC pins
        so follower logs keep rolling over."""
        for txid in [t for t, p in self.prepared.items() if not p.committed]:
            p = self.prepared.pop(txid)
            self._release_locks(p)
            self._flush_deferred(txid)
        self.deciding.clear()
        self.unacked.clear()
        self._set_gc_floor()

    def _fail_deferred(self) -> None:
        for waiters in list(self.deferred.values()):
            for _key, _col, reply, t0 in waiters:
                self._note_lock_wait(t0)
                reply(Result(ErrorCode.NOT_LEADER,
                             leader_hint=self.rep.leader_id))
        self.deferred.clear()

    # ---------------------------------------------------------- lock table
    def lock_owner(self, key: str) -> Optional[str]:
        return self.locks.get(key)

    def lock_conflict(self, keys, txid: Optional[str] = None) -> bool:
        return any(self.locks.get(k) not in (None, txid) for k in keys)

    def has_participant_state(self) -> bool:
        """Gate for range ops: a SPLIT barrier must not detach keys with
        staged-but-unresolved writes attached to them."""
        return bool(self.prepared)

    def defer_read(self, txid: str, key: str, colname: str,
                   reply: Callable) -> None:
        self.reads_deferred += 1
        self.deferred.setdefault(txid, []).append(
            (key, colname, reply, self.rep.node.sim.now))

    def _note_lock_wait(self, t0: float) -> None:
        """Account how long a strong read waited on an in-doubt 2PC key —
        the lock-wait dimension of the range's heat."""
        rep = self.rep
        wait = rep.node.sim.now - t0
        prof = rep.obs.profiler
        if prof.enabled:
            prof.lock_wait(rep.rid, wait)
        rep.obs.metrics.observe(rep.node.node_id, "lock_wait_s", wait)

    def _flush_deferred(self, txid: str) -> None:
        for key, colname, reply, t0 in self.deferred.pop(txid, []):
            self._note_lock_wait(t0)
            self.rep._read_one(key, colname, True, reply)

    def _release_locks(self, p: PreparedTxn) -> None:
        for k in p.keys:
            if self.locks.get(k) == p.txid:
                del self.locks[k]

    # --------------------------------------------------- participant: 2PC
    def on_txn_prepare(self, txid: str, coord_rid: int,
                       ops: list[WriteOp]) -> None:
        from .replica import Role
        rep = self.rep
        if rep.role is not Role.LEADER or not rep.open_for_writes \
                or not rep.node.has_session():
            self._vote(coord_rid, txid, ok=False, reason="not_leader")
            return
        if txid in self.prepared or txid in self.resolved:
            return  # duplicate; the commit-time vote / re-vote tick covers it
        if not all(rep._owns(op.key) for op in ops):
            self._vote(coord_rid, txid, ok=False, reason="wrong_range")
            return
        keys = {op.key for op in ops}
        if self.lock_conflict(keys):
            self.lock_conflicts += 1
            self._vote(coord_rid, txid, ok=False, reason="locked")
            return
        # validate conditionals and assign versions against the latest
        # *proposed* state (mirrors client_write §5.1 pipelining), staging
        # the final per-(key, col) cells; within the txn later ops see
        # earlier ones
        staged_cells: dict[tuple[str, str], tuple[Any, int]] = {}
        for op in ops:
            cur = staged_cells.get((op.key, op.colname), (None, None))[1]
            if cur is None:
                cur = rep.proposed_version.get((op.key, op.colname))
            if cur is None:
                cur = rep.store.current_version(op.key, op.colname)
            if op.is_conditional and op.expected_version != cur:
                self._vote(coord_rid, txid, ok=False,
                           reason="version_mismatch")
                return
            if op.op == OpType.MULTI_PUT:
                for c, v in (op.columns or ()):
                    base = staged_cells.get((op.key, c), (None, None))[1]
                    if base is None:
                        base = rep.proposed_version.get((op.key, c))
                    if base is None:
                        base = rep.store.current_version(op.key, c)
                    staged_cells[(op.key, c)] = (v, base + 1)
            elif op.op in (OpType.DELETE, OpType.COND_DELETE):
                staged_cells[(op.key, op.colname)] = (None, cur + 1)
            else:
                staged_cells[(op.key, op.colname)] = (op.value, cur + 1)
        by_key: dict[str, list[tuple[str, Any, int]]] = {}
        for (key, col), (val, ver) in staged_cells.items():
            by_key.setdefault(key, []).append((col, val, ver))
        staged = tuple((key, tuple(cols)) for key, cols in by_key.items())
        rec = rep.propose_record(OpType.TXN_PREPARE, txid,
                                 txn=(txid, coord_rid, staged))
        rep._jrec("txn_prepare", epoch=rep.epoch, lsn=rec.lsn, txid=txid,
                  coord=coord_rid)
        p = PreparedTxn(txid, coord_rid, rec, staged)
        self.prepared[txid] = p
        for k in p.keys:
            self.locks[k] = txid
        self.prepares += 1
        self._set_gc_floor()
        self._arm()

    def apply_record(self, rec: LogRecord) -> None:
        """A committed TXN record reached `_apply_committed` — runs on
        every replica at the same log position."""
        from .replica import Role
        rep = self.rep
        leaderish = rep.role in (Role.LEADER, Role.TAKEOVER)
        if rec.op is OpType.TXN_PREPARE:
            txid, coord_rid, staged = rec.txn
            p = self.prepared.get(txid)
            if p is None:
                p = PreparedTxn(txid, coord_rid, rec, staged)
                self.prepared[txid] = p
            p.committed = True
            for k in p.keys:
                self.locks[k] = txid
            rep._jrec("txn_prepared", epoch=rep.epoch, lsn=rec.lsn,
                      txid=txid)
            self._set_gc_floor()
            if leaderish and txid not in self.resolved \
                    and txid not in self.deciding:
                self._vote(coord_rid, txid, ok=True, versions=p.versions)
            self._arm()
        elif rec.op in (OpType.TXN_COMMIT, OpType.TXN_ABORT):
            self._resolve(rec, apply_staged=True)
            if leaderish:
                txid = rec.txn[0]
                self._ack_decided(txid)
        elif rec.op is OpType.TXN_DECISION:
            self._apply_decision(rec)

    def _resolve(self, rec: LogRecord, apply_staged: bool) -> None:
        """Apply a committed TXN_COMMIT/TXN_ABORT: install staged writes
        (commit) atomically, release locks, wake deferred readers."""
        txid = rec.txn[0]
        commit = rec.op is OpType.TXN_COMMIT
        self.tracer.txn_mark(txid, "resolve", self.rep.rid)
        self.rep._jrec("txn_resolve", epoch=self.rep.epoch, lsn=rec.lsn,
                       txid=txid, outcome="commit" if commit else "abort")
        self.deciding.discard(txid)
        p = self.prepared.pop(txid, None)
        if p is not None:
            self.resolved[txid] = ("commit" if commit else "abort",
                                   p.coord_rid)
            if commit:
                if apply_staged:
                    for key, cols in p.staged:
                        self.rep.store.apply(
                            LogRecord(self.rep.rid, rec.lsn, OpType.PUT, key,
                                      tuple(cols)))
                # the staged versions just advanced the store PAST any
                # `proposed_version` high-water mark left by earlier normal
                # writes; a stale lower entry would shadow the true version
                # forever (failing every later CAS, and letting
                # _bump_version mint duplicate versions).  The lock held
                # since prepare admission guarantees no newer proposal put
                # a higher entry there, so dropping is always correct.
                for key, cols in p.staged:
                    for colname, _val, _ver in cols:
                        self.rep.proposed_version.pop((key, colname), None)
            self._release_locks(p)
            if commit:
                self.commits += 1
            else:
                self.aborts += 1
        self._flush_deferred(txid)
        self._set_gc_floor()
        self._prune_done()

    def on_txn_decide(self, txid: str, coord_rid: int, commit: bool) -> None:
        from .replica import Role
        rep = self.rep
        if txid in self.resolved:
            self._ack_decided(txid)     # duplicate decide: re-ack only
            return
        if txid in self.deciding:
            return                      # resolution already proposed
        p = self.prepared.get(txid)
        if p is None:
            # never prepared here (abort raced the prepare, or long-resolved
            # state was GC'd after SSTable flush): nothing to undo — ack so
            # the coordinator can retire the intent
            self._ack_to(coord_rid, txid)
            return
        if rep.role is not Role.LEADER or not rep.open_for_writes \
                or not rep.node.has_session():
            return  # the coordinator re-sends to the actual leader
        self.deciding.add(txid)
        rep.propose_record(OpType.TXN_COMMIT if commit else OpType.TXN_ABORT,
                           txid, txn=(txid,))

    def _vote(self, coord_rid: int, txid: str, ok: bool, versions=(),
              reason: str = "") -> None:
        if not ok:
            self.votes_no += 1
        leader = self._leader_of(coord_rid)
        if leader is None:
            return      # re-vote tick (or prepare timeout) covers it
        self.rep._jrec("txn_vote", epoch=self.rep.epoch, txid=txid,
                       vote="yes" if ok else "no", reason=reason)
        self.rep.node.send(leader, coord_rid, "on_txn_vote",
                           nbytes=128 + 24 * len(versions), txid=txid,
                           prid=self.rep.rid, ok=ok,
                           versions=tuple(versions), reason=reason)

    def _ack_decided(self, txid: str) -> None:
        res = self.resolved.get(txid)
        if res is not None:
            self._ack_to(res[1], txid)

    def _ack_to(self, coord_rid: int, txid: str) -> None:
        leader = self._leader_of(coord_rid)
        if leader is None:
            return      # the coordinator's resend tick will retry us
        self.rep.node.send(leader, coord_rid, "on_txn_decided_ack",
                           nbytes=96, txid=txid, prid=self.rep.rid)

    # --------------------------------------------------- coordinator side
    def client_txn2(self, groups: dict[int, list[WriteOp]],
                    reply: Callable, trace=None) -> None:
        """Entry point for a multi-range transaction: this replica's
        leader (first participant range) coordinates."""
        from .replica import Role
        rep = self.rep
        if trace is not None:
            trace.t_cpu = rep.node.sim.now
        if rep.role is not Role.LEADER or not rep.node.has_session():
            reply(Result(ErrorCode.NOT_LEADER, leader_hint=rep.leader_id))
            return
        if not rep.open_for_writes:
            reply(Result(ErrorCode.UNAVAILABLE))
            return
        self._next_txn += 1
        txid = f"x{rep.rid}.{rep.epoch}.{self._next_txn}"
        try:
            # durable intent BEFORE any prepare can exist: recovery always
            # finds either this znode or nothing at all
            rep.zk.create(intent_path(txid),
                          data=(rep.rid, tuple(sorted(groups))))
        except NodeExists:
            reply(Result(ErrorCode.UNAVAILABLE))
            return
        inst = _Coord(txid, dict(groups), reply, rep.node.sim.now,
                      trace=trace)
        self.active[txid] = inst
        self.tracer.txn_begin(txid, rep.rid, sorted(groups))
        for rid, ops in groups.items():
            self._send_prepare(inst, rid, ops)
        self._arm()

    def _send_prepare(self, inst: _Coord, rid: int,
                      ops: list[WriteOp]) -> None:
        leader = self._leader_of(rid)
        if leader is None:
            return      # no leader right now: the prepare timeout aborts
        nbytes = 128 + sum(64 + len(op.key) for op in ops)
        self.tracer.txn_mark(inst.txid, "prepare_sent", rid)
        # batched per (coordinator, participant) node pair: prepares staged
        # in the same event (several ranges led by one node, or concurrent
        # transactions deciding together) share one wire message
        self.rep.node.send_batched(leader, rid, "on_txn_prepare",
                                   nbytes=nbytes, txid=inst.txid,
                                   coord_rid=self.rep.rid, ops=list(ops))

    def on_txn_vote(self, txid: str, prid: int, ok: bool, versions,
                    reason: str) -> None:
        from .replica import Role
        rep = self.rep
        if rep.role is not Role.LEADER or not rep.open_for_writes:
            return      # participants re-vote once a leader is open
        inst = self.active.get(txid)
        if inst is None:
            dec = self.decided.get(txid)
            if dec is not None:
                self._send_decide(txid, prid, commit=dec[0] == "commit")
            elif not self._queued_decision(txid):
                # unknown and undecided ⇒ it aborted (presumed abort)
                rep._jrec("txn_decide", epoch=rep.epoch, txid=txid,
                          outcome="abort", reason="presumed_abort")
                self._send_decide(txid, prid, commit=False)
            return
        if inst.state != "preparing":
            return
        if not ok:
            self._abort(inst, reason)
            return
        inst.votes[prid] = tuple(versions)
        self.tracer.txn_mark(txid, "vote", prid)
        if set(inst.votes) >= set(inst.groups):
            # all YES: log the decision — its commit IS the commit point
            inst.state = "deciding"
            rep._jrec("txn_decide", epoch=rep.epoch, txid=txid,
                      outcome="commit")
            # the decision record's force/commit milestones ARE the client
            # op's: the replica's batch instrumentation stamps
            # t_flush/t_forced/t_commit on the riding trace
            rep.propose_record(
                OpType.TXN_DECISION, txid,
                txn=(txid, "commit", tuple(sorted(inst.groups))),
                trace=inst.trace)

    def _apply_decision(self, rec: LogRecord) -> None:
        """A committed TXN_DECISION: registered on every replica of the
        coordinator range so any future leader can re-drive the commit."""
        from .replica import Role
        rep = self.rep
        txid, outcome, participants = rec.txn
        self.decided[txid] = (outcome, participants)
        self._decision_rec[txid] = rec
        rep._jrec("txn_decision", epoch=rep.epoch, lsn=rec.lsn, txid=txid,
                  outcome=outcome)
        self.tracer.txn_mark(txid, outcome)
        if rep.role in (Role.LEADER, Role.TAKEOVER):
            # resend duty is leader-only: followers never receive acks, so
            # tracking unacked there would never drain.  A promoted
            # follower rebuilds it from the intent znodes in
            # on_leader_open; the GC pin below is intent-scoped, so it
            # releases on followers too once the transaction completes.
            self.unacked[txid] = set(participants)
            inst = self.active.pop(txid, None)
            if inst is not None and inst.reply is not None:
                merged = tuple(v for vs in inst.votes.values() for v in vs)
                self.tracer.txn_mark(txid, "client_ack")
                inst.reply(Result(ErrorCode.OK, value=merged))
            for rid in sorted(participants):
                self._send_decide(txid, rid, commit=outcome == "commit")
        self._set_gc_floor()
        self._prune_done()
        self._arm()

    def _abort(self, inst: _Coord, reason: str) -> None:
        """Presumed abort: nothing logged — drop the intent, notify
        participants, bounce the client with a retryable/terminal code."""
        self.active.pop(inst.txid, None)
        self.aborts += 1
        self.rep._jrec("txn_decide", epoch=self.rep.epoch, txid=inst.txid,
                       outcome="abort", reason=reason)
        self.tracer.txn_mark(inst.txid, "abort")
        for rid in sorted(inst.groups):
            self._send_decide(inst.txid, rid, commit=False)
        try:
            self.rep.zk.delete(intent_path(inst.txid))
        except NoNode:
            pass
        code = {"version_mismatch": ErrorCode.VERSION_MISMATCH,
                "wrong_range": ErrorCode.WRONG_RANGE,
                "locked": ErrorCode.LOCKED}.get(reason, ErrorCode.UNAVAILABLE)
        if inst.reply is not None:
            inst.reply(Result(code))

    def _send_decide(self, txid: str, rid: int, commit: bool) -> None:
        leader = self._leader_of(rid)
        if leader is None:
            return      # resend tick retries while the intent survives
        # decides fan out to every participant the instant the decision
        # commits: participants led by the same node share one envelope
        self.rep.node.send_batched(leader, rid, "on_txn_decide", nbytes=96,
                                   txid=txid, coord_rid=self.rep.rid,
                                   commit=commit)

    def on_txn_decided_ack(self, txid: str, prid: int) -> None:
        pending = self.unacked.get(txid)
        if pending is None:
            return
        pending.discard(prid)
        if not pending:
            del self.unacked[txid]
            self._decision_rec.pop(txid, None)
            try:
                self.rep.zk.delete(intent_path(txid))
            except NoNode:
                pass
            self._set_gc_floor()

    def _queued_decision(self, txid: str) -> bool:
        return any(r.op is OpType.TXN_DECISION and r.txn[0] == txid
                   for r in self.rep.queue.values())

    def _leader_of(self, rid: int) -> Optional[int]:
        try:
            leader_id, _epoch = self.rep.zk.get(f"/ranges/{rid}/leader")
            return leader_id
        except NoNode:
            return None

    # ------------------------------------------------------- resolution tick
    def _arm(self) -> None:
        from .replica import Role
        if self._timer is None \
                and self.rep.role in (Role.LEADER, Role.TAKEOVER):
            self._timer = self.rep.node.sim.schedule(
                self.rep.cfg.txn_tick, self._tick)

    def _tick(self) -> None:
        from .replica import Role
        self._timer = None
        rep = self.rep
        if rep.role is not Role.LEADER or not rep.node.has_session():
            return      # re-armed by on_leader_open / apply hooks
        now = rep.node.sim.now
        # coordinator: time out stuck prepares, re-drive unacked decisions
        for inst in list(self.active.values()):
            if inst.state == "preparing" \
                    and now - inst.t0 > rep.cfg.txn_prepare_timeout:
                self._abort(inst, "timeout")
        for txid, pending in list(self.unacked.items()):
            dec = self.decided.get(txid)
            if dec is None:
                continue
            for rid in sorted(pending):
                self._send_decide(txid, rid, commit=dec[0] == "commit")
        # participant: re-vote in-doubt prepared txns (covers promoted
        # leaders whose original vote died with the old regime)
        if rep.open_for_writes:
            for txid, p in list(self.prepared.items()):
                if p.committed and txid not in self.deciding:
                    self._vote(p.coord_rid, txid, ok=True,
                               versions=p.versions)
        if self.active or self.unacked or self.prepared:
            self._arm()

    # --------------------------------------------------- log-GC cooperation
    _MAX_DONE = 4096   # cap on retained per-txn outcome bookkeeping

    def _set_gc_floor(self) -> None:
        """Pin the WAL GC floor at the lowest LSN 2PC recovery still needs:
        unresolved prepares, and decisions whose transaction has not
        completed (intent znode still present — the intent scopes the pin,
        so follower replicas release it too once every participant acked,
        and the sweep below keeps `_decision_rec` bounded by the number of
        in-flight transactions)."""
        zk = self.rep.zk
        for txid in [t for t in self._decision_rec
                     if t not in self.unacked
                     and not zk.exists(intent_path(t))]:
            del self._decision_rec[txid]
        lsns = [p.record.lsn for p in self.prepared.values()]
        lsns += [r.lsn for r in self._decision_rec.values()]
        floor = min(lsns) if lsns else None
        if floor != self._last_pin:
            # journal every floor *move* — the WAL's own gc_floor_pin /
            # gc_floor_release events fire only on the none<->some edges
            rep = self.rep
            if floor is None:
                rep._jrec("txn_unpin", epoch=rep.epoch)
            else:
                rep._jrec("txn_pin", epoch=rep.epoch, lsn=floor,
                          n_prepared=len(self.prepared),
                          n_decisions=len(self._decision_rec))
            self._last_pin = floor
        self.rep.node.wal.set_gc_floor(self.rep.rid, floor)

    _last_pin: Optional[int] = None

    def _prune_done(self) -> None:
        """Bound the per-transaction outcome maps.  `resolved` entries
        beyond the cap drop oldest-first (a duplicate decide for a
        forgotten txid is acked regardless); `decided` entries drop only
        once their intent is gone — while an intent lives, the outcome
        must survive for in-doubt resolution."""
        if len(self.resolved) > self._MAX_DONE:
            for txid in list(self.resolved)[:len(self.resolved)
                                            - self._MAX_DONE]:
                del self.resolved[txid]
        if len(self.decided) > self._MAX_DONE:
            excess = len(self.decided) - self._MAX_DONE
            zk = self.rep.zk
            for txid in list(self.decided):
                if excess <= 0:
                    break
                if txid in self.unacked or zk.exists(intent_path(txid)):
                    continue
                del self.decided[txid]
                self._decision_rec.pop(txid, None)
                excess -= 1

    def catchup_extras(self, upto: int) -> list[LogRecord]:
        """TXN records a snapshot-fed follower (SSTable catch-up path)
        must still receive: committed-but-unresolved prepares and
        uncompleted decisions, which carry state that data cells cannot
        (`_decision_rec` holds exactly the live-intent ones)."""
        recs = [p.record for p in self.prepared.values()
                if p.committed and p.record.lsn <= upto]
        recs += [r for r in self._decision_rec.values() if r.lsn <= upto]
        return sorted(recs, key=lambda r: r.lsn)
