"""A Spinnaker node (§4.1): shared WAL on a dedicated log device, CPU
server, 3 cohort replicas (chained declustering), ZooKeeper session with
heartbeats, and message dispatch.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, TYPE_CHECKING

from .replica import CohortReplica, ReplicaConfig, Role
from .sim import Disk, DiskParams, FifoServer
from .types import KeyRange
from .wal import WAL

if TYPE_CHECKING:
    from .cluster import SpinnakerCluster


# CPU service times, split into (per-message overhead, per-record marginal
# cost).  The overhead is the kernel/network-stack + dispatch cost paid once
# per message; the marginal term is deserialisation + protocol work per
# record carried.  Proposal batching amortises the overhead across the
# batch — that is its entire benefit, and splitting the costs keeps it
# principled instead of free.  Calibrated so single-record messages cost
# what the flat pre-batching model charged (knees match the paper's §C:
# reads are CPU+network bound, writes log-force bound; the write knee moves
# with batch size exactly as Fig. 8's saturation points suggest).
CPU_COST = {
    "client_read": (96e-6, 14e-6),      # 4KB read incl. kernel / net stack
    "client_write": (30e-6, 25e-6),
    "on_propose": (16e-6, 12e-6),
    "on_ack": (8e-6, 0.0),
    "on_commit": (8e-6, 0.0),
    "on_new_leader": (20e-6, 0.0),
    "on_follower_state": (20e-6, 0.0),
    "on_catchup_data": (24e-6, 6e-6),
    "on_catchup_synced": (20e-6, 0.0),
    "default": (10e-6, 0.0),
}


def message_cost(handler: str, kw: dict) -> float:
    """CPU service time for one message: overhead + marginal * records."""
    base, per_rec = CPU_COST.get(handler, CPU_COST["default"])
    records = kw.get("records")
    n = len(records) if isinstance(records, list) else 1
    return base + per_rec * n


@dataclass
class NodeConfig:
    replica: ReplicaConfig = field(default_factory=ReplicaConfig)
    disk: DiskParams = field(default_factory=DiskParams.hdd)
    heartbeat_interval: float = 0.5
    wal_segment_bytes: int = 1 << 22


class SpinnakerNode:
    def __init__(self, cluster: "SpinnakerCluster", node_id: int,
                 cfg: NodeConfig):
        self.cluster = cluster
        self.node_id = node_id
        self.cfg = cfg
        self.sim = cluster.sim
        self.net = cluster.net
        self.zk = cluster.zk

        self.cpu = FifoServer(self.sim, name=f"cpu{node_id}")
        self.disk = Disk(self.sim, cfg.disk, name=f"log{node_id}")
        self.wal = WAL(self.sim, self.disk, segment_bytes=cfg.wal_segment_bytes)
        self.replicas: dict[int, CohortReplica] = {}
        self.session: Optional[int] = None
        self._hb_timer = None
        self.up = False

    # -- wiring ----------------------------------------------------------------
    def add_range(self, key_range: KeyRange, peers: tuple[int, int]) -> None:
        self.replicas[key_range.range_id] = CohortReplica(
            self, key_range, peers, self.cfg.replica)

    def has_session(self) -> bool:
        return self.session is not None and self.zk.session_alive(self.session)

    # -- lifecycle ---------------------------------------------------------------
    def boot(self) -> None:
        self.up = True
        self.net.set_down(self.node_id, False)
        self.cpu.open()
        self.session = self.zk.create_session()
        try:
            self.zk.create(f"/nodes/{self.node_id}", data=self.sim.now,
                           ephemeral_session=self.session)
        except Exception:
            pass
        self._heartbeat()
        # local recovery of all 3 cohorts (shared log scan, §6), then join
        for replica in self.replicas.values():
            replica.start()

    def _heartbeat(self) -> None:
        if not self.up:
            return
        self.zk.heartbeat(self.session)
        self._hb_timer = self.sim.schedule(self.cfg.heartbeat_interval,
                                           self._heartbeat)

    def crash(self, lose_disk: bool = False, expire_session: bool = False) -> None:
        """Fail-stop: volatile state lost; durable log/SSTables survive
        unless `lose_disk`."""
        self.up = False
        self.net.set_down(self.node_id, True)
        self.cpu.close()
        self.cpu.bump_generation()
        if self._hb_timer is not None:
            self._hb_timer.cancel()
            self._hb_timer = None
        self.wal.crash()
        for replica in self.replicas.values():
            replica.stop()
            replica.store.crash_volatile()
            if lose_disk:
                replica.store.lose_disk()
        if lose_disk:
            self.wal.durable.clear()
            self.wal.durable_bytes = 0
            self.wal.skipped.clear()
            self.wal.flushed_upto.clear()
        if expire_session and self.session is not None:
            self.zk.expire_session(self.session)
        self.session = None

    def restart(self) -> None:
        self.boot()

    # -- messaging -----------------------------------------------------------------
    def send(self, dst: int, rid: int, handler: str, nbytes: int = 256,
             **kw: Any) -> None:
        dst_node = self.cluster.nodes[dst]
        self.net.send(self.node_id, dst,
                      dst_node.receive, rid, handler, kw, nbytes=nbytes)

    def receive(self, rid: int, handler: str, kw: dict) -> None:
        if not self.up:
            return
        replica = self.replicas.get(rid)
        if replica is None:
            return
        self.cpu.submit(message_cost(handler, kw),
                        lambda: getattr(replica, handler)(**kw))

    # client entry points (arrive via network; dispatched through the CPU)
    def handle_client(self, rid: int, kind: str, kw: dict) -> None:
        if not self.up:
            return
        replica = self.replicas.get(rid)
        if replica is None:
            kw["reply"](None)
            return
        base, per_rec = CPU_COST["client_read" if kind == "read"
                                 else "client_write"]
        if kind == "read":
            self.cpu.submit(base + per_rec, lambda: replica.client_read(**kw))
        elif kind == "txn":
            n = max(1, len(kw.get("ops", ())))
            self.cpu.submit(base + per_rec * n,
                            lambda: replica.client_transaction(**kw))
        else:
            self.cpu.submit(base + per_rec, lambda: replica.client_write(**kw))
