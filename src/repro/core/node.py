"""A Spinnaker node (§4.1): shared WAL on a dedicated log device, CPU
server, 3 cohort replicas (chained declustering), ZooKeeper session with
heartbeats, and message dispatch.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, TYPE_CHECKING

from . import ranges as ranges_mod
from .replica import CohortReplica, ReplicaConfig, Role
from .sim import Disk, DiskParams, FifoServer
from .storage import Store
from .types import ErrorCode, KeyRange, Result
from .wal import WAL

if TYPE_CHECKING:
    from .cluster import SpinnakerCluster


# CPU service times, split into (per-message overhead, per-record marginal
# cost).  The overhead is the kernel/network-stack + dispatch cost paid once
# per message; the marginal term is deserialisation + protocol work per
# record carried.  Proposal batching amortises the overhead across the
# batch — that is its entire benefit, and splitting the costs keeps it
# principled instead of free.  Calibrated so single-record messages cost
# what the flat pre-batching model charged (knees match the paper's §C:
# reads are CPU+network bound, writes log-force bound; the write knee moves
# with batch size exactly as Fig. 8's saturation points suggest).
CPU_COST = {
    "client_read": (96e-6, 14e-6),      # 4KB read incl. kernel / net stack
    "client_write": (30e-6, 25e-6),
    "on_propose": (16e-6, 12e-6),
    "on_ack": (8e-6, 0.0),
    "on_commit": (8e-6, 0.0),
    "on_new_leader": (20e-6, 0.0),
    "on_follower_state": (20e-6, 0.0),
    "on_catchup_data": (24e-6, 6e-6),
    "on_catchup_synced": (20e-6, 0.0),
    # 2PC traffic (core/txn.py): prepares carry per-op payload, the
    # control messages are small fixed-cost singles
    "on_txn_prepare": (20e-6, 12e-6),
    "on_txn_vote": (10e-6, 0.0),
    "on_txn_decide": (12e-6, 0.0),
    "on_txn_decided_ack": (8e-6, 0.0),
    # lease renewal + connectivity probes (small control messages)
    "on_lease": (8e-6, 0.0),
    "on_lease_ack": (8e-6, 0.0),
    "on_ping": (6e-6, 0.0),
    "on_pong": (6e-6, 0.0),
    "on_read_confirm": (8e-6, 0.0),
    "on_read_confirm_ack": (8e-6, 0.0),
    "default": (10e-6, 0.0),
}

# dispatch classes that carry client requests; everything else is protocol
# traffic (replication, 2PC, leases) that the two-class ingress drain runs
# ahead of client request processing
_CLIENT_CLASSES = ("client_read", "client_write")


def message_cost(handler: str, kw: dict) -> float:
    """CPU service time for one message: overhead + marginal * records."""
    base, per_rec = CPU_COST.get(handler, CPU_COST["default"])
    records = kw.get("records")
    if not isinstance(records, list):
        records = kw.get("ops")
    n = len(records) if isinstance(records, list) else 1
    return base + per_rec * n


# Resource-profiler component labels (obs/profile.py): every protocol
# message is attributed to the subsystem that sent it, so the profiler can
# answer "which component is burning this node's CPU/network".
COMPONENT_OF = {
    "client_read": "client.read",
    "client_write": "client.write",
    "on_propose": "paxos.propose",
    "on_ack": "paxos.ack",
    "on_commit": "paxos.commit",
    "on_new_leader": "election",
    "on_follower_state": "election",
    "on_deposed": "election",
    "on_catchup_data": "catchup",
    "on_catchup_synced": "catchup",
    "on_txn_prepare": "txn.prepare",
    "on_txn_vote": "txn.vote",
    "on_txn_decide": "txn.decide",
    "on_txn_decided_ack": "txn.ack",
    "on_lease": "lease.heartbeat",
    "on_lease_ack": "lease.heartbeat",
    "on_ping": "lease.heartbeat",
    "on_pong": "lease.heartbeat",
    "on_read_confirm": "paxos.read_confirm",
    "on_read_confirm_ack": "paxos.read_confirm",
}


def component_of(handler: str) -> str:
    return COMPONENT_OF.get(handler, "other")


@dataclass
class NodeConfig:
    replica: ReplicaConfig = field(default_factory=ReplicaConfig)
    disk: DiskParams = field(default_factory=DiskParams.hdd)
    heartbeat_interval: float = 0.5
    wal_segment_bytes: int = 1 << 22
    # -- ingress batching ---------------------------------------------------
    # While the CPU is busy, arriving messages stage in an ingress queue and
    # are served as ONE batch job when it drains: per-message overhead is
    # paid once per message class in the batch, the marginal term per record
    # (recvmmsg-style batched ingest — same amortisation the proposal
    # accumulator applies on the wire, applied at the CPU).  An idle CPU
    # dispatches immediately, so light load keeps the unbatched latency.
    ingress_batch: bool = True
    # -- admission control --------------------------------------------------
    # Client requests arriving when the CPU backlog (queued + staged work)
    # exceeds this many seconds are shed with OVERLOADED instead of queued;
    # the client backs off and retries.  Past the saturation knee this
    # converts collapse (every op queues for seconds, then times out and
    # retries, multiplying load) into flat goodput.  None = admit all.
    admission_limit: Optional[float] = None


class SpinnakerNode:
    def __init__(self, cluster: "SpinnakerCluster", node_id: int,
                 cfg: NodeConfig):
        self.cluster = cluster
        self.node_id = node_id
        self.cfg = cfg
        self.sim = cluster.sim
        self.net = cluster.net
        self.zk = cluster.zk

        self.cpu = FifoServer(self.sim, name=f"cpu{node_id}")
        self.disk = Disk(self.sim, cfg.disk, name=f"log{node_id}")
        self.wal = WAL(self.sim, self.disk, segment_bytes=cfg.wal_segment_bytes)
        def gc_event(kind, rid, lsn):
            # kind ∈ {gc_floor_pin, gc_floor_release}: surfaced in both the
            # cluster event log and the protocol journal (the watchdog's
            # gc_floor_safe invariant reads the journal side)
            cluster.obs.events.emit(kind, node=node_id, rid=rid, lsn=lsn)
            cluster.obs.journal.record(kind, node=node_id, rid=rid, lsn=lsn)
        self.wal.on_gc_event = gc_event
        self.replicas: dict[int, CohortReplica] = {}
        self.session: Optional[int] = None
        self._hb_timer = None
        self.up = False
        # ingress batching: messages staged while the CPU is busy, drained
        # as one amortised batch job (see NodeConfig.ingress_batch)
        self._ingress: list[tuple] = []   # (class, comp, base, marginal, thunk, rid)
        self._ingress_cost = 0.0          # un-amortised staged service time
        self._ingress_ev = None
        self.ingress_draining = False     # replicas defer batch flushes while set
        self.ingress_batches = 0
        self.ingress_msgs = 0
        self.admission_shed = 0
        # reply envelopes: replies minted in one event share one message
        # per client (the "one scheduled ack flush per batch" of §9)
        self._reply_buf: dict[str, list[tuple]] = {}
        # protocol envelopes (send_batched): per-destination staging
        self._proto_buf: dict[int, list[tuple]] = {}

    # -- wiring ----------------------------------------------------------------
    def add_range(self, key_range: KeyRange, peers: tuple[int, ...]) -> None:
        self.replicas[key_range.range_id] = CohortReplica(
            self, key_range, peers, self.cfg.replica)

    # -- range lifecycle (core/ranges.py) ---------------------------------------
    def fork_child_replica(self, child_range: KeyRange,
                           peers: tuple[int, ...], store: Store,
                           fork_lsn: int) -> None:
        """Local zero-copy fork while applying a SPLIT: adopt the detached
        child store, durably seed the child's log state at the fork point,
        and join the child cohort's election."""
        rid = child_range.range_id
        if rid in self.replicas:
            return   # replayed split; the child already exists here
        rep = CohortReplica(self, child_range, peers, self.cfg.replica)
        rep.store = store
        self.wal.seed_range(rid, fork_lsn)
        self.replicas[rid] = rep
        if self.up:
            rep.start()

    def retire_replica(self, rid: int) -> None:
        """Drop a replica this node no longer hosts (migration retire or
        deposed straggler): stop it, clear its candidacies, forget its log
        state, and free the store."""
        rep = self.replicas.pop(rid, None)
        if rep is None:
            return
        rep.stop()
        # the watchdog drops its per-(node, range) expectations here — a
        # later re-add starts this replica's watermarks from scratch
        self.cluster.obs.journal.record("replica_retired", node=self.node_id,
                                        rid=rid)
        for name, (data, _cz) in list(
                self.zk.get_children(f"/ranges/{rid}/candidates").items()):
            if data[0] == self.node_id:
                try:
                    self.zk.delete(f"/ranges/{rid}/candidates/{name}")
                except Exception:
                    pass
        self.wal.forget_range(rid)

    def ensure_replica(self, rid: int) -> None:
        """Host a replica for `rid` if the registered member set includes
        this node and no local replica exists yet (migration destination,
        or a split that happened while this node was down).  The blank
        store is filled by snapshot + WAL catch-up from the range leader."""
        if rid in self.replicas:
            return
        meta = ranges_mod.get_range_meta(self.zk, rid)
        if meta is None:
            return
        lo, hi, members = meta
        if self.node_id not in members:
            return
        if self._hosts_overlapping(lo, hi, rid):
            # a local parent replica still covers these keys: the SPLIT it
            # has yet to apply will fork the child locally, with its data —
            # don't preempt that with an empty snapshot-fed replica
            return
        rep = CohortReplica(self, KeyRange(rid, lo, hi),
                            tuple(m for m in members if m != self.node_id),
                            self.cfg.replica)
        self.replicas[rid] = rep
        if self.up:
            rep.start()

    def _hosts_overlapping(self, lo: str, hi: str, rid: int) -> bool:
        for other in self.replicas.values():
            if other.rid == rid:
                continue
            o_lo, o_hi = other.range.lo, other.range.hi
            if (hi == "" or o_lo < hi) and (o_hi == "" or lo < o_hi):
                return True
        return False

    def reconcile_ranges(self) -> None:
        """Boot-time alignment with coordination metadata: ranges narrowed
        or members changed while this node was down.  Narrow/retire first,
        then create missing replicas (ordering matters: a narrowed parent
        no longer shadows the child it must now host)."""
        rmap = ranges_mod.load_range_map(self.zk)
        if not rmap:
            return
        for rid, (lo, hi, members) in rmap.items():
            rep = self.replicas.get(rid)
            if rep is None:
                continue
            if self.node_id not in members:
                self.retire_replica(rid)
                continue
            rep.peers = tuple(sorted(m for m in members if m != self.node_id))
            if (lo, hi) != (rep.range.lo, rep.range.hi):
                rep.range = KeyRange(rid, lo, hi)
                rep.store.restrict(lo, hi)
        for rid in rmap:
            self.ensure_replica(rid)

    def has_session(self) -> bool:
        return self.session is not None and self.zk.session_alive(self.session)

    # -- lifecycle ---------------------------------------------------------------
    def boot(self) -> None:
        self.up = True
        self.net.set_down(self.node_id, False)
        self.cpu.open()
        self.session = self.zk.create_session()
        try:
            self.zk.create(f"/nodes/{self.node_id}", data=self.sim.now,
                           ephemeral_session=self.session)
        except Exception:
            pass
        self._heartbeat()
        # reconcile hosted replicas with the registered range table first:
        # splits/member changes may have happened while this node was down
        # (replicas created here start themselves, hence the OFFLINE check)
        self.reconcile_ranges()
        # local recovery of the surviving cohorts (shared log scan, §6)
        for replica in list(self.replicas.values()):
            if replica.role is Role.OFFLINE:
                replica.start()

    def _heartbeat(self) -> None:
        if not self.up:
            return
        if self.session is not None:
            self.zk.heartbeat(self.session)
        self._hb_timer = self.sim.schedule(self.cfg.heartbeat_interval,
                                           self._heartbeat)

    def flap_session(self, outage: float = 1.0) -> None:
        """ZK session flap (gray failure): the session expires — every
        ephemeral this node holds (its /nodes znode, leader claims,
        candidacies) vanishes — while the node itself keeps serving.
        After `outage` seconds the client library reconnects with a fresh
        session and the replicas re-join their cohorts."""
        if not self.up or self.session is None:
            return
        old = self.session
        self.session = None
        self.zk.expire_session(old)

        def reconnect():
            if not self.up or self.session is not None:
                return
            self.session = self.zk.create_session()
            try:
                self.zk.create(f"/nodes/{self.node_id}", data=self.sim.now,
                               ephemeral_session=self.session)
            except Exception:
                pass
            for rep in list(self.replicas.values()):
                rep.on_session_reestablished()

        self.sim.schedule(outage, reconnect)

    def crash(self, lose_disk: bool = False, expire_session: bool = False) -> None:
        """Fail-stop: volatile state lost; durable log/SSTables survive
        unless `lose_disk`."""
        self.up = False
        self.net.set_down(self.node_id, True)
        self.cpu.close()
        self.cpu.bump_generation()
        self._ingress.clear()
        self._ingress_cost = 0.0
        if self._ingress_ev is not None:
            self._ingress_ev.cancel()
            self._ingress_ev = None
        self._reply_buf.clear()
        self._proto_buf.clear()
        if self._hb_timer is not None:
            self._hb_timer.cancel()
            self._hb_timer = None
        self.wal.crash()
        for replica in self.replicas.values():
            replica.stop()
            replica.store.crash_volatile()
            if lose_disk:
                replica.store.lose_disk()
        if lose_disk:
            self.wal.durable.clear()
            self.wal.durable_bytes = 0
            self.wal.skipped.clear()
            self.wal.flushed_upto.clear()
            self.wal._gc_dropped_upto.clear()
        if expire_session and self.session is not None:
            self.zk.expire_session(self.session)
        self.session = None

    def restart(self) -> None:
        self.boot()

    # -- messaging -----------------------------------------------------------------
    def send(self, dst: int, rid: int, handler: str, nbytes: int = 256,
             **kw: Any) -> None:
        dst_node = self.cluster.nodes[dst]
        self.net.send(self.node_id, dst,
                      dst_node.receive, rid, handler, kw, nbytes=nbytes,
                      component=component_of(handler), rid=rid)

    def send_batched(self, dst: int, rid: int, handler: str,
                     nbytes: int = 256, **kw: Any) -> None:
        """Protocol-message envelope: messages staged for `dst` in the same
        event leave as ONE wire message (used by the 2PC coordinator so
        prepares/decides per (coordinator, participant) pair share an
        envelope).  The flush is at +0 sim-time — never delays a message."""
        buf = self._proto_buf.get(dst)
        if buf is None:
            buf = self._proto_buf[dst] = []
            self.sim.schedule(0.0, self._flush_proto, dst)
        buf.append((rid, handler, kw, nbytes))

    def _flush_proto(self, dst: int) -> None:
        batch = self._proto_buf.pop(dst, None)
        if not batch or not self.up:
            return
        if len(batch) == 1:
            rid, handler, kw, nbytes = batch[0]
            self.send(dst, rid, handler, nbytes=nbytes, **kw)
            return
        dst_node = self.cluster.nodes[dst]
        items = [(rid, handler, kw) for rid, handler, kw, _n in batch]
        self.net.send(self.node_id, dst, dst_node.receive_batch, items,
                      nbytes=sum(n for *_h, n in batch),
                      component=component_of(batch[0][1]), rid=batch[0][0])

    def receive_batch(self, items: list) -> None:
        """Unpack a protocol envelope; each message dispatches through the
        normal receive path (and the ingress batch amortises their CPU —
        the first dispatch occupies the CPU, the rest stage behind it)."""
        for rid, handler, kw in items:
            self.receive(rid, handler, kw)

    def receive(self, rid: int, handler: str, kw: dict) -> None:
        if not self.up:
            return
        replica = self.replicas.get(rid)
        if replica is None:
            return
        base, per_rec = CPU_COST.get(handler, CPU_COST["default"])
        records = kw.get("records")
        if not isinstance(records, list):
            records = kw.get("ops")
        n = len(records) if isinstance(records, list) else 1
        self._dispatch(handler, component_of(handler), base, per_rec * n,
                       lambda: getattr(replica, handler)(**kw), rid)

    # -- ingress batching (see NodeConfig.ingress_batch) -----------------------
    def _dispatch(self, klass: str, comp: str, base: float, marginal: float,
                  thunk, rid: int) -> None:
        """CPU dispatch: immediate while the CPU is idle; staged into the
        ingress queue while it is busy, to be drained as one batch job."""
        if not self.cfg.ingress_batch or (
                not self._ingress and self.cpu.queue_delay() <= 1e-12):
            self._profile_cpu(comp, base + marginal, rid)
            self.cpu.submit(base + marginal, thunk)
            return
        self._ingress.append((klass, comp, base, marginal, thunk, rid))
        self._ingress_cost += base + marginal
        if self._ingress_ev is None:
            self._ingress_ev = self.sim.schedule(
                self.cpu.queue_delay(), self._drain_ingress)

    def _drain_ingress(self) -> None:
        self._ingress_ev = None
        if not self.up:
            self._ingress.clear()
            self._ingress_cost = 0.0
            return
        if self.cpu.queue_delay() > 1e-12:
            # a completion callback submitted more work in the meantime;
            # keep staging until the CPU actually drains
            self._ingress_ev = self.sim.schedule(
                self.cpu.queue_delay(), self._drain_ingress)
            return
        batch, self._ingress = self._ingress, []
        self._ingress_cost = 0.0
        if not batch:
            return
        self.ingress_batches += 1
        self.ingress_msgs += len(batch)
        # Two-class drain: protocol messages (propose/ack/commit/2PC —
        # microsecond bookkeeping that other nodes' commit paths block on)
        # drain ahead of client request processing, the way real stores
        # run replication handling on its own stage instead of behind the
        # client pool.  Arrival order is preserved within each class.
        proto = [it for it in batch if it[0] not in _CLIENT_CLASSES]
        client = [it for it in batch if it[0] in _CLIENT_CLASSES]
        for job in (proto, client):
            if not job:
                continue
            # one batch job per class group: per-message overhead once per
            # message class, the marginal term per message — each
            # message's share is profiled so component attribution still
            # sums exactly to cpu.total_busy
            total = 0.0
            seen: set[str] = set()
            for klass, comp, base, marginal, _thunk, rid in job:
                share = marginal + (base if klass not in seen else 0.0)
                seen.add(klass)
                total += share
                self._profile_cpu(comp, share, rid)

            def run_batch(job=job):
                # handlers run back-to-back in arrival order at batch end;
                # the draining flag makes replica proposal accumulators
                # hold their flush until every staged write has been
                # admitted, so one ingress batch feeds one proposal batch
                self.ingress_draining = True
                try:
                    for _k, _c, _b, _m, thunk, _r in job:
                        thunk()
                finally:
                    self.ingress_draining = False
                for rep in self.replicas.values():
                    rep.on_ingress_drained()

            self.cpu.submit(total, run_batch)

    # -- reply envelopes --------------------------------------------------------
    def client_reply(self, client_id: str, cb, res, nbytes: int) -> None:
        """Queue a client reply; all replies minted for one client in the
        same event leave as ONE envelope (per-message wire cost paid once).
        The flush is scheduled at +0 sim-time — coalescing never delays an
        ack, it only merges acks that were already simultaneous."""
        buf = self._reply_buf.get(client_id)
        if buf is None:
            buf = self._reply_buf[client_id] = []
            self.sim.schedule(0.0, self._flush_replies, client_id)
        buf.append((cb, res, nbytes))

    def _flush_replies(self, client_id: str) -> None:
        batch = self._reply_buf.pop(client_id, None)
        if not batch or not self.up:
            return   # a node that died this instant loses its replies
        if len(batch) == 1:
            cb, res, nbytes = batch[0]
            self.net.send(self.node_id, client_id, cb, res, nbytes=nbytes,
                          cross_switch=True, component="client.reply")
            return

        def deliver(items=batch):
            for cb, res, _nb in items:
                cb(res)

        self.net.send(self.node_id, client_id, deliver,
                      nbytes=sum(nb for _cb, _res, nb in batch),
                      cross_switch=True, component="client.reply")

    def _profile_cpu(self, component: str, cost: float, rid: int) -> None:
        """Attribute one CPU dispatch to the profiler (the slow factor is
        folded in so component sums match `cpu.total_busy` exactly) and
        feed the queue-wait histogram."""
        prof = self.cluster.obs.profiler
        if not prof.enabled:
            return
        wait = self.cpu.queue_delay()
        prof.cpu_work(self.node_id, component, cost * self.cpu.slow_factor,
                      rid=rid, queue_wait_s=wait)
        self.cluster.obs.metrics.observe(self.node_id, "cpu_queue_wait_s",
                                         wait)

    # client entry points (arrive via network; dispatched through the CPU)
    def handle_client_batch(self, items: list) -> None:
        """Unpack a client request envelope: requests a client issued in
        one event to this node share one message; each unpacks into the
        normal per-request path (and the ingress batch, when busy)."""
        for rid, kind, kw in items:
            self.handle_client(rid, kind, kw)

    def handle_client(self, rid: int, kind: str, kw: dict) -> None:
        if not self.up:
            return
        # the trace context rides the request payload; popped here (the
        # replica handlers are invoked with **kw) and re-threaded to the
        # write-path handlers, which stamp CPU-done on execution
        tr = kw.pop("trace", None)
        if tr is not None:
            tr.mark_recv(self.sim.now, self.node_id)
        replica = self.replicas.get(rid)
        if replica is None:
            kw["reply"](None)
            return
        limit = self.cfg.admission_limit
        if limit is not None \
                and self.cpu.queue_delay() + self._ingress_cost > limit:
            # shed at the NIC, before any CPU is spent: the client backs
            # off and retries, so offered load stops compounding the queue
            self.admission_shed += 1
            self.cluster.obs.metrics.inc(self.node_id, "admission_shed")
            kw["reply"](Result(ErrorCode.OVERLOADED))
            return
        base, per_rec = CPU_COST["client_read" if kind in ("read", "mread")
                                 else "client_write"]
        if kind == "read":
            n, comp = 1, "client.read"
            thunk = lambda: replica.client_read(**kw)           # noqa: E731
        elif kind == "mread":
            # batched read service: one message overhead for the group
            n = max(1, len(kw.get("pairs", ())))
            comp = "client.read"
            thunk = lambda: replica.client_multi_read(**kw)     # noqa: E731
        elif kind == "txn":
            n = max(1, len(kw.get("ops", ())))
            comp = "client.txn"
            thunk = lambda: replica.client_transaction(         # noqa: E731
                kw["ops"], kw["reply"], trace=tr)
        elif kind == "txn2":
            # cross-range transaction: this leader coordinates 2PC
            n = max(1, sum(len(ops) for ops in kw.get("groups", {}).values()))
            comp = "client.txn"
            thunk = lambda: replica.client_txn2(                # noqa: E731
                kw["groups"], kw["reply"], trace=tr)
        else:
            n, comp = 1, "client.write"
            thunk = lambda: replica.client_write(               # noqa: E731
                kw["op"], kw["reply"], trace=tr)
        klass = "client_read" if kind in ("read", "mread") else "client_write"
        self._dispatch(klass, comp, base, per_rec * n, thunk, rid)
