"""A Spinnaker node (§4.1): shared WAL on a dedicated log device, CPU
server, 3 cohort replicas (chained declustering), ZooKeeper session with
heartbeats, and message dispatch.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, TYPE_CHECKING

from . import ranges as ranges_mod
from .replica import CohortReplica, ReplicaConfig, Role
from .sim import Disk, DiskParams, FifoServer
from .storage import Store
from .types import KeyRange
from .wal import WAL

if TYPE_CHECKING:
    from .cluster import SpinnakerCluster


# CPU service times, split into (per-message overhead, per-record marginal
# cost).  The overhead is the kernel/network-stack + dispatch cost paid once
# per message; the marginal term is deserialisation + protocol work per
# record carried.  Proposal batching amortises the overhead across the
# batch — that is its entire benefit, and splitting the costs keeps it
# principled instead of free.  Calibrated so single-record messages cost
# what the flat pre-batching model charged (knees match the paper's §C:
# reads are CPU+network bound, writes log-force bound; the write knee moves
# with batch size exactly as Fig. 8's saturation points suggest).
CPU_COST = {
    "client_read": (96e-6, 14e-6),      # 4KB read incl. kernel / net stack
    "client_write": (30e-6, 25e-6),
    "on_propose": (16e-6, 12e-6),
    "on_ack": (8e-6, 0.0),
    "on_commit": (8e-6, 0.0),
    "on_new_leader": (20e-6, 0.0),
    "on_follower_state": (20e-6, 0.0),
    "on_catchup_data": (24e-6, 6e-6),
    "on_catchup_synced": (20e-6, 0.0),
    # 2PC traffic (core/txn.py): prepares carry per-op payload, the
    # control messages are small fixed-cost singles
    "on_txn_prepare": (20e-6, 12e-6),
    "on_txn_vote": (10e-6, 0.0),
    "on_txn_decide": (12e-6, 0.0),
    "on_txn_decided_ack": (8e-6, 0.0),
    # lease renewal + connectivity probes (small control messages)
    "on_lease": (8e-6, 0.0),
    "on_lease_ack": (8e-6, 0.0),
    "on_ping": (6e-6, 0.0),
    "on_pong": (6e-6, 0.0),
    "on_read_confirm": (8e-6, 0.0),
    "on_read_confirm_ack": (8e-6, 0.0),
    "default": (10e-6, 0.0),
}


def message_cost(handler: str, kw: dict) -> float:
    """CPU service time for one message: overhead + marginal * records."""
    base, per_rec = CPU_COST.get(handler, CPU_COST["default"])
    records = kw.get("records")
    if not isinstance(records, list):
        records = kw.get("ops")
    n = len(records) if isinstance(records, list) else 1
    return base + per_rec * n


# Resource-profiler component labels (obs/profile.py): every protocol
# message is attributed to the subsystem that sent it, so the profiler can
# answer "which component is burning this node's CPU/network".
COMPONENT_OF = {
    "client_read": "client.read",
    "client_write": "client.write",
    "on_propose": "paxos.propose",
    "on_ack": "paxos.ack",
    "on_commit": "paxos.commit",
    "on_new_leader": "election",
    "on_follower_state": "election",
    "on_deposed": "election",
    "on_catchup_data": "catchup",
    "on_catchup_synced": "catchup",
    "on_txn_prepare": "txn.prepare",
    "on_txn_vote": "txn.vote",
    "on_txn_decide": "txn.decide",
    "on_txn_decided_ack": "txn.ack",
    "on_lease": "lease.heartbeat",
    "on_lease_ack": "lease.heartbeat",
    "on_ping": "lease.heartbeat",
    "on_pong": "lease.heartbeat",
    "on_read_confirm": "paxos.read_confirm",
    "on_read_confirm_ack": "paxos.read_confirm",
}


def component_of(handler: str) -> str:
    return COMPONENT_OF.get(handler, "other")


@dataclass
class NodeConfig:
    replica: ReplicaConfig = field(default_factory=ReplicaConfig)
    disk: DiskParams = field(default_factory=DiskParams.hdd)
    heartbeat_interval: float = 0.5
    wal_segment_bytes: int = 1 << 22


class SpinnakerNode:
    def __init__(self, cluster: "SpinnakerCluster", node_id: int,
                 cfg: NodeConfig):
        self.cluster = cluster
        self.node_id = node_id
        self.cfg = cfg
        self.sim = cluster.sim
        self.net = cluster.net
        self.zk = cluster.zk

        self.cpu = FifoServer(self.sim, name=f"cpu{node_id}")
        self.disk = Disk(self.sim, cfg.disk, name=f"log{node_id}")
        self.wal = WAL(self.sim, self.disk, segment_bytes=cfg.wal_segment_bytes)
        def gc_event(kind, rid, lsn):
            # kind ∈ {gc_floor_pin, gc_floor_release}: surfaced in both the
            # cluster event log and the protocol journal (the watchdog's
            # gc_floor_safe invariant reads the journal side)
            cluster.obs.events.emit(kind, node=node_id, rid=rid, lsn=lsn)
            cluster.obs.journal.record(kind, node=node_id, rid=rid, lsn=lsn)
        self.wal.on_gc_event = gc_event
        self.replicas: dict[int, CohortReplica] = {}
        self.session: Optional[int] = None
        self._hb_timer = None
        self.up = False

    # -- wiring ----------------------------------------------------------------
    def add_range(self, key_range: KeyRange, peers: tuple[int, ...]) -> None:
        self.replicas[key_range.range_id] = CohortReplica(
            self, key_range, peers, self.cfg.replica)

    # -- range lifecycle (core/ranges.py) ---------------------------------------
    def fork_child_replica(self, child_range: KeyRange,
                           peers: tuple[int, ...], store: Store,
                           fork_lsn: int) -> None:
        """Local zero-copy fork while applying a SPLIT: adopt the detached
        child store, durably seed the child's log state at the fork point,
        and join the child cohort's election."""
        rid = child_range.range_id
        if rid in self.replicas:
            return   # replayed split; the child already exists here
        rep = CohortReplica(self, child_range, peers, self.cfg.replica)
        rep.store = store
        self.wal.seed_range(rid, fork_lsn)
        self.replicas[rid] = rep
        if self.up:
            rep.start()

    def retire_replica(self, rid: int) -> None:
        """Drop a replica this node no longer hosts (migration retire or
        deposed straggler): stop it, clear its candidacies, forget its log
        state, and free the store."""
        rep = self.replicas.pop(rid, None)
        if rep is None:
            return
        rep.stop()
        # the watchdog drops its per-(node, range) expectations here — a
        # later re-add starts this replica's watermarks from scratch
        self.cluster.obs.journal.record("replica_retired", node=self.node_id,
                                        rid=rid)
        for name, (data, _cz) in list(
                self.zk.get_children(f"/ranges/{rid}/candidates").items()):
            if data[0] == self.node_id:
                try:
                    self.zk.delete(f"/ranges/{rid}/candidates/{name}")
                except Exception:
                    pass
        self.wal.forget_range(rid)

    def ensure_replica(self, rid: int) -> None:
        """Host a replica for `rid` if the registered member set includes
        this node and no local replica exists yet (migration destination,
        or a split that happened while this node was down).  The blank
        store is filled by snapshot + WAL catch-up from the range leader."""
        if rid in self.replicas:
            return
        meta = ranges_mod.get_range_meta(self.zk, rid)
        if meta is None:
            return
        lo, hi, members = meta
        if self.node_id not in members:
            return
        if self._hosts_overlapping(lo, hi, rid):
            # a local parent replica still covers these keys: the SPLIT it
            # has yet to apply will fork the child locally, with its data —
            # don't preempt that with an empty snapshot-fed replica
            return
        rep = CohortReplica(self, KeyRange(rid, lo, hi),
                            tuple(m for m in members if m != self.node_id),
                            self.cfg.replica)
        self.replicas[rid] = rep
        if self.up:
            rep.start()

    def _hosts_overlapping(self, lo: str, hi: str, rid: int) -> bool:
        for other in self.replicas.values():
            if other.rid == rid:
                continue
            o_lo, o_hi = other.range.lo, other.range.hi
            if (hi == "" or o_lo < hi) and (o_hi == "" or lo < o_hi):
                return True
        return False

    def reconcile_ranges(self) -> None:
        """Boot-time alignment with coordination metadata: ranges narrowed
        or members changed while this node was down.  Narrow/retire first,
        then create missing replicas (ordering matters: a narrowed parent
        no longer shadows the child it must now host)."""
        rmap = ranges_mod.load_range_map(self.zk)
        if not rmap:
            return
        for rid, (lo, hi, members) in rmap.items():
            rep = self.replicas.get(rid)
            if rep is None:
                continue
            if self.node_id not in members:
                self.retire_replica(rid)
                continue
            rep.peers = tuple(sorted(m for m in members if m != self.node_id))
            if (lo, hi) != (rep.range.lo, rep.range.hi):
                rep.range = KeyRange(rid, lo, hi)
                rep.store.restrict(lo, hi)
        for rid in rmap:
            self.ensure_replica(rid)

    def has_session(self) -> bool:
        return self.session is not None and self.zk.session_alive(self.session)

    # -- lifecycle ---------------------------------------------------------------
    def boot(self) -> None:
        self.up = True
        self.net.set_down(self.node_id, False)
        self.cpu.open()
        self.session = self.zk.create_session()
        try:
            self.zk.create(f"/nodes/{self.node_id}", data=self.sim.now,
                           ephemeral_session=self.session)
        except Exception:
            pass
        self._heartbeat()
        # reconcile hosted replicas with the registered range table first:
        # splits/member changes may have happened while this node was down
        # (replicas created here start themselves, hence the OFFLINE check)
        self.reconcile_ranges()
        # local recovery of the surviving cohorts (shared log scan, §6)
        for replica in list(self.replicas.values()):
            if replica.role is Role.OFFLINE:
                replica.start()

    def _heartbeat(self) -> None:
        if not self.up:
            return
        if self.session is not None:
            self.zk.heartbeat(self.session)
        self._hb_timer = self.sim.schedule(self.cfg.heartbeat_interval,
                                           self._heartbeat)

    def flap_session(self, outage: float = 1.0) -> None:
        """ZK session flap (gray failure): the session expires — every
        ephemeral this node holds (its /nodes znode, leader claims,
        candidacies) vanishes — while the node itself keeps serving.
        After `outage` seconds the client library reconnects with a fresh
        session and the replicas re-join their cohorts."""
        if not self.up or self.session is None:
            return
        old = self.session
        self.session = None
        self.zk.expire_session(old)

        def reconnect():
            if not self.up or self.session is not None:
                return
            self.session = self.zk.create_session()
            try:
                self.zk.create(f"/nodes/{self.node_id}", data=self.sim.now,
                               ephemeral_session=self.session)
            except Exception:
                pass
            for rep in list(self.replicas.values()):
                rep.on_session_reestablished()

        self.sim.schedule(outage, reconnect)

    def crash(self, lose_disk: bool = False, expire_session: bool = False) -> None:
        """Fail-stop: volatile state lost; durable log/SSTables survive
        unless `lose_disk`."""
        self.up = False
        self.net.set_down(self.node_id, True)
        self.cpu.close()
        self.cpu.bump_generation()
        if self._hb_timer is not None:
            self._hb_timer.cancel()
            self._hb_timer = None
        self.wal.crash()
        for replica in self.replicas.values():
            replica.stop()
            replica.store.crash_volatile()
            if lose_disk:
                replica.store.lose_disk()
        if lose_disk:
            self.wal.durable.clear()
            self.wal.durable_bytes = 0
            self.wal.skipped.clear()
            self.wal.flushed_upto.clear()
            self.wal._gc_dropped_upto.clear()
        if expire_session and self.session is not None:
            self.zk.expire_session(self.session)
        self.session = None

    def restart(self) -> None:
        self.boot()

    # -- messaging -----------------------------------------------------------------
    def send(self, dst: int, rid: int, handler: str, nbytes: int = 256,
             **kw: Any) -> None:
        dst_node = self.cluster.nodes[dst]
        self.net.send(self.node_id, dst,
                      dst_node.receive, rid, handler, kw, nbytes=nbytes,
                      component=component_of(handler), rid=rid)

    def receive(self, rid: int, handler: str, kw: dict) -> None:
        if not self.up:
            return
        replica = self.replicas.get(rid)
        if replica is None:
            return
        cost = message_cost(handler, kw)
        self._profile_cpu(component_of(handler), cost, rid)
        self.cpu.submit(cost, lambda: getattr(replica, handler)(**kw))

    def _profile_cpu(self, component: str, cost: float, rid: int) -> None:
        """Attribute one CPU dispatch to the profiler (the slow factor is
        folded in so component sums match `cpu.total_busy` exactly) and
        feed the queue-wait histogram."""
        prof = self.cluster.obs.profiler
        if not prof.enabled:
            return
        wait = self.cpu.queue_delay()
        prof.cpu_work(self.node_id, component, cost * self.cpu.slow_factor,
                      rid=rid, queue_wait_s=wait)
        self.cluster.obs.metrics.observe(self.node_id, "cpu_queue_wait_s",
                                         wait)

    # client entry points (arrive via network; dispatched through the CPU)
    def handle_client(self, rid: int, kind: str, kw: dict) -> None:
        if not self.up:
            return
        # the trace context rides the request payload; popped here (the
        # replica handlers are invoked with **kw) and re-threaded to the
        # write-path handlers, which stamp CPU-done on execution
        tr = kw.pop("trace", None)
        if tr is not None:
            tr.mark_recv(self.sim.now, self.node_id)
        replica = self.replicas.get(rid)
        if replica is None:
            kw["reply"](None)
            return
        base, per_rec = CPU_COST["client_read" if kind in ("read", "mread")
                                 else "client_write"]
        if kind == "read":
            cost, comp = base + per_rec, "client.read"
            thunk = lambda: replica.client_read(**kw)           # noqa: E731
        elif kind == "mread":
            # batched read service: one message overhead for the group
            n = max(1, len(kw.get("pairs", ())))
            cost, comp = base + per_rec * n, "client.read"
            thunk = lambda: replica.client_multi_read(**kw)     # noqa: E731
        elif kind == "txn":
            n = max(1, len(kw.get("ops", ())))
            cost, comp = base + per_rec * n, "client.txn"
            thunk = lambda: replica.client_transaction(         # noqa: E731
                kw["ops"], kw["reply"], trace=tr)
        elif kind == "txn2":
            # cross-range transaction: this leader coordinates 2PC
            n = max(1, sum(len(ops) for ops in kw.get("groups", {}).values()))
            cost, comp = base + per_rec * n, "client.txn"
            thunk = lambda: replica.client_txn2(                # noqa: E731
                kw["groups"], kw["reply"], trace=tr)
        else:
            cost, comp = base + per_rec, "client.write"
            thunk = lambda: replica.client_write(               # noqa: E731
                kw["op"], kw["reply"], trace=tr)
        self._profile_cpu(comp, cost, rid)
        self.cpu.submit(cost, thunk)
