"""ZooKeeper-model coordination service (§4.2, §7.1).

Implements the znode tree semantics Spinnaker relies on: persistent /
ephemeral / sequential znodes, one-shot watches on children and on node
deletion, sessions with heartbeat-based expiry.  The service itself is
modeled as a fault-tolerant black box (it is Paxos-replicated ZooKeeper in
the paper); it is **not** on the read/write critical path — only election
and membership traffic touch it, exactly as §4.2 prescribes.

Calls incur a small scheduled delay (ZK serves from memory over the LAN);
watch notifications are delivered asynchronously.  Sessions expire when
heartbeats stop for `session_timeout` (paper §D.1 uses 2 s), which deletes
the session's ephemerals and fires watches — this is the cluster's failure
detector.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .sim import Simulator


@dataclass
class Znode:
    name: str
    data: Any = None
    ephemeral_session: Optional[int] = None
    children: dict[str, "Znode"] = field(default_factory=dict)
    seq_counter: int = 0
    czxid: int = 0  # creation order, breaks election ties (§7.2 line 6)


class CoordinationError(Exception):
    pass


class NodeExists(CoordinationError):
    pass


class NoNode(CoordinationError):
    pass


class Coordination:
    OP_DELAY = 350e-6  # one round trip to the ensemble

    def __init__(self, sim: Simulator, session_timeout: float = 2.0):
        self.sim = sim
        self.session_timeout = session_timeout
        self.root = Znode(name="")
        self._zxid = 0
        # watches: path -> list of callbacks; one-shot (ZK semantics)
        self._child_watches: dict[str, list[Callable]] = {}
        self._exists_watches: dict[str, list[Callable]] = {}
        # sessions: id -> last heartbeat time
        self._sessions: dict[int, float] = {}
        self._session_ephemerals: dict[int, set[str]] = {}
        self._next_session = 1
        self._expiry_timers: dict[int, Any] = {}

    # -- sessions -------------------------------------------------------------
    def create_session(self) -> int:
        sid = self._next_session
        self._next_session += 1
        self._sessions[sid] = self.sim.now
        self._session_ephemerals[sid] = set()
        self._arm_expiry(sid)
        return sid

    def heartbeat(self, sid: int) -> None:
        if sid in self._sessions:
            self._sessions[sid] = self.sim.now
            self._arm_expiry(sid)

    def _arm_expiry(self, sid: int) -> None:
        t = self._expiry_timers.get(sid)
        if t is not None:
            t.cancel()
        self._expiry_timers[sid] = self.sim.schedule(
            self.session_timeout, self._check_expiry, sid)

    def _check_expiry(self, sid: int) -> None:
        last = self._sessions.get(sid)
        if last is None:
            return
        if self.sim.now - last >= self.session_timeout - 1e-9:
            self.expire_session(sid)

    def expire_session(self, sid: int) -> None:
        if sid not in self._sessions:
            return
        del self._sessions[sid]
        timer = self._expiry_timers.pop(sid, None)
        if timer is not None:
            timer.cancel()
        for path in sorted(self._session_ephemerals.pop(sid, ())):
            try:
                self.delete(path)
            except NoNode:
                pass

    def session_alive(self, sid: int) -> bool:
        return sid in self._sessions

    # -- tree ops ---------------------------------------------------------------
    def _walk(self, path: str, create_parents: bool = False) -> tuple[Znode, str]:
        parts = [p for p in path.split("/") if p]
        node = self.root
        for p in parts[:-1]:
            child = node.children.get(p)
            if child is None:
                if not create_parents:
                    raise NoNode(path)
                child = Znode(name=p)
                node.children[p] = child
            node = child
        if not parts:
            raise CoordinationError("root")
        return node, parts[-1]

    def create(self, path: str, data: Any = None, ephemeral_session: Optional[int] = None,
               sequential: bool = False) -> str:
        """Atomic create; raises NodeExists.  Returns the actual path
        (suffixed with a monotonically increasing id when sequential)."""
        parent, name = self._walk(path, create_parents=True)
        if sequential:
            name = f"{name}{parent.seq_counter:010d}"
            parent.seq_counter += 1
        if name in parent.children:
            raise NodeExists(path)
        self._zxid += 1
        parent.children[name] = Znode(name=name, data=data,
                                      ephemeral_session=ephemeral_session,
                                      czxid=self._zxid)
        if ephemeral_session is not None:
            if ephemeral_session not in self._sessions:
                raise CoordinationError("session expired")
            parent_path = path.rsplit("/", 1)[0]
            self._session_ephemerals[ephemeral_session].add(
                f"{parent_path}/{name}")
        parent_path = path.rsplit("/", 1)[0]
        self._fire_child_watches(parent_path)
        full = f"{parent_path}/{name}"
        self._fire_exists_watches(full)
        return full

    def delete(self, path: str) -> None:
        parent, name = self._walk(path)
        node = parent.children.pop(name, None)
        if node is None:
            raise NoNode(path)
        if node.ephemeral_session is not None:
            eph = self._session_ephemerals.get(node.ephemeral_session)
            if eph is not None:
                eph.discard(path)
        self._fire_child_watches(path.rsplit("/", 1)[0])
        self._fire_exists_watches(path)

    def delete_children(self, path: str) -> None:
        try:
            parent, name = self._walk(path)
        except NoNode:
            return
        node = parent.children.get(name)
        if node is None:
            return
        for child in list(node.children):
            self.delete(f"{path}/{child}")

    def get(self, path: str) -> Any:
        parent, name = self._walk(path)
        node = parent.children.get(name)
        if node is None:
            raise NoNode(path)
        return node.data

    def set_data(self, path: str, data: Any) -> None:
        parent, name = self._walk(path)
        node = parent.children.get(name)
        if node is None:
            raise NoNode(path)
        node.data = data
        self._zxid += 1
        # NodeDataChanged: ZK delivers data-change events to exists watches;
        # range-table version bumps rely on this to invalidate client caches
        self._fire_exists_watches(path)

    def exists(self, path: str) -> bool:
        try:
            parent, name = self._walk(path)
        except NoNode:
            return False
        return name in parent.children

    def get_children(self, path: str) -> dict[str, tuple[Any, int]]:
        """name -> (data, czxid); empty dict if the node doesn't exist."""
        try:
            parent, name = self._walk(path)
        except NoNode:
            return {}
        node = parent.children.get(name)
        if node is None:
            return {}
        return {n: (c.data, c.czxid) for n, c in node.children.items()}

    def fetch_and_add(self, path: str, delta: int = 1, initial: int = 0) -> int:
        """Atomic counter (epoch numbers, App. B)."""
        if not self.exists(path):
            try:
                self.create(path, data=initial)
            except NodeExists:
                pass
        val = self.get(path) + delta
        self.set_data(path, val)
        return val

    # -- watches ------------------------------------------------------------------
    def watch_children(self, path: str, cb: Callable) -> None:
        self._child_watches.setdefault(path, []).append(cb)

    def watch_exists(self, path: str, cb: Callable) -> None:
        self._exists_watches.setdefault(path, []).append(cb)

    def _fire_child_watches(self, path: str) -> None:
        cbs = self._child_watches.pop(path, [])
        for cb in cbs:
            self.sim.schedule(self.OP_DELAY, cb, path)

    def _fire_exists_watches(self, path: str) -> None:
        cbs = self._exists_watches.pop(path, [])
        for cb in cbs:
            self.sim.schedule(self.OP_DELAY, cb, path)
