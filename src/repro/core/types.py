"""Core datatypes: LSNs, log records, cells, API results.

LSNs are 64-bit integers with the *epoch* in the high bits and a sequence
number in the low bits (paper App. B: "the high order bits of the LSN are
used to store the epoch number").  LSNs double as Paxos proposal numbers;
the epoch is bumped in the coordination service on every leader takeover,
which guarantees new writes order after everything from prior regimes.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Optional

SEQ_BITS = 40
SEQ_MASK = (1 << SEQ_BITS) - 1


def make_lsn(epoch: int, seq: int) -> int:
    if seq > SEQ_MASK:
        raise ValueError("sequence number overflow")
    return (epoch << SEQ_BITS) | seq


def lsn_epoch(lsn: int) -> int:
    return lsn >> SEQ_BITS


def lsn_seq(lsn: int) -> int:
    return lsn & SEQ_MASK


def fmt_lsn(lsn: int) -> str:
    return f"{lsn_epoch(lsn)}.{lsn_seq(lsn)}"


class OpType(enum.Enum):
    PUT = "put"
    DELETE = "delete"
    COND_PUT = "cond_put"
    COND_DELETE = "cond_delete"
    # multi-column variant of put (§3: "multi-column versions of its API")
    MULTI_PUT = "multi_put"
    # range-management records (core/ranges.py): replicated through the
    # normal Paxos pipeline so every replica changes ranges at the same
    # log position.  They never touch the memtable (Store.apply ignores
    # them); CohortReplica._apply_committed intercepts them instead.
    SPLIT = "split"                  # key = split point; columns carry child rid
    MEMBER_CHANGE = "member_change"  # columns carry the new member tuple
    # cross-range 2PC records (core/txn.py): every transaction state
    # transition is made durable through the same pipeline.  PREPARE
    # stages the participant's writes + locks; COMMIT/ABORT resolve them;
    # DECISION is the coordinator's logged commit point.  Like range ops
    # they bypass the memtable and are intercepted on apply.
    TXN_PREPARE = "txn_prepare"      # key = txid; `txn` carries staged writes
    TXN_COMMIT = "txn_commit"        # key = txid
    TXN_ABORT = "txn_abort"          # key = txid
    TXN_DECISION = "txn_decision"    # key = txid; coordinator-side record

RANGE_OPS = (OpType.SPLIT, OpType.MEMBER_CHANGE)
TXN_OPS = (OpType.TXN_PREPARE, OpType.TXN_COMMIT, OpType.TXN_ABORT,
           OpType.TXN_DECISION)
# ops intercepted by the replica instead of applied to the memtable
CONTROL_OPS = RANGE_OPS + TXN_OPS


@dataclass(frozen=True)
class WriteOp:
    """A client write request (pre-LSN-assignment)."""
    op: OpType
    key: str
    colname: str = ""
    value: Any = None
    expected_version: Optional[int] = None       # for conditional ops
    columns: Optional[tuple[tuple[str, Any], ...]] = None  # for MULTI_PUT

    @property
    def is_conditional(self) -> bool:
        return self.op in (OpType.COND_PUT, OpType.COND_DELETE)


@dataclass
class LogRecord:
    """A replicated log record.  `versions` are assigned by the leader at
    propose time so every replica applies identical state.  `txn_tail`
    (§8.2 multi-op transactions) marks the LSN of the batch's last record:
    replicas apply a batch only once its tail is committed."""
    range_id: int
    lsn: int
    op: OpType
    key: str
    columns: tuple[tuple[str, Any, int], ...]  # (colname, value, version); value None => tombstone
    txn_tail: int = 0
    # 2PC payload (core/txn.py): TXN_PREPARE carries
    # (txid, coord_rid, staged) where staged = ((key, cols), ...);
    # TXN_COMMIT/TXN_ABORT carry (txid,); TXN_DECISION carries
    # (txid, outcome, participant_rids)
    txn: Any = None

    def nbytes(self) -> int:
        n = 64
        for c, v, _ in self.columns:
            n += len(c) + (len(v) if isinstance(v, (bytes, str)) else 16)
        if self.op is OpType.TXN_PREPARE and self.txn is not None:
            n += 48
            for key, cols in self.txn[2]:
                n += len(key) + sum(
                    len(c) + (len(v) if isinstance(v, (bytes, str)) else 16)
                    for c, v, _ in cols)
        elif self.txn is not None:
            n += 48
        return n


@dataclass
class CommitMarker:
    """Non-forced log record persisting a replica's last-committed LSN."""
    range_id: int
    commit_lsn: int


@dataclass(frozen=True)
class Cell:
    """A (value, version) pair stored under (key, colname)."""
    value: Any
    version: int
    lsn: int
    deleted: bool = False


class ErrorCode(enum.Enum):
    OK = "ok"
    NOT_LEADER = "not_leader"
    UNAVAILABLE = "unavailable"
    VERSION_MISMATCH = "version_mismatch"
    NOT_FOUND = "not_found"
    TIMEOUT = "timeout"
    # the key no longer belongs to the range the client addressed (it
    # moved to a child range, or the replica's range narrowed after a
    # split); the client must refresh its cached range table and re-route
    WRONG_RANGE = "wrong_range"
    # the key is locked by an in-flight cross-range transaction (no-wait
    # deadlock avoidance, core/txn.py): retryable — the lock clears as
    # soon as the owning transaction resolves
    LOCKED = "locked"
    # admission control (core/node.py): the node's CPU backlog is past its
    # configured limit and the request was shed before queuing; retryable
    # after backoff — by then the queue has drained or the client's load
    # has spread to other cohorts
    OVERLOADED = "overloaded"


@dataclass
class Result:
    code: ErrorCode
    value: Any = None
    version: Optional[int] = None
    leader_hint: Optional[int] = None
    latency: float = 0.0
    # attempts the client spent on this op (retries + 1); a write with
    # attempts > 1 may have committed more than once (a retry after a lost
    # ack re-executes), which the linearizability auditor accounts for
    attempts: int = 1

    @property
    def ok(self) -> bool:
        return self.code == ErrorCode.OK


@dataclass(frozen=True)
class KeyRange:
    """[lo, hi) over the key space; range_id indexes the cohort."""
    range_id: int
    lo: str
    hi: str          # exclusive; "" means +inf (wraparound tail range)

    def contains(self, key: str) -> bool:
        if self.hi == "":
            return key >= self.lo
        return self.lo <= key < self.hi
