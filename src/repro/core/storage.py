"""Memtables and SSTables (§4.1), per-cohort storage engine.

Committed writes land in a sorted in-memory *memtable*; when it exceeds a
threshold it is flushed to an immutable *SSTable* tagged with the min/max
LSN of the writes it contains (§6.1: catch-up falls back to SSTables when
the log has rolled over).  Background size-tiered compaction merges small
SSTables.  Reads consult the memtable, then SSTables newest-first.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterator, Optional

from .types import Cell, CONTROL_OPS, LogRecord, OpType


def _in_range(key: str, lo: str, hi: str) -> bool:
    """[lo, hi) membership; hi == "" means +inf (tail range)."""
    return key >= lo and (hi == "" or key < hi)


def _cell_bytes(colname: str, cell: Cell) -> int:
    return 48 + len(colname) + (
        len(cell.value) if isinstance(cell.value, (bytes, str)) else 16)


class Memtable:
    def __init__(self):
        self.rows: dict[str, dict[str, Cell]] = {}
        self.bytes = 0
        self.min_lsn: Optional[int] = None
        self.max_lsn: int = 0

    def apply(self, rec: LogRecord) -> None:
        """Apply a committed record.  Idempotent: re-applying the same LSN
        leaves identical state (local recovery replays ranges of the log)."""
        row = self.rows.setdefault(rec.key, {})
        for colname, value, version in rec.columns:
            old = row.get(colname)
            if old is not None and old.lsn >= rec.lsn:
                continue  # replay of an already-applied record
            deleted = rec.op in (OpType.DELETE, OpType.COND_DELETE) or value is None
            row[colname] = Cell(value=None if deleted else value,
                                version=version, lsn=rec.lsn, deleted=deleted)
            self.bytes += 48 + len(colname) + (
                len(value) if isinstance(value, (bytes, str)) else 16)
        if self.min_lsn is None:
            self.min_lsn = rec.lsn
        self.max_lsn = max(self.max_lsn, rec.lsn)

    def get(self, key: str, colname: str) -> Optional[Cell]:
        row = self.rows.get(key)
        return row.get(colname) if row else None

    def items(self) -> Iterator[tuple[str, str, Cell]]:
        for key in sorted(self.rows):
            for colname in sorted(self.rows[key]):
                yield key, colname, self.rows[key][colname]


@dataclass
class SSTable:
    """Immutable sorted run, indexed by (key, colname); LSN-tagged (§6.1)."""
    cells: dict[tuple[str, str], Cell]
    min_lsn: int
    max_lsn: int

    def get(self, key: str, colname: str) -> Optional[Cell]:
        return self.cells.get((key, colname))

    @property
    def nbytes(self) -> int:
        return 48 * len(self.cells)


class Store:
    """Per-(node, range) storage engine: one memtable + SSTable stack.

    The memtable is volatile (rebuilt by local recovery); SSTables and the
    flushed-LSN watermark are durable.
    """

    def __init__(self, flush_threshold_bytes: int = 4 << 20,
                 compact_fanin: int = 4):
        self.memtable = Memtable()
        self.sstables: list[SSTable] = []   # oldest first
        self.flush_threshold = flush_threshold_bytes
        self.compact_fanin = compact_fanin
        self.flushed_upto = 0               # durable watermark
        self.flushes = 0
        self.compactions = 0

    # -- write path -----------------------------------------------------------
    def apply(self, rec: LogRecord) -> None:
        if rec.op in CONTROL_OPS:
            return  # range/txn control records carry no direct row data
        self.memtable.apply(rec)

    def maybe_flush(self, committed_lsn: int) -> Optional[int]:
        """Flush the memtable if over threshold.  Returns the new flushed
        watermark (callers feed it to WAL.note_flushed for log GC)."""
        if self.memtable.bytes < self.flush_threshold or self.memtable.min_lsn is None:
            return None
        return self.flush(committed_lsn)

    def flush(self, committed_lsn: int) -> int:
        mt = self.memtable
        if mt.min_lsn is None:
            return self.flushed_upto
        cells = {(k, c): cell for k, c, cell in mt.items()}
        self.sstables.append(SSTable(cells=cells, min_lsn=mt.min_lsn,
                                     max_lsn=mt.max_lsn))
        self.flushed_upto = max(self.flushed_upto, committed_lsn)
        self.memtable = Memtable()
        self.flushes += 1
        self._maybe_compact()
        return self.flushed_upto

    def _maybe_compact(self) -> None:
        """Size-tiered: merge the `fanin` *oldest* runs when they pile up.

        The victims are the oldest runs and the merged run becomes the new
        bottom of the stack, so dropping its tombstones cannot resurrect
        anything: every surviving cell above has a higher LSN (SSTable LSN
        ranges are disjoint and flush-ordered) and still wins reads.  The
        GC is visible to `cells_with_lsn_above` — peers catching up from
        SSTables after the log rolled over miss the delete, the same
        gc-grace caveat real LSM stores carry (§6.1)."""
        if len(self.sstables) < self.compact_fanin * 2:
            return
        merged: dict[tuple[str, str], Cell] = {}
        victims = self.sstables[:self.compact_fanin]
        for t in victims:  # oldest→newest so newer cells overwrite
            merged.update(t.cells)
        merged = {k: v for k, v in merged.items() if not v.deleted}
        self.sstables = [SSTable(
            cells=merged,
            min_lsn=min(t.min_lsn for t in victims),
            max_lsn=max(t.max_lsn for t in victims))] + self.sstables[self.compact_fanin:]
        self.compactions += 1

    # -- read path ------------------------------------------------------------
    def get(self, key: str, colname: str) -> Optional[Cell]:
        """Newest cell for (key, colname), or None if never written.

        CONTRACT: deletes are returned as tombstone cells
        (`cell.deleted == True`, `cell.value is None`) rather than None.
        Callers that present reads to clients must check `.deleted` and
        report NOT_FOUND; callers doing version arithmetic (conditional
        puts) must keep using the tombstone's `version` so versions stay
        monotone across a delete.  Only after a whole-stack compaction
        garbage-collects the tombstone does `get` return None (and
        `current_version` restarts at 0)."""
        best = self.memtable.get(key, colname)
        for t in reversed(self.sstables):
            c = t.get(key, colname)
            if c is not None and (best is None or c.lsn > best.lsn):
                best = c
        return best

    def current_version(self, key: str, colname: str) -> int:
        cell = self.get(key, colname)
        if cell is None:
            return 0
        return cell.version

    # -- catch-up source (SSTable path, §6.1) ----------------------------------
    def cells_with_lsn_above(self, lo_excl: int) -> list[tuple[str, str, Cell]]:
        out: dict[tuple[str, str], Cell] = {}
        for t in self.sstables:
            for (k, c), cell in t.cells.items():
                if cell.lsn > lo_excl:
                    prev = out.get((k, c))
                    if prev is None or cell.lsn > prev.lsn:
                        out[(k, c)] = cell
        for k, c, cell in self.memtable.items():
            if cell.lsn > lo_excl:
                prev = out.get((k, c))
                if prev is None or cell.lsn > prev.lsn:
                    out[(k, c)] = cell
        return [(k, c, cell) for (k, c), cell in sorted(out.items())]

    # -- range lifecycle (live splits / migration, core/ranges.py) -------------
    def iter_range(self, lo: str, hi: str) -> Iterator[tuple[str, str, Cell]]:
        """Newest-wins cells with key in [lo, hi), sorted by (key, colname).
        Tombstones are included (a migrating replica must learn deletes)."""
        out: dict[tuple[str, str], Cell] = {}
        for t in self.sstables:
            for (k, c), cell in t.cells.items():
                if _in_range(k, lo, hi):
                    prev = out.get((k, c))
                    if prev is None or cell.lsn > prev.lsn:
                        out[(k, c)] = cell
        for k, c, cell in self.memtable.items():
            if _in_range(k, lo, hi):
                prev = out.get((k, c))
                if prev is None or cell.lsn > prev.lsn:
                    out[(k, c)] = cell
        for (k, c), cell in sorted(out.items()):
            yield k, c, cell

    def keys_in_range(self, lo: str, hi: str) -> list[str]:
        keys: set[str] = set()
        for t in self.sstables:
            keys.update(k for (k, _c) in t.cells if _in_range(k, lo, hi))
        keys.update(k for k in self.memtable.rows if _in_range(k, lo, hi))
        return sorted(keys)

    def median_key(self, lo: str, hi: str) -> Optional[str]:
        """Median stored key strictly above `lo` — the default split point.
        None when the range has fewer than 2 distinct keys (unsplittable)."""
        keys = self.keys_in_range(lo, hi)
        if len(keys) < 2:
            return None
        return keys[len(keys) // 2]   # index >= 1, so strictly above lo

    def detach_range(self, lo: str, hi: str, fork_lsn: int = 0) -> "Store":
        """Fork [lo, hi) out into a new child Store with zero data copy:
        SSTable cells move by reference into one LSN-tagged child run, and
        the child's durable watermark covers everything forked (the fork
        rides the durable SPLIT record that triggered it, so a restarted
        child recovers via snapshot catch-up, not from its empty log)."""
        moved: dict[tuple[str, str], Cell] = {}
        for t in self.sstables:
            take = {(k, c): cell for (k, c), cell in t.cells.items()
                    if _in_range(k, lo, hi)}
            if take:
                for kc in take:
                    del t.cells[kc]
                for kc, cell in take.items():
                    prev = moved.get(kc)
                    if prev is None or cell.lsn > prev.lsn:
                        moved[kc] = cell
        mt = self.memtable
        for key in [k for k in mt.rows if _in_range(k, lo, hi)]:
            for colname, cell in mt.rows.pop(key).items():
                prev = moved.get((key, colname))
                if prev is None or cell.lsn > prev.lsn:
                    moved[(key, colname)] = cell
        # recompute parent memtable byte accounting after the eviction
        mt.bytes = sum(_cell_bytes(c, cell)
                       for row in mt.rows.values()
                       for c, cell in row.items())
        child = Store(flush_threshold_bytes=self.flush_threshold,
                      compact_fanin=self.compact_fanin)
        if moved:
            lsns = [cell.lsn for cell in moved.values()]
            child.sstables = [SSTable(cells=moved, min_lsn=min(lsns),
                                      max_lsn=max(lsns))]
        child.flushed_upto = max(fork_lsn,
                                 max((c.lsn for c in moved.values()),
                                     default=0))
        return child

    def restrict(self, lo: str, hi: str) -> None:
        """Drop every cell outside [lo, hi) — boot-time reconciliation when
        coordination metadata says this replica's range narrowed while the
        node was down (the data lives in the child cohort now)."""
        for t in self.sstables:
            for kc in [kc for kc in t.cells if not _in_range(kc[0], lo, hi)]:
                del t.cells[kc]
        self.sstables = [t for t in self.sstables if t.cells]
        mt = self.memtable
        for key in [k for k in mt.rows if not _in_range(k, lo, hi)]:
            del mt.rows[key]
        mt.bytes = sum(_cell_bytes(c, cell)
                       for row in mt.rows.values()
                       for c, cell in row.items())

    # -- crash ------------------------------------------------------------------
    def crash_volatile(self) -> None:
        self.memtable = Memtable()

    def lose_disk(self) -> None:
        """Disk failure: SSTables and watermark gone (§6.1 'lost all its
        data because of a disk failure ... moves directly to catch up')."""
        self.memtable = Memtable()
        self.sstables = []
        self.flushed_upto = 0
