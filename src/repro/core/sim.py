"""Deterministic discrete-event simulator.

Every component of the Spinnaker reproduction (nodes, disks, network,
coordination service, clients) runs on this simulator so that arbitrary
failure schedules are reproducible bit-for-bit from a seed.  Time is in
seconds (float).  Events with equal timestamps are ordered by insertion
sequence, which makes runs deterministic regardless of heap tie-breaking.
"""

from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


class Event:
    """A cancellable scheduled callback."""

    __slots__ = ("time", "seq", "fn", "args", "cancelled")

    def __init__(self, time: float, seq: int, fn: Callable, args: tuple):
        self.time = time
        self.seq = seq
        self.fn = fn
        self.args = args
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True

    def __lt__(self, other: "Event") -> bool:
        return (self.time, self.seq) < (other.time, other.seq)


class Simulator:
    """Event loop with a virtual clock."""

    def __init__(self, seed: int = 0):
        self.now: float = 0.0
        self._heap: list[Event] = []
        self._seq = itertools.count()
        self.rng = random.Random(seed)
        self.events_processed = 0

    # -- scheduling ---------------------------------------------------------
    def schedule(self, delay: float, fn: Callable, *args: Any) -> Event:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        ev = Event(self.now + delay, next(self._seq), fn, args)
        heapq.heappush(self._heap, ev)
        return ev

    def at(self, time: float, fn: Callable, *args: Any) -> Event:
        return self.schedule(max(0.0, time - self.now), fn, *args)

    # -- execution ----------------------------------------------------------
    def step(self) -> bool:
        """Run one event.  Returns False when the queue is exhausted."""
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            if ev.time < self.now - 1e-12:
                raise RuntimeError("event scheduled in the past")
            self.now = max(self.now, ev.time)
            self.events_processed += 1
            ev.fn(*ev.args)
            return True
        return False

    def run(self, until: Optional[float] = None, max_events: int = 50_000_000) -> None:
        """Run events until the queue empties or the clock passes `until`."""
        n = 0
        while self._heap:
            ev = self._heap[0]
            if ev.cancelled:
                heapq.heappop(self._heap)
                continue
            if until is not None and ev.time > until:
                self.now = until
                return
            if not self.step():
                return
            n += 1
            if n > max_events:
                raise RuntimeError(f"simulation exceeded {max_events} events")
        if until is not None:
            self.now = max(self.now, until)

    def run_until_idle(self, max_events: int = 50_000_000) -> None:
        self.run(until=None, max_events=max_events)

    def run_for(self, dt: float) -> None:
        """Advance the clock by dt (periodic timers keep the queue non-empty
        forever, so bounded runs are the normal driving mode)."""
        self.run(until=self.now + dt)

    # -- randomness helpers ---------------------------------------------------
    def jitter(self, mean: float, cv: float = 0.25) -> float:
        """Log-normal-ish positive jittered latency with coefficient of variation cv."""
        if mean <= 0:
            return 0.0
        lo = mean * max(0.05, 1.0 - 2.0 * cv)
        x = self.rng.gauss(mean, mean * cv)
        return max(lo, x)


# ---------------------------------------------------------------------------
# Resources
# ---------------------------------------------------------------------------


class FifoServer:
    """A single-server FIFO queue (models per-node CPU or a disk head).

    `submit(service_time, cb)` enqueues a job; `cb` fires when the job
    completes.  Utilisation and queue statistics are tracked so benchmarks
    can report saturation points.
    """

    def __init__(self, sim: Simulator, name: str = "srv"):
        self.sim = sim
        self.name = name
        self.busy_until: float = 0.0
        self.queue_len = 0
        self.total_busy = 0.0
        self.jobs = 0
        self._open = True
        self.slow_factor = 1.0  # gray-failure degradation multiplier

    def reset(self) -> None:
        """Drop queued work (e.g. on node crash)."""
        self.busy_until = self.sim.now
        self.queue_len = 0

    def close(self) -> None:
        self._open = False
        self.reset()

    def open(self) -> None:
        self._open = True
        self.busy_until = self.sim.now

    def submit(self, service_time: float, cb: Optional[Callable] = None,
               *args: Any) -> float:
        """Enqueue a job; returns its completion time."""
        if not self._open:
            return float("inf")
        service_time *= self.slow_factor
        start = max(self.sim.now, self.busy_until)
        done = start + service_time
        self.busy_until = done
        self.total_busy += service_time
        self.jobs += 1
        if cb is not None:
            gen = self._gen  # crash-generation guard
            def fire():
                if self._open and self._gen == gen:
                    cb(*args)
            self.sim.schedule(done - self.sim.now, fire)
        return done

    _gen = 0

    def bump_generation(self) -> None:
        self._gen += 1

    def queue_delay(self) -> float:
        """Seconds of already-accepted work ahead of a job submitted now —
        the queue-depth gauge the metrics registry scrapes (the header
        `queue_len` counter is not maintained by `submit`)."""
        return max(0.0, self.busy_until - self.sim.now)


@dataclass
class NetParams:
    base_latency: float = 200e-6      # one-way cold-path cost, 1 GbE rack:
    #                                   propagation + switch + the full
    #                                   per-message OS/NIC stack traversal
    bandwidth: float = 117e6          # bytes/sec usable on 1 Gbit
    jitter_cv: float = 0.20
    cross_switch_extra: float = 120e-6  # second-level switch hop
    # Message-coalescing path: consecutive messages on an active (src, dst)
    # connection are framed onto the already-hot pipeline (socket open, NIC
    # ring warm, interrupts coalesced), so they pay only the propagation
    # floor + serialization instead of the full per-message stack overhead.
    # This is the "per-message cost once, per-record cost n times" behavior
    # measured for batched Paxos messaging ("The Performance of Paxos in
    # the Cloud"): per-message overhead, not the protocol, dominates.  A
    # connection goes cold after `stream_idle` of send silence.
    stream_floor: float = 40e-6       # propagation + switch + warm NIC
    stream_idle: float = 50e-3        # send gap after which the pipeline
    #                                   drains and full overhead returns
    #                                   (order of a TCP RTO / slow-start-
    #                                   after-idle, not a NIC timescale)


class Network:
    """Point-to-point reliable in-order messaging (TCP model, §A.1).

    Per (src, dst) pair delivery is FIFO: a later send never arrives before
    an earlier one.  Messages to/from a down endpoint are dropped, like a
    broken TCP connection.
    """

    def __init__(self, sim: Simulator, params: NetParams | None = None):
        self.sim = sim
        self.p = params or NetParams()
        self._last_delivery: dict[tuple[Any, Any], float] = {}
        # last successful send per (src, dst): the message-coalescing path
        # charges only `stream_floor` while the connection stays warm
        self._last_send: dict[tuple[Any, Any], float] = {}
        self._down: set[Any] = set()
        self._group: dict[Any, int] = {}   # partition membership
        # one-way partitions: messages src∈A -> dst∈B are blocked, B -> A flow
        self._oneway: list[tuple[frozenset, frozenset]] = []
        # per-link gray faults: (src, dst) -> (drop_p, dup_p, delay_factor)
        self._link_faults: dict[tuple[Any, Any], tuple[float, float, float]] = {}
        self.bytes_sent = 0
        self.msgs_sent = 0
        self.msgs_warm = 0      # sends that rode the coalescing path
        self.dropped = 0
        # resource profiler attribution (obs/profile.py); accounting only
        self.profiler = None

    def set_down(self, endpoint: Any, down: bool = True) -> None:
        if down:
            self._down.add(endpoint)
            # connections to/from a dead endpoint reset: reconnection pays
            # the cold per-message cost again
            self._last_send = {k: t for k, t in self._last_send.items()
                               if endpoint not in k}
        else:
            self._down.discard(endpoint)

    def is_down(self, endpoint: Any) -> bool:
        return endpoint in self._down

    # -- partitions -----------------------------------------------------------
    def set_partition(self, groups) -> None:
        """Partition the network into `groups` of endpoints.

        Messages between endpoints in *different* groups are dropped (both
        at send and delivery time, so in-flight traffic is cut too).
        Endpoints in no group — clients, the coordination service — keep
        full connectivity, mirroring the paper's deployment where ZooKeeper
        sits outside the data path."""
        self._group = {}
        for gi, members in enumerate(groups):
            for e in members:
                self._group[e] = gi

    def clear_partition(self) -> None:
        self._group = {}

    def set_oneway_partition(self, src_group, dst_group) -> None:
        """Block messages from `src_group` to `dst_group` only — the reverse
        direction keeps flowing (asymmetric / gray partition).  Cumulative:
        each call adds one directed cut."""
        self._oneway.append((frozenset(src_group), frozenset(dst_group)))

    def clear_oneway_partitions(self) -> None:
        self._oneway = []

    # -- per-link gray faults -------------------------------------------------
    def set_link_fault(self, src: Any, dst: Any, drop_p: float = 0.0,
                       dup_p: float = 0.0, delay_factor: float = 1.0) -> None:
        """Degrade the directed link src -> dst: drop each message with
        probability `drop_p`, duplicate it with probability `dup_p`, and
        multiply its latency by `delay_factor`."""
        self._link_faults[(src, dst)] = (drop_p, dup_p, delay_factor)

    def update_link_fault(self, src: Any, dst: Any,
                          drop_p: Optional[float] = None,
                          dup_p: Optional[float] = None,
                          delay_factor: Optional[float] = None) -> None:
        """Merge into an existing link fault: only the given aspects change,
        so `drop` + `slow link` directives on the same link compose."""
        cur = self._link_faults.get((src, dst), (0.0, 0.0, 1.0))
        self._link_faults[(src, dst)] = (
            cur[0] if drop_p is None else drop_p,
            cur[1] if dup_p is None else dup_p,
            cur[2] if delay_factor is None else delay_factor)

    def clear_link_fault(self, src: Any, dst: Any) -> None:
        self._link_faults.pop((src, dst), None)

    def clear_link_faults(self) -> None:
        self._link_faults = {}

    def clear_faults(self) -> None:
        """Heal everything: symmetric + one-way partitions and link faults."""
        self.clear_partition()
        self.clear_oneway_partitions()
        self.clear_link_faults()

    def partitioned(self, src: Any, dst: Any) -> bool:
        gs, gd = self._group.get(src), self._group.get(dst)
        if gs is not None and gd is not None and gs != gd:
            return True
        for sg, dg in self._oneway:
            if src in sg and dst in dg:
                return True
        return False

    def _blocked(self, src: Any, dst: Any) -> bool:
        return src in self._down or dst in self._down \
            or self.partitioned(src, dst)

    def send(self, src: Any, dst: Any, handler: Callable, *args: Any,
             nbytes: int = 256, cross_switch: bool = False,
             component: Optional[str] = None, rid: Any = None) -> None:
        if self._blocked(src, dst):
            self.dropped += 1
            return  # dropped
        fault = self._link_faults.get((src, dst))
        copies = 1
        delay_factor = 1.0
        if fault is not None:
            drop_p, dup_p, delay_factor = fault
            if drop_p and self.sim.rng.random() < drop_p:
                self.dropped += 1
                return  # silently eaten by the flaky link
            if dup_p and self.sim.rng.random() < dup_p:
                copies = 2
        prof = self.profiler
        # message-coalescing path: a send while the (src, dst) connection is
        # warm is framed onto the in-flight pipeline and pays the propagation
        # floor; the first send after an idle gap pays the full per-message
        # stack overhead (FIFO delivery clamp below keeps ordering intact)
        link = (src, dst)
        last = self._last_send.get(link)
        warm = last is not None \
            and self.sim.now - last <= self.p.stream_idle
        self._last_send[link] = self.sim.now
        overhead = self.p.stream_floor if warm else self.p.base_latency
        if warm:
            self.msgs_warm += 1
        for _ in range(copies):
            lat = self.sim.jitter(overhead, self.p.jitter_cv)
            lat += nbytes / self.p.bandwidth
            if cross_switch:
                lat += self.p.cross_switch_extra
            lat *= delay_factor
            key = (src, dst)
            deliver_at = max(self.sim.now + lat,
                             self._last_delivery.get(key, 0.0) + 1e-9)
            self._last_delivery[key] = deliver_at
            self.bytes_sent += nbytes
            self.msgs_sent += 1
            if prof is not None and prof.enabled:
                prof.net_msg(src, component or "other", nbytes, rid)

            def deliver():
                # recheck liveness and partition membership at delivery time
                if self._blocked(src, dst):
                    self.dropped += 1
                    return
                handler(*args)

            self.sim.at(deliver_at, deliver)


@dataclass
class DiskParams:
    """Log-device model.  Defaults are the paper's SATA HDD logging disk."""
    force_latency: float = 4.0e-3      # rotational + metadata seek, §C
    force_cv: float = 0.35
    bandwidth: float = 80e6            # sequential bytes/sec
    kind: str = "hdd"

    @staticmethod
    def hdd() -> "DiskParams":
        return DiskParams()

    @staticmethod
    def ssd() -> "DiskParams":
        # FusionIO ioXtreme-class device (App. D.4)
        return DiskParams(force_latency=90e-6, force_cv=0.25, bandwidth=500e6,
                          kind="ssd")

    @staticmethod
    def memory() -> "DiskParams":
        # main-memory "log" (App. D.6.2): a force is just a memcpy
        return DiskParams(force_latency=4e-6, force_cv=0.10, bandwidth=8e9,
                          kind="mem")


class Disk:
    """Serial log device with FIFO forcing; used by the WAL's group commit."""

    def __init__(self, sim: Simulator, params: DiskParams | None = None,
                 name: str = "disk"):
        self.sim = sim
        self.p = params or DiskParams()
        self.name = name
        self.busy = False
        # (nbytes, cb, component, rid)
        self._waiters: list[tuple[int, Callable, Optional[str], Any]] = []
        self.forces = 0
        self.bytes_forced = 0
        self.total_busy = 0.0
        self._gen = 0
        self.slow_factor = 1.0  # gray-failure degradation multiplier
        # resource profiler attribution (obs/profile.py); accounting only
        self.profiler = None
        self.profiler_node = None

    def crash(self) -> None:
        """Drop in-flight IO (node crash).  Durable state is kept by the WAL."""
        self._gen += 1
        self._waiters.clear()
        self.busy = False

    def queue_depth(self) -> int:
        """Force requests queued or in flight (metrics gauge)."""
        return len(self._waiters) + (1 if self.busy else 0)

    def force(self, nbytes: int, cb: Callable,
              component: Optional[str] = None, rid: Any = None) -> None:
        """Request a durable write of `nbytes`; `cb()` fires on completion.

        Requests arriving while the head is busy are coalesced into one
        batch force when the head frees up — this IS group commit [13].
        """
        self._waiters.append((nbytes, cb, component, rid))
        if not self.busy:
            self._start_batch()

    def _start_batch(self) -> None:
        if not self._waiters:
            return
        batch = self._waiters
        self._waiters = []
        self.busy = True
        total = sum(b[0] for b in batch)
        lat = self.sim.jitter(self.p.force_latency, self.p.force_cv)
        lat += total / self.p.bandwidth
        lat *= self.slow_factor
        gen = self._gen
        self.forces += 1
        self.bytes_forced += total
        self.total_busy += lat
        prof = self.profiler
        if prof is not None and prof.enabled:
            # attribute the batch's head time proportionally by bytes (equal
            # split when the batch carries no payload) so component sums
            # match total_busy exactly
            for nb, _cb, comp, rid in batch:
                share = lat * (nb / total) if total else lat / len(batch)
                prof.disk_busy(self.profiler_node, comp or "wal.force",
                               share, nb, rid)

        def done():
            if gen != self._gen:
                return
            self.busy = False
            for b in batch:
                b[1]()
            self._start_batch()

        self.sim.schedule(lat, done)


# ---------------------------------------------------------------------------
# Statistics helper
# ---------------------------------------------------------------------------


class LatencyStats:
    def __init__(self):
        self.samples: list[float] = []

    def add(self, v: float) -> None:
        self.samples.append(v)

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def mean(self) -> float:
        return sum(self.samples) / len(self.samples) if self.samples else float("nan")

    def percentile(self, p: float) -> float:
        if not self.samples:
            return float("nan")
        s = sorted(self.samples)
        idx = min(len(s) - 1, max(0, int(round(p / 100.0 * (len(s) - 1)))))
        return s[idx]
