"""Cluster assembly (Fig. 2), range partitioning with chained declustering
(§4), and the client library (routing, retries, consistency levels).

Ranges are *elastic* (core/ranges.py): the table built here is only the
initial pre-split.  Live splits and replica migrations rewrite the
registered metadata; the cluster mirrors it into `ranges`/`members` as
ground truth for tests and the balancer, while clients route through
their own RangeTable cache and chase WRONG_RANGE redirects.
"""

from __future__ import annotations

import bisect
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from . import ranges as ranges_mod
from .coordination import Coordination, NoNode
from .node import NodeConfig, SpinnakerNode
from .ranges import BalancerConfig, RangeBalancer, RangeTable
from .sim import LatencyStats, NetParams, Network, Simulator
from .types import ErrorCode, KeyRange, OpType, Result, WriteOp
from ..obs import Observability, ObsConfig, install_node_gauges


@dataclass
class ClusterConfig:
    n_nodes: int = 5
    num_keys: int = 100_000          # key-space pre-split for range boundaries
    # base ranges per node.  One range per node is the minimal layout; a
    # finer pre-split (the paper's deployments run many ranges per node,
    # §2.1) spreads range leadership round-robin so a skewed workload's
    # hot keys land on different leaders instead of piling onto one node
    ranges_per_node: int = 1
    node: NodeConfig = field(default_factory=NodeConfig)
    net: NetParams = field(default_factory=NetParams)
    session_timeout: float = 2.0     # §D.1
    trace: bool = False
    obs: ObsConfig = field(default_factory=ObsConfig)


def key_of(i: int) -> str:
    return f"k{i:012d}"


class SpinnakerCluster:
    """N nodes; node i owns base range i, replicated on i+1, i+2 (mod N)."""

    def __init__(self, sim: Simulator, cfg: ClusterConfig | None = None):
        self.sim = sim
        self.cfg = cfg or ClusterConfig()
        self.net = Network(sim, self.cfg.net)
        self.zk = Coordination(sim, session_timeout=self.cfg.session_timeout)
        self.nodes: dict[int, SpinnakerNode] = {}
        self.trace_log: list[str] = []
        self.obs = Observability(sim, "spinnaker", self.cfg.obs)

        n = self.cfg.n_nodes
        if n < 3:
            raise ValueError("Spinnaker needs >= 3 nodes for 3-way replication")
        nr = n * max(1, self.cfg.ranges_per_node)
        self.n_base_ranges = nr
        # initial range table: uniform pre-split of the key space,
        # `ranges_per_node` base ranges per node, chained declustering
        # cohort(r) = {r, r+1, r+2} (mod n)
        boundaries = [key_of(i * self.cfg.num_keys // nr) for i in range(nr)]
        self.ranges: dict[int, KeyRange] = {}
        self.members: dict[int, tuple[int, ...]] = {}
        for i in range(nr):
            hi = boundaries[i + 1] if i + 1 < nr else ""
            self.ranges[i] = KeyRange(range_id=i, lo=boundaries[i], hi=hi)
            self.members[i] = tuple(sorted(
                (i % n, (i + 1) % n, (i + 2) % n)))
        self._rebuild_routing()
        # register the table in coordination: clients route from these
        # znodes, and splits/migrations rewrite them
        self.zk.create(ranges_mod.VERSION_PATH, data=0)
        self.zk.create(ranges_mod.NEXT_RID_PATH, data=nr - 1)
        for rid, kr in self.ranges.items():
            ranges_mod.set_range_meta(self.zk, rid, kr.lo, kr.hi,
                                      self.members[rid])

        self.obs.profiler.attach_network(self.net)
        for i in range(n):
            self.nodes[i] = SpinnakerNode(self, i, self.cfg.node)
            install_node_gauges(self.obs, self.nodes[i])
            self.obs.profiler.attach_node(i, self.nodes[i].cpu,
                                          self.nodes[i].disk)
        for rid, kr in self.ranges.items():
            for m in self.members[rid]:
                peers = tuple(x for x in self.members[rid] if x != m)
                self.nodes[m].add_range(kr, peers)
        self.balancer: Optional[RangeBalancer] = None

    def cohort(self, rid: int) -> tuple[int, ...]:
        return self.members[rid]

    def _rebuild_routing(self) -> None:
        table = sorted((kr.lo, rid) for rid, kr in self.ranges.items())
        self._route_los = [lo for lo, _ in table]
        self._route_rids = [rid for _, rid in table]

    def range_of(self, key: str) -> int:
        """Ground-truth routing oracle (tests, preload).  Live clients use
        their own RangeTable cache + WRONG_RANGE redirects instead."""
        idx = bisect.bisect_right(self._route_los, key) - 1
        return self._route_rids[max(0, idx)]

    def on_range_table_changed(self) -> None:
        """Mirror registered range metadata into cluster ground truth and
        reconcile live nodes (create replicas they just joined — migration
        destinations, split children — retire ones they left).  Idempotent;
        invoked by replicas whenever they rewrite `/ranges/*` metadata."""
        rmap = ranges_mod.load_range_map(self.zk)
        if not rmap:
            return
        self.ranges = {rid: KeyRange(rid, lo, hi)
                       for rid, (lo, hi, _m) in rmap.items()}
        self.members = {rid: tuple(sorted(m))
                        for rid, (_lo, _hi, m) in rmap.items()}
        self._rebuild_routing()
        for node in self.nodes.values():
            if not node.up:
                continue   # down nodes reconcile at boot
            for rid, (_lo, _hi, members) in rmap.items():
                if node.node_id in members:
                    node.ensure_replica(rid)
                elif rid in node.replicas:
                    node.retire_replica(rid)

    # -- range administration (split / migrate / rebalance) --------------------
    def admin_split(self, rid: int, split_key: Optional[str] = None) -> bool:
        """Propose a live split of `rid` (at its median key by default)."""
        rep = self.leader_replica(rid)
        return rep.propose_split(split_key) if rep is not None else False

    def admin_move(self, rid: int, src: Optional[int] = None,
                   dst: Optional[int] = None) -> bool:
        """Migrate one replica of `rid` from `src` to `dst`.  Defaults:
        src = first follower member, dst = first up non-member node."""
        rep = self.leader_replica(rid)
        if rep is None:
            return False
        members = self.members.get(rid, ())
        if src is None:
            followers = [m for m in members if m != rep.node.node_id]
            src = followers[0] if followers else None
        if dst is None:
            cands = [i for i, node in sorted(self.nodes.items())
                     if node.up and i not in members]
            dst = cands[0] if cands else None
        if src is None or dst is None:
            return False
        return rep.start_migration(src, dst)

    def set_autobalance(self, on: bool,
                        cfg: Optional[BalancerConfig] = None) -> None:
        if on:
            if self.balancer is not None and cfg is not None \
                    and self.balancer.cfg is not cfg:
                self.balancer.stop()     # never leave two tickers running
                self.balancer = None
            if self.balancer is None:
                self.balancer = RangeBalancer(self, cfg)
            self.balancer.start()
        elif self.balancer is not None:
            self.balancer.stop()

    def start(self) -> None:
        self.obs.start()
        for node in self.nodes.values():
            node.boot()

    def settle(self, timeout: float = 30.0) -> None:
        """Drive the sim until every cohort has an open leader (test helper)."""
        deadline = self.sim.now + timeout
        while self.sim.now < deadline:
            if all(self.leader_replica(r) is not None
                   for r in list(self.ranges)):
                return
            before = self.sim.now
            self.sim.run(until=min(deadline, before + 0.05))
            if not self.sim._heap and self.sim.now >= deadline:
                break
        missing = [r for r in sorted(self.ranges)
                   if self.leader_replica(r) is None]
        if missing:
            raise RuntimeError(f"cohorts without open leader: {missing}")

    def leader_replica(self, rid: int):
        from .replica import Role
        for m in self.members.get(rid, ()):
            rep = self.nodes[m].replicas.get(rid)
            if rep is not None and rep.role is Role.LEADER \
                    and rep.open_for_writes and self.nodes[m].has_session():
                return rep
        return None

    # -- failure injection ------------------------------------------------------
    def crash_node(self, node_id: int, lose_disk: bool = False,
                   expire_session: bool = True) -> None:
        self.obs.events.emit("node_crash", node=node_id,
                             lose_disk=lose_disk)
        self.obs.journal.record("node_crash", node=node_id,
                                lose_disk=lose_disk)
        self.nodes[node_id].crash(lose_disk=lose_disk,
                                  expire_session=expire_session)

    def restart_node(self, node_id: int) -> None:
        self.obs.events.emit("node_restart", node=node_id)
        self.obs.journal.record("node_restart", node=node_id)
        self.nodes[node_id].restart()

    def partition(self, *groups) -> None:
        """Partition the data network into node groups, e.g.
        `cluster.partition({0, 1}, {2, 3, 4})`."""
        self.net.set_partition(groups)

    def partition_oneway(self, src_group, dst_group) -> None:
        """Asymmetric partition: messages src_group -> dst_group are cut,
        the reverse direction keeps flowing (gray failure)."""
        self.obs.events.emit("partition_oneway",
                             src=sorted(src_group), dst=sorted(dst_group))
        self.net.set_oneway_partition(src_group, dst_group)

    def set_link_fault(self, src: int, dst: int,
                       drop_p: Optional[float] = None,
                       dup_p: Optional[float] = None,
                       delay_factor: Optional[float] = None) -> None:
        """Degrade the directed data link src -> dst.  Merge semantics:
        only the aspects passed change, so drop + delay compose."""
        self.obs.events.emit("link_fault", src=src, dst=dst, drop_p=drop_p,
                             dup_p=dup_p, delay_factor=delay_factor)
        self.net.update_link_fault(src, dst, drop_p=drop_p, dup_p=dup_p,
                                   delay_factor=delay_factor)

    def slow_disk(self, node_id: int, factor: float) -> None:
        """Gray failure: the node's log device serves at `factor`x latency."""
        self.obs.events.emit("slow_disk", node=node_id, factor=factor)
        self.nodes[node_id].disk.slow_factor = factor

    def slow_cpu(self, node_id: int, factor: float) -> None:
        """Gray failure: the node's CPU serves at `factor`x service time."""
        self.obs.events.emit("slow_cpu", node=node_id, factor=factor)
        self.nodes[node_id].cpu.slow_factor = factor

    def flap_session(self, node_id: int, outage: float = 1.0) -> None:
        """Expire the node's ZK session while it keeps running; the client
        library reconnects after `outage` seconds."""
        self.obs.events.emit("session_flap", node=node_id, outage=outage)
        # a flapped node's ephemerals (leader claims, candidacies) vanish
        # with the session: any lease it believed in is protocol-moot, so
        # tell the watchdog not to hold it against a successor
        self.obs.journal.record("session_flap", node=node_id, outage=outage)
        self.nodes[node_id].flap_session(outage)

    def heal(self) -> None:
        """Clear EVERY injected network/gray fault: symmetric and one-way
        partitions, per-link drop/dup/delay, and disk/CPU slow factors.
        (Crashed nodes stay down — `restart` is a separate event.)"""
        self.net.clear_faults()
        for node in self.nodes.values():
            node.disk.slow_factor = 1.0
            node.cpu.slow_factor = 1.0

    def trace(self, msg: str) -> None:
        if self.cfg.trace:
            self.trace_log.append(msg)

    def make_client(self, client_id: str = "c0") -> "Client":
        return Client(self, client_id)


class Client:
    """Closed-loop client: routes ops to cohort leaders (strong) or round-
    robin replicas (timeline), retries on NOT_LEADER/UNAVAILABLE with
    capped exponential backoff, and re-routes on WRONG_RANGE redirects.

    Routing is dynamic: the range table is cached from the coordination
    metadata (`core/ranges.py`), invalidated by a data-change watch on the
    table version znode or by a WRONG_RANGE reply from a replica whose
    range no longer covers the key (live splits move keys between cohorts
    mid-flight)."""

    MAX_RETRIES = 60
    BACKOFF_BASE = 0.02      # first retry delay; doubles per retry ...
    BACKOFF_CAP = 1.0        # ... up to this cap (±50% jitter throughout)
    ATTEMPT_TIMEOUT = 1.0    # first attempt; scales with the retry count
    ATTEMPT_TIMEOUT_CAP = 8.0
    # client->node request envelope window: requests headed to the same
    # node within this window share one message (per-message wire cost paid
    # once).  0 = same-event only — ops issued simultaneously (e.g. the
    # convoy a coalesced reply envelope releases) batch for free, and no op
    # is ever delayed to wait for company.
    COALESCE_WINDOW = 0.0

    def __init__(self, cluster: SpinnakerCluster, client_id: str):
        self.cluster = cluster
        self.sim = cluster.sim
        self.id = client_id
        self.leader_cache: dict[int, int] = {}
        self.range_table = RangeTable(cluster.zk)
        self.wrong_range_redirects = 0
        self.mread_batches = 0       # multi_get fan-outs (one per range)
        self.txn2_issued = 0         # cross-range (2PC) transaction sends
        self.lock_retries = 0        # LOCKED replies (no-wait lock policy)
        self._rr = 0
        self.stats = LatencyStats()
        self.stats_by_kind: dict[str, LatencyStats] = {}
        self.errors = 0
        self._session_seen: dict[tuple[str, str], int] = {}
        # client-perceived robustness counters (chaos runs report these as
        # client-side unavailability evidence); mirrored into the obs
        # metrics registry under the client id
        self.retries = 0
        self.backoff_time = 0.0          # total seconds spent backing off
        self.attempt_timeouts = 0        # per-attempt timer expiries
        self.retry_exhausted = 0         # ops that gave up (TIMEOUT result)
        self.error_counts: dict[str, int] = {}   # non-OK reply codes seen
        # per-key retry gate: same-key writes that entered the retry path
        # re-send in issue order (see _schedule_retry)
        self._retry_gate: dict[str, dict] = {}
        self._retry_waiters: dict[str, deque] = {}
        # workload-driver hook: called once per finished op with
        # (kind, result); fires for successes AND retry-exhausted timeouts
        self.op_hook: Optional[Callable[[str, Result], None]] = None
        # workload adapters set this right before a call so the sampled
        # trace carries the workload's op label ("rmw", "txn_cross", ...)
        # instead of the client-internal path name; consumed per op
        self.next_trace_kind: Optional[str] = None
        # request envelopes: per-target staging (see COALESCE_WINDOW)
        self._req_buf: dict[int, list[tuple]] = {}
        self.req_envelopes = 0       # multi-request envelopes sent

    # -- routing -----------------------------------------------------------------
    def _retry_delay(self, tries: int) -> float:
        """Capped exponential backoff with jitter.  The old fixed 50 ms
        retry loop synchronized every blocked client into periodic bursts
        — past the saturation knee those bursts are what collapses
        throughput (congestion collapse); spreading and spacing retries
        keeps the overload tail flat."""
        exp = min(self.BACKOFF_CAP, self.BACKOFF_BASE * (2 ** tries))
        delay = exp * (0.5 + self.sim.rng.random())
        # every _retry_delay call schedules exactly one retry: count it here
        self.retries += 1
        self.backoff_time += delay
        self._count("client_retries")
        self._count("client_backoff_s", delay)
        return delay

    def _schedule_retry(self, kind: str, key: str, kw: dict, cb: Callable,
                        consistent: bool, t0: float, tries: int) -> None:
        """Re-schedule a failed attempt.  Same-key *writes* serialize
        through a per-key gate while in the retry path: pipelined writes
        that all bounced (redirect chasing a live split, leader failover)
        must be re-sent in issue order, or a later conditional put can
        overtake an earlier one and fail with a spurious VERSION_MISMATCH.
        First sends are never gated — the happy path pipelines freely."""
        delay = self._retry_delay(tries)
        if kind not in ("write", "txn"):
            self.sim.schedule(delay, self._op, kind, key, kw, cb,
                              consistent, t0, tries + 1)
            return
        owner = self._retry_gate.get(key)
        if owner is None or owner is kw:
            self._retry_gate[key] = kw
            self.sim.schedule(delay, self._op, kind, key, kw, cb,
                              consistent, t0, tries + 1)
        else:
            self._retry_waiters.setdefault(key, deque()).append(
                (delay, kind, kw, cb, consistent, t0, tries))

    def _gate_release(self, kind: str, key: str, kw: dict) -> None:
        """Terminal completion of a gated write: hand the gate to the next
        parked same-key retry (preserving issue order) or clear it."""
        if kind not in ("write", "txn") or self._retry_gate.get(key) is not kw:
            return
        q = self._retry_waiters.get(key)
        if not q:
            del self._retry_gate[key]
            self._retry_waiters.pop(key, None)
            return
        delay, nkind, nkw, ncb, nconsistent, nt0, ntries = q.popleft()
        if not q:
            del self._retry_waiters[key]
        self._retry_gate[key] = nkw
        self.sim.schedule(delay, self._op, nkind, key, nkw, ncb,
                          nconsistent, nt0, ntries + 1)

    def _attempt_timeout(self, tries: int) -> float:
        """Per-attempt timeout, scaled with the backoff schedule: the first
        attempt keeps the historical 1 s, retries wait longer — under a
        fault the op is probably queued behind recovery, and re-sending it
        on a short fuse just multiplies load on the healing cohort."""
        return min(self.ATTEMPT_TIMEOUT_CAP,
                   self.ATTEMPT_TIMEOUT * (2 ** min(tries, 3)))

    def _count(self, name: str, v: float = 1.0) -> None:
        self.cluster.obs.metrics.inc(self.id, name, v)

    def _note_reply(self, res: Optional[Result]) -> None:
        """Track non-OK replies (and lost attempts) per error code."""
        if res is None:
            code = "ATTEMPT_TIMEOUT"
            self.attempt_timeouts += 1
        elif res.ok:
            return
        else:
            code = getattr(res.code, "name", str(res.code))
        self.error_counts[code] = self.error_counts.get(code, 0) + 1
        self._count(f"client_err_{code}")

    def robustness_summary(self) -> dict:
        return {"retries": self.retries,
                "backoff_time_s": round(self.backoff_time, 6),
                "attempt_timeouts": self.attempt_timeouts,
                "retry_exhausted": self.retry_exhausted,
                "error_counts": dict(sorted(self.error_counts.items()))}

    def _lookup_leader(self, rid: int) -> Optional[int]:
        cached = self.leader_cache.get(rid)
        if cached is not None:
            return cached
        try:
            leader_id, _epoch = self.cluster.zk.get(f"/ranges/{rid}/leader")
            self.leader_cache[rid] = leader_id
            return leader_id
        except NoNode:
            return None

    def _any_replica(self, rid: int) -> Optional[int]:
        members = self.range_table.members(rid)
        if not members:
            return None
        self._rr += 1
        return members[self._rr % len(members)]

    # -- async API -----------------------------------------------------------------
    def get(self, key: str, colname: str, consistent: bool,
            cb: Callable[[Result], None], monotonic: bool = False) -> None:
        """`monotonic=True` adds the PNUTS-style session guarantee to
        timeline reads: this client never observes versions going
        backwards (stale replicas are retried)."""
        if monotonic and not consistent:
            inner = cb

            def cb(res, _key=(key, colname)):
                seen = self._session_seen.get(_key, -1)
                if res.ok and res.version is not None \
                        and res.version < seen:
                    self.get(key, colname, False, inner, monotonic=True)
                    return
                if res.ok and res.version is not None:
                    self._session_seen[_key] = max(seen, res.version)
                inner(res)

        self._op("read", key, dict(key=key, colname=colname,
                                   consistent=consistent), cb,
                 consistent=consistent, t0=self.sim.now, tries=0)

    def put(self, key: str, colname: str, value: Any,
            cb: Callable[[Result], None]) -> None:
        op = WriteOp(OpType.PUT, key, colname, value)
        self._op("write", key, dict(op=op), cb, consistent=True,
                 t0=self.sim.now, tries=0)

    def delete(self, key: str, colname: str, cb: Callable) -> None:
        op = WriteOp(OpType.DELETE, key, colname)
        self._op("write", key, dict(op=op), cb, consistent=True,
                 t0=self.sim.now, tries=0)

    def conditional_put(self, key: str, colname: str, value: Any, version: int,
                        cb: Callable) -> None:
        op = WriteOp(OpType.COND_PUT, key, colname, value,
                     expected_version=version)
        self._op("write", key, dict(op=op), cb, consistent=True,
                 t0=self.sim.now, tries=0)

    def conditional_delete(self, key: str, colname: str, version: int,
                           cb: Callable) -> None:
        op = WriteOp(OpType.COND_DELETE, key, colname,
                     expected_version=version)
        self._op("write", key, dict(op=op), cb, consistent=True,
                 t0=self.sim.now, tries=0)

    def multi_put(self, key: str, columns: list[tuple[str, Any]],
                  cb: Callable) -> None:
        op = WriteOp(OpType.MULTI_PUT, key, columns=tuple(columns))
        self._op("write", key, dict(op=op), cb, consistent=True,
                 t0=self.sim.now, tries=0)

    def multi_get(self, pairs: list[tuple[str, str]], consistent: bool,
                  cb: Callable[[list[Result]], None],
                  monotonic: bool = False) -> None:
        """Range-aware batched read: keys are grouped by the cached range
        table and each group goes out as ONE `mread` message to its
        cohort (leader for strong, round-robin replica for timeline) —
        the fan-out is per *range*, not per key, so both the client and
        the server pay one message overhead per cohort.  Per-key
        WRONG_RANGE redirects re-group just the moved keys; group-level
        failures (leader change, timeout) retry the whole group."""
        if not pairs:
            cb([])
            return
        results: list[Optional[Result]] = [None] * len(pairs)
        pending = [len(pairs)]
        t0 = self.sim.now

        def settle(i: int, res: Result, record: bool) -> None:
            if record:
                res.latency = self.sim.now - t0
                if res.code != ErrorCode.TIMEOUT:
                    # retry-exhausted timeouts are reported (op_hook,
                    # errors) but kept out of the latency population,
                    # matching the single-op path
                    self.stats.add(res.latency)
                    self.stats_by_kind.setdefault(
                        "read", LatencyStats()).add(res.latency)
                if self.op_hook is not None:
                    self.op_hook("read", res)
            results[i] = res
            pending[0] -= 1
            if pending[0] == 0:
                cb(results)  # type: ignore[arg-type]

        def deliver(i: int, res: Result) -> None:
            key, colname = pairs[i]
            if monotonic and not consistent and res.ok \
                    and res.version is not None:
                seen = self._session_seen.get((key, colname), -1)
                if res.version < seen:
                    # stale replica: fall back to the single-get retry path
                    # (it records its own stats)
                    self.get(key, colname, False,
                             lambda r, _i=i: settle(_i, r, False),
                             monotonic=True)
                    return
                self._session_seen[(key, colname)] = max(seen, res.version)
            settle(i, res, True)

        self._mread([(i, k, c) for i, (k, c) in enumerate(pairs)],
                    consistent, deliver, tries=0)

    # per-key retryable mread results (reads never bounce on locks —
    # strong reads of locked keys defer server-side instead)
    _RETRY_CODES = (ErrorCode.NOT_LEADER, ErrorCode.UNAVAILABLE,
                    ErrorCode.WRONG_RANGE, ErrorCode.OVERLOADED)

    def _mread(self, items: list[tuple[int, str, str]], consistent: bool,
               deliver: Callable, tries: int) -> None:
        """Group `items` ((idx, key, colname)) by range and issue one
        batched read per group; re-invoked with the residue on retries."""
        if tries > self.MAX_RETRIES:
            for i, _k, _c in items:
                self.errors += 1
                self.retry_exhausted += 1
                self._count("client_retry_exhausted")
                deliver(i, Result(ErrorCode.TIMEOUT))
            return
        groups: dict[int, list[tuple[int, str, str]]] = {}
        stale: list[tuple[int, str, str]] = []
        for it in items:
            rid = self.range_table.lookup(it[1])
            if rid is None:
                stale.append(it)
            else:
                groups.setdefault(rid, []).append(it)
        if stale:
            self.range_table.invalidate()
            self.sim.schedule(self._retry_delay(tries), self._mread, stale,
                              consistent, deliver, tries + 1)
        for rid, its in groups.items():
            self._mread_group(rid, its, consistent, deliver, tries)

    def _mread_group(self, rid: int, items: list[tuple[int, str, str]],
                     consistent: bool, deliver: Callable,
                     tries: int) -> None:
        target = self._lookup_leader(rid) if consistent \
            else self._any_replica(rid)
        if target is None:
            self.sim.schedule(self._retry_delay(tries), self._mread, items,
                              consistent, deliver, tries + 1)
            return
        self.mread_batches += 1
        settled = [False]

        def retry(residue: list, saw_wrong_range: bool,
                  leader_hint: Optional[int]) -> None:
            self.leader_cache.pop(rid, None)
            if saw_wrong_range:
                self.wrong_range_redirects += 1
                self.range_table.invalidate()
            if leader_hint is not None:
                self.leader_cache[rid] = leader_hint
            self.sim.schedule(self._retry_delay(tries), self._mread, residue,
                              consistent, deliver, tries + 1)

        def on_reply(res) -> None:
            if settled[0]:
                return
            settled[0] = True
            timeout_ev.cancel()
            if isinstance(res, Result):
                self._note_reply(res)
            if res is None or isinstance(res, Result):
                # whole-group gate failure (or dead target): retry all
                wrong = res is not None and res.code == ErrorCode.WRONG_RANGE
                hint = res.leader_hint if res is not None \
                    and res.code == ErrorCode.NOT_LEADER else None
                retry(items, wrong, hint)
                return
            redo: list[tuple[int, str, str]] = []
            wrong = False
            for it, r in zip(items, res):
                if r.code in self._RETRY_CODES:
                    redo.append(it)
                    wrong = wrong or r.code == ErrorCode.WRONG_RANGE
                else:
                    deliver(it[0], r)
            if redo:
                retry(redo, wrong, None)

        def on_timeout() -> None:
            if settled[0]:
                return
            settled[0] = True
            self._note_reply(None)
            retry(items, False, None)

        timeout_ev = self.sim.schedule(self._attempt_timeout(tries),
                                       on_timeout)
        payload = dict(pairs=[(k, c) for _i, k, c in items],
                       consistent=consistent,
                       reply=self._reply_via_net(target, on_reply))
        self._send_req(target, rid, "mread", payload,
                       200 + 64 * len(items), "client.read")

    def transaction(self, ops: list[WriteOp], cb: Callable) -> None:
        """Multi-operation transaction.  Single-cohort op sets keep the
        paper's §8.2 fast path untouched (one Paxos round, no locks, no
        2PC); op sets spanning ranges are partitioned via the cached
        range table and run through the Paxos-backed 2PC coordinator
        (core/txn.py) — the leader of the first op's range coordinates.
        Groups are recomputed on every retry so WRONG_RANGE redirects
        chase live splits."""
        if not ops:
            cb(Result(ErrorCode.OK))
            return
        self._op("txn", ops[0].key, dict(ops=ops), cb, consistent=True,
                 t0=self.sim.now, tries=0)

    # -- engine --------------------------------------------------------------------
    def _op(self, kind: str, key: str, kw: dict, cb: Callable,
            consistent: bool, t0: float, tries: int) -> None:
        if tries == 0:
            # sampled trace rides `kw` across retries ("_trace" never goes
            # on the wire; each attempt forwards it as payload["trace"])
            hint, self.next_trace_kind = self.next_trace_kind, None
            tr = self.cluster.obs.tracer.maybe_start(hint or kind, kind, key)
            if tr is not None:
                kw["_trace"] = tr
        if tries > self.MAX_RETRIES:
            self.errors += 1
            self.retry_exhausted += 1
            self._count("client_retry_exhausted")
            self._gate_release(kind, key, kw)
            tr = kw.pop("_trace", None)
            if tr is not None:
                self.cluster.obs.tracer.finish(tr, False, "timeout")
            res = Result(ErrorCode.TIMEOUT, latency=self.sim.now - t0,
                         attempts=tries)
            if self.op_hook is not None:
                self.op_hook(kind, res)
            cb(res)
            return
        rid = self.range_table.lookup(key)
        wire_kind, payload_kw = kind, kw
        if kind == "txn" and rid is not None:
            # partition the op set by range — recomputed per attempt so
            # redirects chase live splits.  One range: §8.2 fast path.
            # Several: 2PC via the first range's leader (core/txn.py).
            groups: dict[int, list[WriteOp]] = {}
            for op in kw["ops"]:
                r = self.range_table.lookup(op.key)
                if r is None:
                    rid = None
                    break
                groups.setdefault(r, []).append(op)
            if rid is not None and len(groups) > 1:
                wire_kind = "txn2"
                payload_kw = dict(groups=groups)
                self.txn2_issued += 1
        if kind == "read" and not consistent:
            target = self._any_replica(rid) if rid is not None else None
        else:
            target = self._lookup_leader(rid) if rid is not None else None
        if target is None:
            if rid is None:
                self.range_table.invalidate()
            self._schedule_retry(kind, key, kw, cb, consistent, t0, tries)
            return

        settled = [False]

        def retry(res: Optional[Result]):
            self.leader_cache.pop(rid, None)
            if res is not None and res.code == ErrorCode.WRONG_RANGE:
                # the range table moved under us (live split / migration):
                # reload it before re-routing
                self.wrong_range_redirects += 1
                self.range_table.invalidate()
            if res is not None and res.leader_hint is not None \
                    and res.code == ErrorCode.NOT_LEADER:
                self.leader_cache[rid] = res.leader_hint
            self._schedule_retry(kind, key, kw, cb, consistent, t0, tries)

        def on_reply(res: Optional[Result]):
            if settled[0]:
                return
            settled[0] = True
            timeout_ev.cancel()
            self._note_reply(res)
            if res is not None and res.code == ErrorCode.LOCKED:
                self.lock_retries += 1
            if res is None or res.code in (ErrorCode.NOT_LEADER,
                                           ErrorCode.UNAVAILABLE,
                                           ErrorCode.WRONG_RANGE,
                                           ErrorCode.LOCKED,
                                           ErrorCode.OVERLOADED):
                retry(res)
                return
            self._gate_release(kind, key, kw)
            res.latency = self.sim.now - t0
            res.attempts = tries + 1
            tr = kw.pop("_trace", None)
            if tr is not None:
                self.cluster.obs.tracer.finish(
                    tr, res.ok, getattr(res.code, "name", str(res.code)))
            self.stats.add(res.latency)
            self.stats_by_kind.setdefault(kind, LatencyStats()).add(
                res.latency)
            if self.op_hook is not None:
                self.op_hook(kind, res)
            cb(res)

        def on_timeout():
            if settled[0]:
                return
            settled[0] = True
            self._note_reply(None)
            retry(None)

        timeout_ev = self.sim.schedule(self._attempt_timeout(tries),
                                       on_timeout)

        payload = dict(payload_kw)
        payload.pop("_trace", None)
        tr = kw.get("_trace")
        if tr is not None:
            tr.attempts += 1
            tr.t_send = self.sim.now
            payload["trace"] = tr
        payload["reply"] = self._reply_via_net(target, on_reply)
        nbytes = 4200 if kind in ("write", "txn") else 300
        comp = "client.write" if kind in ("write", "txn") else "client.read"
        self._send_req(target, rid, wire_kind, payload, nbytes, comp)

    # -- request/reply envelopes (client <-> node edge) ---------------------------
    def _send_req(self, target: int, rid: int, wire_kind: str, payload: dict,
                  nbytes: int, comp: str) -> None:
        """Stage a request for `target`; everything staged within the
        coalescing window leaves as one envelope."""
        buf = self._req_buf.get(target)
        if buf is None:
            buf = self._req_buf[target] = []
            self.sim.schedule(self.COALESCE_WINDOW, self._flush_reqs, target)
        buf.append((rid, wire_kind, payload, nbytes, comp))

    def _flush_reqs(self, target: int) -> None:
        batch = self._req_buf.pop(target, None)
        if not batch:
            return
        node = self.cluster.nodes[target]
        if len(batch) == 1:
            rid, kind, payload, nbytes, comp = batch[0]
            self.cluster.net.send(self.id, target, node.handle_client, rid,
                                  kind, payload, nbytes=nbytes,
                                  cross_switch=True, component=comp, rid=rid)
            return
        self.req_envelopes += 1
        self._count("client_req_envelopes")
        items = [(rid, kind, payload) for rid, kind, payload, _n, _c in batch]
        self.cluster.net.send(self.id, target, node.handle_client_batch,
                              items,
                              nbytes=sum(n for *_h, n, _c in batch),
                              cross_switch=True, component=batch[0][4],
                              rid=batch[0][0])

    def _reply_via_net(self, src_node: int, cb: Callable) -> Callable:
        """Build the server-side reply hook: replies route through the
        node's per-client reply envelope (node.client_reply), so acks and
        read results minted in one event share one message back."""
        node = self.cluster.nodes[src_node]

        def reply(res):
            if isinstance(res, list):   # batched mread reply
                nbytes = 200 + sum(
                    4200 if r is not None and r.value is not None else 64
                    for r in res)
            else:
                nbytes = 4200 if res is not None and res.value is not None \
                    else 200
            node.client_reply(self.id, cb, res, nbytes)
        return reply

    # -- synchronous helpers for tests ------------------------------------------------
    def sync(self, fn: Callable, *args) -> Result:
        box: list[Result] = []
        fn(*args, lambda r: box.append(r))
        guard = 0
        while not box and guard < 2_000_000:
            if not self.sim.step():
                break
            guard += 1
        if not box:
            raise RuntimeError("op did not complete")
        return box[0]

    def sync_put(self, key: str, colname: str, value: Any) -> Result:
        return self.sync(self.put, key, colname, value)

    def sync_get(self, key: str, colname: str, consistent: bool = True) -> Result:
        return self.sync(self.get, key, colname, consistent)

    def sync_cond_put(self, key: str, colname: str, value: Any,
                      version: int) -> Result:
        return self.sync(self.conditional_put, key, colname, value, version)

    def sync_delete(self, key: str, colname: str) -> Result:
        return self.sync(self.delete, key, colname)
