"""Spinnaker core: the paper's Paxos replication protocol, log/storage
engine, coordination service, and cluster — on a deterministic simulator.
"""

from .cluster import Client, ClusterConfig, SpinnakerCluster, key_of
from .coordination import Coordination
from .node import NodeConfig
from .ranges import BalancerConfig, RangeBalancer, RangeTable
from .replica import ReplicaConfig, Role
from .sim import DiskParams, NetParams, Simulator
from .types import ErrorCode, OpType, Result, WriteOp

__all__ = [
    "BalancerConfig", "Client", "ClusterConfig", "SpinnakerCluster",
    "key_of", "Coordination", "NodeConfig", "RangeBalancer", "RangeTable",
    "ReplicaConfig", "Role", "DiskParams", "NetParams", "Simulator",
    "ErrorCode", "OpType", "Result", "WriteOp",
]
