"""Per-cohort Paxos replica state machine (§5 replication, §6 recovery,
§7 leader election).

One `CohortReplica` instance exists per (node, key-range).  The node wires
replicas to its shared WAL, CPU server, network, and coordination session.

Protocol summary (steady state, Fig. 4):
  client write -> leader: assign LSN (epoch.seq) + versions, append to the
  cohort's *batch accumulator*; the batch flushes (immediately when the
  CPU is idle, else on a record-count/byte/deadline trigger) as ONE
  multi-record PROPOSE per in-sync follower ∥ one WAL force covering the
  whole batch; followers force the batch once and reply with a single
  *cumulative* ACK (their durability watermark, superseding all lower
  acks); the leader commits once 2 of 3 logs hold a record (its own force
  counts), applies to memtable, replies to clients.  A periodic async
  COMMIT message advances followers (the *commit period*, skipped while
  cmt is idle); commit LSNs are persisted with non-forced log writes.

  Batching is the paper's "leader batches writes" lever (§5, §C): it
  amortises per-message CPU and per-force disk cost, which is what moves
  the §C saturation knee.  With `batch="off"` every record flushes alone
  and the wire protocol degenerates to the per-operation original.

Recovery (Fig. 5/6, App. B): follower local recovery replays (flushed,
f.cmt], catch-up pulls committed writes (f.cmt, l.cmt] from the leader
(log- or SSTable-sourced), the window (f.cmt, f.lst] is *logically
truncated* via skipped-LSN lists; leader takeover re-proposes
(l.cmt, l.lst] under a fresh epoch before reopening for writes.

Election (Fig. 7): candidates advertise last-LSN in ephemeral sequential
znodes; with a majority present the max-LSN candidate claims /leader
atomically.  Entries are stamped with the election *round* (the epoch
counter) so stale candidacies from earlier rounds are never counted —
this closes the stale-lst race the paper waves off as "certain race
conditions ignored".
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Callable, Optional, TYPE_CHECKING

from . import ranges as ranges_mod
from .coordination import NodeExists, NoNode
from .storage import Store
from .txn import TxnManager
from .types import (CommitMarker, ErrorCode, KeyRange, LogRecord, OpType,
                    Result, TXN_OPS, WriteOp, fmt_lsn, lsn_epoch, lsn_seq,
                    make_lsn)
from ..obs.journal import record_digest

if TYPE_CHECKING:
    from .node import SpinnakerNode


class Role(enum.Enum):
    OFFLINE = "offline"
    ELECTING = "electing"
    CATCHUP = "catchup"          # follower pulling missed writes
    FOLLOWER = "follower"
    TAKEOVER = "takeover"        # leader-elect running Fig. 6
    LEADER = "leader"


@dataclass
class ReplicaConfig:
    commit_period: float = 1.0          # §D.1 default
    # §D.1: piggy-back the commit LSN on proposal batches.  On by default
    # since the §9 write-path campaign: while writes flow, followers learn
    # commit from the piggybacked watermark and the periodic on_commit
    # broadcast is suppressed (commit markers stop paying their own
    # message); idle ranges keep the slow keepalive rebroadcast.
    piggyback_commit: bool = True
    flush_threshold: int = 4 << 20
    # -- leader-side proposal batching -------------------------------------
    # "adaptive": a write flushes immediately while the node's CPU queue is
    # empty (light load keeps per-op latency), and accumulates under queuing
    # until a record-count/byte/deadline trigger fires — so batch size grows
    # exactly when the per-message costs start to dominate.  "off": flush
    # after every record (the strictly per-operation protocol).
    batch: str = "adaptive"             # "adaptive" | "off"
    batch_max_records: int = 32
    batch_max_bytes: int = 256 << 10
    batch_deadline: float = 0.5e-3      # max extra latency bought for batching
    # -- cross-range 2PC (core/txn.py) -------------------------------------
    txn_prepare_timeout: float = 0.5    # coordinator aborts stuck prepares
    txn_tick: float = 0.15              # resolution/resend/re-vote period
    # -- partition-aware leader leases (§7; Keyspace-style master leases) ---
    # A leader only serves strong reads/writes while it holds a time-bounded
    # lease renewed through follower acks (renewal quorum = commit quorum).
    # The lease window is anchored at the renewal's SEND time minus the
    # maximum simulated clock skew, so a deposed leader's lease provably
    # expires before the majority side elects a successor: followers wait
    # `lease_duration + 4*max_clock_skew` of leader silence before deleting
    # the leader znode (deposal needs fresh majority connectivity so a lone
    # partitioned follower cannot disrupt a healthy cohort).  A leader whose
    # lease lapses abdicates, fences writes, and suppresses its own
    # candidacy until it re-establishes data-network majority contact —
    # without this, the minority-partitioned ex-leader (max lst, ZK always
    # reachable) would win every re-election and stall the range forever.
    lease_enabled: bool = True
    lease_duration: float = 1.0
    max_clock_skew: float = 0.05
    # -- mutation corpus (test-only switches; never enable in production
    # configs).  Each one deliberately reintroduces a known-fixed protocol
    # bug so the invariant watchdog (obs/watchdog.py) can be validated to
    # pinpoint it at the violating transition — see chaos/mutations.py.
    bug_catchup_starvation: bool = False   # pace catch-up retries off the
                                           # lease-heartbeat clock again
    bug_takeover_wedge: bool = False       # skip the WAL reload of the
                                           # unresolved window at takeover
    bug_ack_before_force: bool = False     # follower acks a proposal at
                                           # receive time, before its force
    drop_first_catchup: bool = False       # fault hook: swallow the first
                                           # catch-up data delivery


class CohortReplica:
    def __init__(self, node: "SpinnakerNode", key_range: KeyRange,
                 peers: tuple[int, ...], cfg: ReplicaConfig):
        self.node = node
        self.range = key_range                 # narrows on live splits
        self.rid = key_range.range_id
        self.peers = tuple(sorted(peers))      # other member node ids
        self.cfg = cfg
        self.store = Store(flush_threshold_bytes=cfg.flush_threshold)

        self.role = Role.OFFLINE
        self.epoch = 0
        self.leader_id: Optional[int] = None

        # log positions
        self.cmt = 0           # last committed LSN known locally
        self.lst = 0           # last LSN in local log
        self.forced_upto = 0   # leader: own contiguous durable LSN
        self._next_seq = 1

        # leader-side state
        self.queue: dict[int, LogRecord] = {}           # pending (uncommitted)
        self.acked: dict[int, int] = {}                 # follower -> max acked LSN
        self.insync: set[int] = set()
        self.open_for_writes = False
        self.pending_reply: dict[int, Callable] = {}
        self.blocked_writes: list[tuple[WriteOp, Callable]] = []
        self.proposed_version: dict[tuple[str, str], int] = {}
        self._commit_timer = None
        self._takeover_hi = 0    # l.lst at takeover; writes open when cmt >= this
        self._election_round = 0
        self._last_commit_bcast = -1   # cmt at the last on_commit broadcast
        self._piggy_sent = -1    # highest cmt piggybacked to ALL insync
        # range management (core/ranges.py): a proposed-but-unapplied SPLIT
        # gates writes above the split point; one member change in flight max
        self.pending_split: Optional[tuple[str, int]] = None  # (key, child rid)
        self._pending_member_change = False
        self._watched_peers: set[int] = set()
        # cross-range 2PC state machine (lock table, prepared set,
        # coordinator role) — core/txn.py
        self.txn = TxnManager(self)

        # leader-side batch accumulator (records queued + WAL-buffered but
        # not yet covered by a force / proposed to followers)
        self._batch: list[LogRecord] = []
        self._batch_bytes = 0
        self._batch_timer = None

        # follower-side
        self._announced_leader_epoch = 0

        # -- leader leases + connectivity probes (cfg.lease_enabled) -------
        self._lease_until = 0.0          # leader: lease valid through here
        self._lease_seq = 0              # renewal round counter
        self._lease_sent: dict[int, float] = {}      # seq -> send time
        self._lease_acks: dict[int, set[int]] = {}   # seq -> acked peers
        self._lease_timer = None
        self._guard_timer = None
        self._leader_seen = 0.0          # follower: last leader contact
        self._catchup_seen = 0.0         # CATCHUP: last data-path progress
                                         # (lease heartbeats keep
                                         # _leader_seen fresh, so the
                                         # catch-up retry must pace off its
                                         # own clock or it never fires)
        self._peer_seen: dict[int, float] = {}       # peer -> last pong/ping
        self._suppressed = False         # barred from candidacy until
                                         # majority data-net contact returns
        self._rc_seq = 0                 # read-confirm (read-index) rounds
        self._rc_waiting: list[Callable] = []
        self._rc_acks: set[int] = set()
        self._rc_inflight = False

        # stats
        self.commits = 0
        self.writes_served = 0
        self.reads_served = 0
        self.batches_flushed = 0       # leader: batch forces issued
        self.batched_records = 0       # leader: records covered by them
        self.acks_sent = 0             # follower: cumulative acks sent

        # observability: sampled traces of admitted-but-uncommitted writes,
        # keyed by LSN (leader side only; never serialized into records)
        self._trace_by_lsn: dict[int, object] = {}

    # ------------------------------------------------------------------ utils
    @property
    def zk(self):
        return self.node.zk

    @property
    def base(self) -> str:
        return f"/ranges/{self.rid}"

    def _send(self, dst: int, handler: str, nbytes: int = 256, **kw) -> None:
        self.node.send(dst, self.rid, handler, nbytes=nbytes, **kw)

    def _send_batched(self, dst: int, handler: str, nbytes: int = 256,
                      **kw) -> None:
        """Hot-path variant of `_send`: same-event messages to one peer
        node share a wire envelope (node.send_batched).  With many ranges
        per node an ingress drain flushes several replicas at once — their
        proposes (and the acks coming back) ride one message per peer."""
        self.node.send_batched(dst, self.rid, handler, nbytes=nbytes, **kw)

    def log(self, msg: str) -> None:
        self.node.cluster.trace(
            f"[{self.node.sim.now*1e3:9.2f}ms n{self.node.node_id} r{self.rid} "
            f"{self.role.value:9s} e{self.epoch}] {msg}")

    @property
    def obs(self):
        return self.node.cluster.obs

    def _minc(self, name: str, v: float = 1.0) -> None:
        self.obs.metrics.inc(self.node.node_id, name, v)

    def _heat(self, nbytes: int = 0) -> None:
        """Bump this range's heat (served ops + payload bytes) in the
        cluster-global profiler — the balancer's load signal."""
        prof = self.obs.profiler
        if prof.enabled:
            prof.range_op(self.rid, nbytes)

    def _jrec(self, kind: str, **fields) -> None:
        """Record a protocol transition in the flight-recorder journal
        (obs/journal.py) — pure measurement, zero modeled cost."""
        jr = self.obs.journal
        if jr.enabled:
            jr.record(kind, node=self.node.node_id, rid=self.rid, **fields)

    # ============================================================== lifecycle
    def start(self) -> None:
        """Called after the node's local recovery pass for this range."""
        records, cmt = self.node.wal.recover_range(self.rid)
        # lst floor: records below the SSTable-flush watermark were GC'd
        # from the log (and a forked child's whole prefix lives only in its
        # fork SSTable), so the durable position is at least that watermark
        self.lst = max(max((r.lsn for r in records), default=0),
                       self.node.wal.flushed_upto.get(self.rid, 0))
        self.cmt = min(cmt, self.lst)
        # local recovery: re-apply (flushed, f.cmt] idempotently (§6.1)
        for r in records:
            if self.store.flushed_upto < r.lsn <= self.cmt:
                self.store.apply(r)
        # rebuild 2PC state (prepared txns + locks, logged decisions) from
        # the same scan — a leader promoted after this restart inherits
        # them from the log, not from anyone's memory
        self.txn.reset()
        self.txn.recover(records, self.cmt, self.store.flushed_upto)
        # drop cells outside our range: a SPLIT applied in a prior life
        # detached them, but replaying the shared log re-admits them
        self.store.restrict(self.range.lo, self.range.hi)
        self.queue = {r.lsn: r for r in records if r.lsn > self.cmt}
        self._follower_forced = self.lst   # durable log scanned
        self._reset_batch()
        self.pending_reply.clear()
        self._trace_by_lsn.clear()
        self.acked = {p: 0 for p in self.peers}
        self.insync.clear()
        self.open_for_writes = False
        self.proposed_version.clear()
        self.pending_split = None
        self._pending_member_change = False
        self._suppressed = False     # fresh boots re-join without evidence
        self._leader_seen = self.node.sim.now
        self.role = Role.ELECTING
        self._arm_guard_timer()
        # Stagger the boot-time join by the node's chained-declustering
        # distance from the range's home node.  Cold elections tie on
        # lst=0 and fall to the candidacy-znode sequence, which otherwise
        # always crowns the second-lowest member id — clumping every base
        # range's leadership onto the same few nodes.  A microsecond-scale
        # rotation-ordered stagger makes the winner rotate with the range
        # id instead, spreading leadership round-robin.  Re-elections are
        # unaffected: real lst gaps dominate the tie-break, and the delay
        # is invisible next to the session timeout.
        n = self.node.cluster.cfg.n_nodes
        stagger = ((self.node.node_id - self.rid) % n) * 1e-6
        if stagger > 0.0:
            self.node.sim.schedule(stagger, self._staggered_join)
        else:
            self._join_or_elect()

    def _staggered_join(self) -> None:
        if self.role is Role.ELECTING:
            self._join_or_elect()

    def stop(self) -> None:
        self.role = Role.OFFLINE
        if self._commit_timer is not None:
            self._commit_timer.cancel()
            self._commit_timer = None
        if self._lease_timer is not None:
            self._lease_timer.cancel()
            self._lease_timer = None
        if self._guard_timer is not None:
            self._guard_timer.cancel()
            self._guard_timer = None
        self._lease_until = 0.0
        self._lease_sent.clear()
        self._lease_acks.clear()
        self._fail_read_confirms()
        self._reset_batch()
        self.txn.stop()

    def _reset_batch(self) -> None:
        """Drop the accumulated (not yet proposed) batch.  The records stay
        in `queue`/`pending_reply`/the WAL buffer; regime-change paths
        (`_drop_uncommitted_tail`, crash volatility) settle their fate."""
        self._batch = []
        self._batch_bytes = 0
        if self._batch_timer is not None:
            self._batch_timer.cancel()
            self._batch_timer = None

    # ======================================================== election (§7.2)
    def _join_or_elect(self) -> None:
        if self.role == Role.OFFLINE:
            return
        leader_path = f"{self.base}/leader"
        if self.zk.exists(leader_path):
            leader_id, epoch = self.zk.get(leader_path)
            if leader_id == self.node.node_id:
                # our own stale leader znode (crash + restart faster than
                # session expiry): drop it and start over
                try:
                    self.zk.delete(leader_path)
                except NoNode:
                    pass
                self._join_or_elect()
                return
            self._become_joining_follower(leader_id, epoch)
            return
        self._run_election()

    def _current_round(self) -> int:
        try:
            return self.zk.get(f"{self.base}/epoch")
        except NoNode:
            return 0

    def _majority(self) -> int:
        """Cohort majority; cohorts are briefly 4-wide mid-migration (add
        before remove), where majorities of the old and new member sets
        always intersect — that is what makes single-change
        reconfiguration safe."""
        return (len(self.peers) + 1) // 2 + 1

    def _refresh_membership(self) -> bool:
        """Adopt the registered member set before electing: a replica that
        slept through a MEMBER_CHANGE must not vote under a stale cohort
        (or at all, if it was retired).  Returns False when this replica
        deregistered itself."""
        meta = ranges_mod.get_range_meta(self.zk, self.rid)
        if meta is None:
            return True
        _lo, _hi, members = meta
        me = self.node.node_id
        if me not in members:
            self.log("not in registered member set; deregistering")
            self.node.retire_replica(self.rid)
            return False
        self.peers = tuple(sorted(m for m in members if m != me))
        return True

    def _run_election(self) -> None:
        if self.role == Role.OFFLINE:
            return
        if not self._refresh_membership():
            return
        if self._suppressed and self.cfg.lease_enabled:
            # fenced ex-leader: ZK is reachable (coordination sits outside
            # the data network) and our lst is maximal, so we would win —
            # and stall the range again.  Probe the data network instead;
            # candidacy resumes once a majority answers.
            self.role = Role.ELECTING
            self._probe_connectivity()
            return
        self._minc("elections_started")
        self.role = Role.ELECTING
        self._election_round = self._current_round()
        # Fig. 7 line 1: clean up old state — our prior candidacies and
        # anything stamped with an older round
        for name, (data, _) in self.zk.get_children(f"{self.base}/candidates").items():
            node_id, _lst, rnd = data
            if node_id == self.node.node_id or rnd < self._election_round:
                try:
                    self.zk.delete(f"{self.base}/candidates/{name}")
                except NoNode:
                    pass
        # line 4: advertise our last LSN in an ephemeral sequential znode
        self.zk.create(f"{self.base}/candidates/c",
                       data=(self.node.node_id, self.lst, self._election_round),
                       ephemeral_session=self.node.session,
                       sequential=True)
        self._jrec("elect_start", epoch=self.epoch,
                   round=self._election_round, lst=self.lst)
        self._evaluate_election()

    def _evaluate_election(self, _path: str = "") -> None:
        if self.role is not Role.ELECTING or not self.node.has_session():
            return
        leader_path = f"{self.base}/leader"
        if self.zk.exists(leader_path):
            leader_id, epoch = self.zk.get(leader_path)
            if leader_id != self.node.node_id:
                self._become_joining_follower(leader_id, epoch)
            return
        if self._current_round() != self._election_round:
            # a takeover happened and that leader died already; restart with
            # a fresh candidacy so our advertised lst is current
            self._run_election()
            return
        cands = {n: d for n, (d, cz) in
                 self.zk.get_children(f"{self.base}/candidates").items()
                 if d[2] == self._election_round}
        czxids = {n: cz for n, (d, cz) in
                  self.zk.get_children(f"{self.base}/candidates").items()}
        # lines 5-6: wait for a majority; winner = max n.lst, znode sequence
        # number breaks ties
        if len(cands) < self._majority():
            self.zk.watch_children(f"{self.base}/candidates",
                                   self._evaluate_election)
            return
        winner_name = max(cands, key=lambda n: (cands[n][1], czxids[n]))
        winner_node = cands[winner_name][0]
        if winner_node == self.node.node_id:
            # lines 7-8: atomically claim leadership under a fresh epoch
            new_epoch = self.zk.fetch_and_add(f"{self.base}/epoch", 1, initial=0)
            try:
                self.zk.create(f"{self.base}/leader",
                               data=(self.node.node_id, new_epoch),
                               ephemeral_session=self.node.session)
            except NodeExists:
                leader_id, epoch = self.zk.get(f"{self.base}/leader")
                if leader_id != self.node.node_id:
                    self._become_joining_follower(leader_id, epoch)
                return
            self._jrec("elect_decide", epoch=new_epoch,
                       round=self._election_round,
                       candidates=sorted(d[0] for d in cands.values()),
                       winner=winner_node,
                       winner_lst=cands[winner_name][1],
                       max_lst=max(d[1] for d in cands.values()),
                       n_cohort=len(self.peers) + 1)
            self._start_takeover(new_epoch)
        else:
            # line 11 + liveness: watch for the winner's claim, and for
            # candidate churn (the winner may die before claiming)
            self.zk.watch_children(f"{self.base}/candidates",
                                   self._evaluate_election)
            self.zk.watch_exists(f"{self.base}/leader",
                                 self._evaluate_election)

    def _watch_leader_liveness(self) -> None:
        """Re-elect when the leader's ephemeral znode disappears."""
        leader_path = f"{self.base}/leader"

        def on_change(_p):
            if self.role in (Role.OFFLINE, Role.LEADER, Role.TAKEOVER):
                return
            if not self.zk.exists(leader_path):
                self.log("leader znode gone; (re)electing")
                self._run_election()
            else:
                lid, ep = self.zk.get(leader_path)
                if lid != self.node.node_id and ep > self.epoch:
                    self._become_joining_follower(lid, ep)
                else:
                    self.zk.watch_exists(leader_path, on_change)

        self.zk.watch_exists(leader_path, on_change)

    # ===================================================== leader takeover
    def _start_takeover(self, new_epoch: int) -> None:
        """Fig. 6.  We hold the leader znode; re-commit the unresolved
        window, then open for writes under `new_epoch`."""
        self.epoch = new_epoch
        self.leader_id = self.node.node_id
        self.role = Role.TAKEOVER
        self.open_for_writes = False
        self.insync.clear()
        self.acked = {p: 0 for p in self.peers}
        # the unresolved window (l.cmt, l.lst] is already in self.queue
        # (rebuilt from the durable log in start(), or live from before) —
        # EXCEPT when this election was reached out of a CATCHUP that
        # dropped the volatile tail (an aborted join under a leader that
        # never sent catch-up data, e.g. one-way-partitioned away): the
        # durable, never-truncated copies are still ours to re-commit
        if not self.cfg.bug_takeover_wedge and self.lst > self.cmt \
                and not all(l in self.queue
                            for l in range(self.cmt + 1, self.lst + 1)):
            for rec in (self.node.wal.records_between(
                    self.rid, self.cmt, self.lst) or []):
                self.queue.setdefault(rec.lsn, rec)
            # anything still missing was logically truncated (a superseded
            # tail): don't force peers past what we can actually re-send
            have = max((l for l in self.queue if l > self.cmt),
                       default=self.cmt)
            self.lst = min(self.lst, have)
        self.forced_upto = self.lst        # everything local is durable or inflight->refused on crash
        self._takeover_hi = self.lst
        self._reset_batch()
        self._last_commit_bcast = -1   # first tick re-announces cmt
        self._piggy_sent = -1
        self._watched_peers.clear()
        # rebuild version map + range-op gates from the unresolved queue:
        # an in-flight SPLIT must keep gating writes above the split point
        # across the regime change, else post-takeover writes to moved keys
        # would land above the barrier and be detached away
        self.proposed_version.clear()
        self.pending_split = None
        self._pending_member_change = False
        for lsn in sorted(self.queue):
            rec = self.queue[lsn]
            if rec.op is OpType.SPLIT:
                self.pending_split = (rec.key, rec.columns[0][1])
            elif rec.op is OpType.MEMBER_CHANGE:
                self._pending_member_change = True
            elif rec.op in TXN_OPS:
                # an in-flight prepare must keep its locks gating writes
                # across the regime change; in-flight resolutions keep
                # their txid marked so decides are not double-proposed
                self.txn.stage_from_record(rec)
            else:
                for colname, _value, version in rec.columns:
                    self.proposed_version[(rec.key, colname)] = version
        self._next_seq = lsn_seq(self.lst) + 1
        self._minc("elections_won")
        self.obs.events.emit("leader_takeover", node=self.node.node_id,
                             rid=self.rid, epoch=new_epoch,
                             unresolved=len(self.queue))
        if self.obs.journal.enabled:
            # `missing` = durable, never-truncated records of the unresolved
            # window that takeover did NOT reload into its re-proposal queue
            # — always 0 for a correct takeover; the watchdog flags any gap
            # (the PR 6 takeover-wedge shape) at this very transition
            durable = self.node.wal.range_lsns_between(
                self.rid, self.cmt, self.lst) or []
            self._jrec("takeover", epoch=new_epoch, cmt=self.cmt,
                       lst=self.lst,
                       unresolved=sum(1 for l in self.queue if l > self.cmt),
                       missing=sum(1 for l in durable if l not in self.queue),
                       n_cohort=len(self.peers) + 1)
        # `forced_upto = lst` above re-establishes local durability for the
        # whole queue; traces carried across the regime change would
        # otherwise never see their flush/force milestones again
        now = self.node.sim.now
        for lsn, tr in self._trace_by_lsn.items():
            if lsn in self.queue:
                if tr.t_flush is None:
                    tr.t_flush = now
                if tr.t_forced is None:
                    tr.t_forced = now
        self.log(f"takeover: cmt={fmt_lsn(self.cmt)} lst={fmt_lsn(self.lst)} "
                 f"unresolved={len(self.queue)}")
        for p in self.peers:
            self._send(p, "on_new_leader", epoch=self.epoch,
                       leader=self.node.node_id)
        self._watch_peer_sessions()
        self._arm_commit_timer()
        # takeover grace lease: the previous regime's lease provably lapsed
        # before our deposal/election, so a fresh window starting now is
        # safe; renewals must extend it before it runs out, which doubles
        # as the takeover timeout — a leader elected through ZK while
        # data-partitioned never hears an ack and abdicates instead of
        # squatting on the range
        self._lease_until = self.node.sim.now + self.cfg.lease_duration
        self._jrec("lease_acquire", epoch=new_epoch,
                   until=self._lease_until, grace=True)
        self._lease_sent.clear()
        self._lease_acks.clear()
        self._arm_lease_timer()
        self._renew_lease()

    def _watch_peer_sessions(self) -> None:
        for p in self.peers:
            if p in self._watched_peers:
                continue  # re-invoked after member changes; arm once each
            self._watched_peers.add(p)

            def on_change(_p, peer=p):
                if peer not in self.peers:
                    self._watched_peers.discard(peer)  # retired mid-watch
                    return
                if self.role not in (Role.LEADER, Role.TAKEOVER):
                    return
                if not self.zk.exists(f"/nodes/{peer}"):
                    if peer in self.insync:
                        self.insync.discard(peer)
                        self.acked[peer] = 0
                        self.log(f"follower n{peer} lost (session expired)")
                self.zk.watch_exists(f"/nodes/{peer}", on_change)

            self.zk.watch_exists(f"/nodes/{p}", on_change)

    # --- follower side of takeover / join ------------------------------------
    def _become_joining_follower(self, leader_id: int, epoch: int) -> None:
        """We found an existing leader (restart path §6.1): advertise state,
        wait for catch-up."""
        if epoch < self.epoch or self.role == Role.OFFLINE:
            return
        if epoch == self.epoch and self.leader_id == leader_id \
                and self.role in (Role.CATCHUP, Role.FOLLOWER):
            return  # duplicate announcement (znode watch + NEW_LEADER msg)
        self._step_down()
        self.epoch = epoch
        self.leader_id = leader_id
        self.role = Role.CATCHUP
        self._leader_seen = self.node.sim.now
        self._catchup_seen = self.node.sim.now
        self._jrec("catchup_enter", epoch=epoch, leader=leader_id)
        self._drop_uncommitted_tail()
        self._watch_leader_liveness()
        self._send(leader_id, "on_follower_state", epoch=epoch,
                   follower=self.node.node_id, f_cmt=self.cmt, f_lst=self.lst)

    def on_new_leader(self, epoch: int, leader: int) -> None:
        if self.role == Role.OFFLINE or epoch <= self._announced_leader_epoch \
                or epoch < self.epoch or leader == self.node.node_id:
            return
        self._announced_leader_epoch = epoch
        self._become_joining_follower(leader, epoch)

    def _step_down(self) -> None:
        if self.role in (Role.LEADER, Role.TAKEOVER):
            self.open_for_writes = False
            self._reset_batch()
            if self._commit_timer is not None:
                self._commit_timer.cancel()
                self._commit_timer = None
            if self._lease_timer is not None:
                self._lease_timer.cancel()
                self._lease_timer = None
            self._lease_until = 0.0
            self._lease_sent.clear()
            self._lease_acks.clear()
            self._fail_read_confirms()
            for op, cb, _tr in self.blocked_writes:
                cb(Result(ErrorCode.NOT_LEADER, leader_hint=self.leader_id))
            self.blocked_writes.clear()
            self.txn.on_step_down()

    def _drop_uncommitted_tail(self) -> None:
        """Entering a new regime: pending writes in (cmt, lst] are ambiguous.
        Drop the volatile queue; the durable copies are logically truncated
        when catch-up data arrives (§6.1.1).  The durability watermark must
        retreat with them: a truncated record no longer counts as a stable
        copy, so re-proposals of it must be re-forced before being acked."""
        self.queue = {l: r for l, r in self.queue.items() if l <= self.cmt}
        self._follower_forced = min(self._follower_forced, self.cmt)
        self._trace_by_lsn.clear()   # dropped writes retry with fresh marks
        for lsn in list(self.pending_reply):
            cb = self.pending_reply.pop(lsn)
            cb(Result(ErrorCode.UNAVAILABLE))
        self.txn.drop_uncommitted()

    # ================================== leader leases (cfg.lease_enabled)
    def _lease_tick_period(self) -> float:
        return self.cfg.lease_duration / 4.0

    def _depose_after(self) -> float:
        """Leader silence a follower tolerates before deleting the leader
        znode.  Strictly longer than any lease the silent leader can hold:
        a granted lease ends at renewal-send-time + duration - skew, and
        every acking follower saw that renewal no earlier than it was
        sent, so silence of duration + 4*skew outlives it."""
        return self.cfg.lease_duration + 4.0 * self.cfg.max_clock_skew

    def lease_valid(self) -> bool:
        return (self.cfg.lease_enabled
                and self.node.sim.now <= self._lease_until)

    def _arm_lease_timer(self) -> None:
        if self._lease_timer is not None:
            self._lease_timer.cancel()
        self._lease_timer = self.node.sim.schedule(
            self._lease_tick_period(), self._lease_tick)

    def _lease_tick(self) -> None:
        self._lease_timer = None
        if self.role not in (Role.LEADER, Role.TAKEOVER) \
                or not self.cfg.lease_enabled:
            return
        if self.node.sim.now > self._lease_until:
            why = ("lease lapsed" if self.role is Role.LEADER
                   else "takeover timed out (no data-net quorum)")
            self.obs.events.emit("lease_lapse", node=self.node.node_id,
                                 rid=self.rid, epoch=self.epoch, why=why)
            self._jrec("lease_lapse", epoch=self.epoch, why=why)
            self._abdicate(why, suppress=True)
            return
        prev = self._lease_acks.get(self._lease_seq)
        if prev is not None and len(prev) < self._majority() - 1:
            # the previous renewal round never reached a majority — the
            # lease is burning down; surface it in the cluster event log
            self.obs.events.emit("lease_renew_fail", node=self.node.node_id,
                                 rid=self.rid, epoch=self.epoch,
                                 seq=self._lease_seq, acks=len(prev))
        self._renew_lease()
        self._arm_lease_timer()

    def _renew_lease(self) -> None:
        if not self.cfg.lease_enabled:
            return
        if self._majority() - 1 == 0:
            # single-replica cohort: no follower promises needed
            new_until = (self.node.sim.now
                         + self.cfg.lease_duration - self.cfg.max_clock_skew)
            if new_until > self._lease_until:
                self._lease_until = new_until
                self._jrec("lease_acquire", epoch=self.epoch, until=new_until)
            return
        self._lease_seq += 1
        seq = self._lease_seq
        self._lease_sent[seq] = self.node.sim.now
        self._lease_acks[seq] = set()
        self._jrec("lease_renew", epoch=self.epoch, seq=seq)
        # prune stale rounds (acks for them could no longer extend anything)
        for old in [s for s in self._lease_sent if s < seq - 8]:
            self._lease_sent.pop(old, None)
            self._lease_acks.pop(old, None)
        for p in self.peers:
            self._send(p, "on_lease", nbytes=96, epoch=self.epoch, seq=seq,
                       leader=self.node.node_id)

    def on_lease(self, epoch: int, seq: int, leader: int) -> None:
        """Follower: a lease renewal doubles as a leader heartbeat — ack it
        and push back our deposal clock (the promise not to elect)."""
        if self.role not in (Role.FOLLOWER, Role.CATCHUP) \
                or epoch != self.epoch:
            return
        self._leader_seen = self.node.sim.now
        if self.role is Role.CATCHUP:
            # CATCHUP beats feed the watchdog's starvation monitor: a
            # replica kept alive by heartbeats but starved of catch-up data
            self._jrec("lease_heard", epoch=epoch, role="CATCHUP",
                       leader=leader)
        self._send(leader, "on_lease_ack", nbytes=96, epoch=epoch, seq=seq,
                   follower=self.node.node_id)

    def on_lease_ack(self, epoch: int, seq: int, follower: int) -> None:
        if self.role not in (Role.LEADER, Role.TAKEOVER) \
                or epoch != self.epoch:
            return
        self._peer_seen[follower] = self.node.sim.now
        sent = self._lease_sent.get(seq)
        acks = self._lease_acks.get(seq)
        if sent is None or acks is None:
            return
        acks.add(follower)
        if len(acks) >= self._majority() - 1:
            # the lease window is anchored at the renewal's SEND time: every
            # acking follower promises `_depose_after` of patience measured
            # from a clock that saw the renewal AFTER it was sent
            new_until = sent + self.cfg.lease_duration \
                - self.cfg.max_clock_skew
            if new_until > self._lease_until:
                self._lease_until = new_until
                self._jrec("lease_acquire", epoch=epoch, until=new_until)
                if self._lease_event_epoch != epoch:
                    # event-log satellite: one lease_acquire event per
                    # regime (renewals extend silently; the journal keeps
                    # the per-renewal record)
                    self._lease_event_epoch = epoch
                    self.obs.events.emit(
                        "lease_acquire", node=self.node.node_id,
                        rid=self.rid, epoch=epoch,
                        until=round(new_until, 6))

    _lease_event_epoch = -1

    def _abdicate(self, why: str, suppress: bool) -> None:
        """Fence ourselves out of the leader regime: drop the leader znode
        (if still ours), refuse pending/blocked writes, and go back to
        ELECTING.  The unresolved queue is KEPT — if we legitimately win a
        later election these records are re-proposed exactly like after a
        crash-restart (dropping them here would let `lst` advertise records
        takeover could no longer resolve)."""
        if self.role not in (Role.LEADER, Role.TAKEOVER):
            return
        self.log(f"abdicating: {why}")
        self.obs.events.emit("leader_abdicate", node=self.node.node_id,
                             rid=self.rid, epoch=self.epoch, why=why)
        self._jrec("abdicate", epoch=self.epoch, why=why)
        self._minc("leader_abdications")
        leader_path = f"{self.base}/leader"
        try:
            lid, ep = self.zk.get(leader_path)
            if lid == self.node.node_id and ep == self.epoch:
                self.zk.delete(leader_path)
        except NoNode:
            pass
        self._step_down()
        for lsn in list(self.pending_reply):
            cb = self.pending_reply.pop(lsn)
            cb(Result(ErrorCode.UNAVAILABLE))
        self._trace_by_lsn.clear()
        self._suppressed = suppress and self.cfg.lease_enabled
        self.role = Role.ELECTING
        self._join_or_elect()

    # --- connectivity probes (ping/pong over the data network) -------------
    def on_ping(self, frm: int) -> None:
        if self.role is Role.OFFLINE:
            return
        self._peer_seen[frm] = self.node.sim.now
        self._send(frm, "on_pong", nbytes=96, frm=self.node.node_id)

    def on_pong(self, frm: int) -> None:
        if self.role is Role.OFFLINE:
            return
        self._peer_seen[frm] = self.node.sim.now

    def _fresh_majority_contact(self, window: float = 0.75) -> bool:
        now = self.node.sim.now
        fresh = sum(1 for p in self.peers
                    if now - self._peer_seen.get(p, -1e9) <= window)
        return 1 + fresh >= self._majority()

    def _probe_connectivity(self) -> None:
        """Suppressed ex-leader in ELECTING: ping peers and re-enter the
        join/elect path once a data-network majority answers."""
        if self.role is not Role.ELECTING or not self._suppressed:
            return
        if self._fresh_majority_contact():
            self._suppressed = False
            self.log("data-net majority contact restored; candidacy resumes")
            self._join_or_elect()
            return
        for p in self.peers:
            self._send(p, "on_ping", nbytes=96, frm=self.node.node_id)
        self.node.sim.schedule(0.25, self._probe_connectivity)

    # --- follower watchdog -------------------------------------------------
    def _arm_guard_timer(self) -> None:
        if self._guard_timer is not None:
            self._guard_timer.cancel()
        self._guard_timer = self.node.sim.schedule(0.25, self._guard_tick)

    def _guard_tick(self) -> None:
        self._guard_timer = None
        if self.role is Role.OFFLINE:
            return
        self._arm_guard_timer()
        if self.role not in (Role.FOLLOWER, Role.CATCHUP):
            return
        stale = self.node.sim.now - self._leader_seen
        leader_path = f"{self.base}/leader"
        # bug_catchup_starvation (mutation corpus): the original PR 6 bug
        # paced catch-up retries off `_leader_seen`, which lease heartbeats
        # keep perpetually fresh — so a CATCHUP replica whose data was lost
        # never re-requested it and starved behind a live leader
        catchup_clock = (self._leader_seen if self.cfg.bug_catchup_starvation
                         else self._catchup_seen)
        if self.role is Role.CATCHUP \
                and self.node.sim.now - catchup_clock > 0.6:
            # the catch-up request or its data was lost (flaky link, leader
            # drop): restart the exchange — idempotent, the leader re-syncs
            # us from scratch
            self._catchup_seen = self.node.sim.now   # pace retries
            self._jrec("catchup_retry", epoch=self.epoch)
            if self.leader_id is not None:
                self._send(self.leader_id, "on_follower_state",
                           epoch=self.epoch, follower=self.node.node_id,
                           f_cmt=self.cmt, f_lst=self.lst)
            return
        if not self.cfg.lease_enabled or stale <= self._depose_after() / 2:
            return
        # recover from a lost leader announcement before suspecting anyone
        try:
            lid, ep = self.zk.get(leader_path)
        except NoNode:
            return   # znode already gone; the liveness watch re-elects
        if (lid, ep) != (self.leader_id, self.epoch):
            if ep > self.epoch and lid != self.node.node_id:
                self._become_joining_follower(lid, ep)
            return
        for p in self.peers:
            self._send(p, "on_ping", nbytes=96, frm=self.node.node_id)
        if stale > self._depose_after() and self._fresh_majority_contact():
            # the leader is silent past any lease it could hold, and we can
            # see a cohort majority: depose it so the majority side elects.
            # The get-then-delete pair is atomic here (synchronous ZK model)
            self.log(f"deposing silent leader n{lid} "
                     f"(stale {stale:.2f}s > {self._depose_after():.2f}s)")
            self.obs.events.emit("leader_deposed", node=self.node.node_id,
                                 rid=self.rid, epoch=ep, leader=lid)
            self._jrec("deposed", epoch=ep, leader=lid)
            self._minc("leader_deposals")
            try:
                self.zk.delete(leader_path)
            except NoNode:
                pass

    # --- ZK session flap recovery ------------------------------------------
    def on_session_reestablished(self) -> None:
        """The node's ZK session expired and came back (gray failure): every
        ephemeral we held — leader claim, candidacies, /nodes/<id> — is
        gone, and a leader has dropped us from its in-sync set."""
        if self.role is Role.OFFLINE:
            return
        if self.role in (Role.LEADER, Role.TAKEOVER):
            # our leader znode vanished with the session; a successor may
            # already rule.  No suppression: the data network is fine
            self._abdicate("zk session flapped", suppress=False)
        elif self.role in (Role.FOLLOWER, Role.CATCHUP) \
                and self.leader_id is not None:
            # re-announce so the leader re-syncs us (it zeroed our ack state
            # when /nodes/<id> disappeared)
            self._leader_seen = self.node.sim.now
            self._send(self.leader_id, "on_follower_state", epoch=self.epoch,
                       follower=self.node.node_id, f_cmt=self.cmt,
                       f_lst=self.lst)
        else:
            self._join_or_elect()

    # --- read-index fallback (quorum-confirmed strong reads) ----------------
    def _fail_read_confirms(self) -> None:
        waiting, self._rc_waiting = self._rc_waiting, []
        self._rc_inflight = False
        self._rc_acks.clear()
        for thunk in waiting:
            thunk(False)

    def _confirm_leadership(self, cb: Callable) -> None:
        """Serve a strong read without a valid lease: confirm with a
        follower majority that our regime still stands (one round trip),
        then read locally.  `cb(ok)` fires with the verdict."""
        if self._majority() - 1 == 0:
            cb(True)
            return
        self._rc_waiting.append(cb)
        if self._rc_inflight:
            return
        self._rc_inflight = True
        self._rc_seq += 1
        self._rc_acks.clear()
        seq = self._rc_seq
        for p in self.peers:
            self._send(p, "on_read_confirm", nbytes=96, epoch=self.epoch,
                       seq=seq, leader=self.node.node_id)

        def timeout():
            if self._rc_inflight and self._rc_seq == seq:
                self._fail_read_confirms()

        self.node.sim.schedule(0.5, timeout)

    def on_read_confirm(self, epoch: int, seq: int, leader: int) -> None:
        if self.role not in (Role.FOLLOWER, Role.CATCHUP) \
                or epoch != self.epoch:
            return
        self._leader_seen = self.node.sim.now
        self._send(leader, "on_read_confirm_ack", nbytes=96, epoch=epoch,
                   seq=seq, follower=self.node.node_id)

    def on_read_confirm_ack(self, epoch: int, seq: int, follower: int) -> None:
        if self.role is not Role.LEADER or epoch != self.epoch \
                or seq != self._rc_seq or not self._rc_inflight:
            return
        self._peer_seen[follower] = self.node.sim.now
        self._rc_acks.add(follower)
        if len(self._rc_acks) >= self._majority() - 1:
            waiting, self._rc_waiting = self._rc_waiting, []
            self._rc_inflight = False
            for thunk in waiting:
                thunk(True)

    # --- leader side: follower catch-up (§6.1 + Fig. 6 lines 3-8) ------------
    def on_follower_state(self, epoch: int, follower: int, f_cmt: int,
                          f_lst: int) -> None:
        if self.role not in (Role.LEADER, Role.TAKEOVER) or epoch != self.epoch:
            return
        if follower not in self.peers:
            # a replica retired by a MEMBER_CHANGE it slept through is
            # rejoining: tell it to deregister instead of feeding it data
            self._send(follower, "on_deposed", epoch=self.epoch)
            return
        # a restarted follower must re-sync from scratch
        self.insync.discard(follower)
        self.acked[follower] = 0
        self.log(f"catch-up request from n{follower} "
                 f"(f.cmt={fmt_lsn(f_cmt)} f.lst={fmt_lsn(f_lst)})")
        self._send_catchup(follower, f_cmt, f_lst, first=True)

    def _send_catchup(self, follower: int, f_cmt: int, f_lst: int,
                      first: bool = False) -> None:
        target = self.cmt
        recs = self.node.wal.records_between(self.rid, f_cmt, target)
        if recs is None:
            # log rolled over: source from SSTables (§6.1), synthesising one
            # record per surviving cell — plus any unresolved 2PC records,
            # which carry prepared/decision state data cells cannot
            cells = self.store.cells_with_lsn_above(f_cmt)
            recs = [LogRecord(self.rid, cell.lsn,
                              OpType.DELETE if cell.deleted else OpType.PUT,
                              key, ((colname, cell.value, cell.version),))
                    for key, colname, cell in cells
                    if cell.lsn <= target]
            recs.extend(self.txn.catchup_extras(target))
            recs.sort(key=lambda r: r.lsn)
        nbytes = 128 + sum(r.nbytes() for r in recs)
        self._send(follower, "on_catchup_data", nbytes=nbytes,
                   epoch=self.epoch, records=recs, commit_lsn=target,
                   truncate_from=f_cmt if first else None,
                   truncate_to=f_lst if first else None)

    def on_catchup_synced(self, epoch: int, follower: int, upto: int) -> None:
        if self.role not in (Role.LEADER, Role.TAKEOVER) or epoch != self.epoch:
            return
        if upto < self.cmt:
            # new writes committed while the batch was in flight: send the
            # delta (the paper's "momentarily blocks new writes" final round
            # is subsumed by the gap-forwarding below once upto == cmt)
            self._send_catchup(follower, upto, upto)
            return
        self.insync.add(follower)
        self.acked[follower] = max(self.acked.get(follower, 0), upto)
        # close the in-flight gap: forward pending proposals this follower
        # has not seen (they were proposed while it was out-of-sync) as one
        # batched propose; FIFO links order it before any subsequent propose.
        # Records still sitting in the un-flushed accumulator are excluded —
        # the follower is in-sync now, so the coming flush covers them.
        staged = {r.lsn for r in self._batch}
        pending = [self.queue[l] for l in sorted(self.queue)
                   if l > upto and l not in staged]
        if pending:
            nbytes = sum(r.nbytes() for r in pending) + 64
            self._send(follower, "on_propose", nbytes=nbytes,
                       epoch=self.epoch, records=pending,
                       commit_lsn=self._piggyback())
        self.log(f"follower n{follower} in-sync @ {fmt_lsn(upto)}")
        self._after_quorum_progress()
        self._check_migration()   # a just-synced dst unblocks phase 2

    def _after_quorum_progress(self) -> None:
        if self.role == Role.TAKEOVER and self.insync:
            # Fig. 6 lines 8-10: quorum reached; re-propose (l.cmt, l.lst]
            unresolved = sorted(l for l in self.queue if l > self.cmt)
            self.role = Role.LEADER
            if unresolved:
                self.log(f"re-proposing {len(unresolved)} unresolved writes")
                # records were already forwarded to the in-sync follower by
                # on_catchup_synced's gap-forwarding; commits flow via acks
                self._advance_commit()
            if self.cmt >= self._takeover_hi and not self.open_for_writes:
                self._open_writes()
        elif self.role == Role.LEADER and not self.open_for_writes:
            if self.cmt >= self._takeover_hi:
                self._open_writes()

    def _open_writes(self) -> None:
        self.open_for_writes = True
        self._next_seq = max(self._next_seq, lsn_seq(self.lst) + 1)
        self.obs.events.emit("leader_open", node=self.node.node_id,
                             rid=self.rid, epoch=self.epoch)
        self._jrec("leader_open", epoch=self.epoch, lsn=self.cmt)
        self.log(f"open for writes (next lsn {self.epoch}.{self._next_seq})")
        # self-heal range metadata: a dead leader may have applied a range
        # op without publishing it (idempotent — no version churn when the
        # registered state already matches), then resume any interrupted
        # migration from its intent znode
        ranges_mod.set_range_meta(
            self.zk, self.rid, self.range.lo, self.range.hi,
            tuple(sorted((self.node.node_id,) + self.peers)))
        self.node.cluster.on_range_table_changed()
        self.node.sim.schedule(0.0, self._check_migration)
        # resume 2PC duties: presume-abort orphan intents we coordinate,
        # re-drive logged decisions, re-vote in-doubt prepares
        self.node.sim.schedule(0.0, self.txn.on_leader_open)
        blocked, self.blocked_writes = self.blocked_writes, []
        for op, cb, tr in blocked:
            if isinstance(op, list):                # blocked transaction
                self.client_transaction(op, cb, trace=tr)
            else:
                self.client_write(op, cb, trace=tr)

    # --- follower side: catch-up data -----------------------------------------
    def on_catchup_data(self, epoch: int, records: list[LogRecord],
                        commit_lsn: int, truncate_from: Optional[int],
                        truncate_to: Optional[int]) -> None:
        if self.role not in (Role.CATCHUP, Role.FOLLOWER) or epoch != self.epoch:
            return
        if self.cfg.drop_first_catchup and not self._dropped_catchup:
            # test-only fault hook (chaos/mutations.py): pretend the first
            # catch-up delivery was lost on the wire — the retry logic in
            # _guard_tick must recover; bug_catchup_starvation defeats it
            self._dropped_catchup = True
            return
        self._leader_seen = self.node.sim.now
        self._catchup_seen = self.node.sim.now
        self._suppressed = False   # live data-path contact with the leader
        if truncate_from is not None and truncate_to is not None \
                and truncate_to > truncate_from:
            # §6.1.1 logical truncation: (f.cmt, f.lst] may contain records
            # discarded by the new regime; never re-apply them.  Re-sent
            # records are re-appended afresh (WAL.append un-skips their LSN).
            lsns = self.node.wal.range_lsns_between(self.rid, truncate_from,
                                                    truncate_to)
            self.node.wal.logically_truncate(self.rid, lsns)
            self.lst = min(self.lst, truncate_from)

        fresh = [r for r in records if r.lsn > self.lst]
        e0 = self.epoch

        def complete() -> None:
            if self.role == Role.OFFLINE or self.epoch != e0:
                return
            self._apply_committed(commit_lsn)
            self._jrec("catchup_exit", epoch=self.epoch, lsn=commit_lsn)
            if self.role == Role.CATCHUP:
                self.role = Role.FOLLOWER
            self._send(self.leader_id, "on_catchup_synced",
                       epoch=self.epoch, follower=self.node.node_id,
                       upto=commit_lsn)

        if not fresh:
            complete()
            return
        jr = self.obs.journal
        for i, rec in enumerate(fresh):
            self.queue[rec.lsn] = rec
            self.lst = max(self.lst, rec.lsn)
            if jr.enabled:
                jr.record("append", node=self.node.node_id, rid=self.rid,
                          epoch=lsn_epoch(rec.lsn), lsn=rec.lsn,
                          digest=record_digest(rec), op=rec.op.name,
                          via="catchup")
            last = i == len(fresh) - 1
            self.node.wal.append(rec, force=last, cb=complete if last else None,
                                 component="catchup", rid=self.rid)

    def on_deposed(self, epoch: int) -> None:
        """The leader says we are not in this cohort's member set (we
        missed a MEMBER_CHANGE retiring us while down): drop the replica."""
        if self.role is Role.OFFLINE:
            return
        self.log("deposed: not in the cohort member set; deregistering")
        self.node.retire_replica(self.rid)

    # ===================================================== steady state (§5)
    def _piggyback(self) -> Optional[int]:
        return self.cmt if self.cfg.piggyback_commit else None

    def _owns(self, key: str) -> bool:
        """Does this replica currently serve `key`?  False once the range
        narrowed under a split, or (leader only) once a SPLIT above the
        key is proposed — the barrier must not admit writes that would
        land past it and then be detached away."""
        if not self.range.contains(key):
            return False
        ps = self.pending_split
        return ps is None or key < ps[0]

    def client_write(self, op: WriteOp, reply: Callable,
                     trace=None) -> None:
        if trace is not None:
            trace.t_cpu = self.node.sim.now
        if self.role != Role.LEADER or not self.node.has_session() \
                or (self.cfg.lease_enabled and not self.lease_valid()):
            # a lapsed lease fences writes immediately (abdication follows
            # on the next lease tick): admitting them would let a fenced-off
            # leader queue work that can never commit, stalling clients
            reply(Result(ErrorCode.NOT_LEADER, leader_hint=self.leader_id))
            return
        if not self._owns(op.key):
            self._minc("wrong_range_replies")
            reply(Result(ErrorCode.WRONG_RANGE))
            return
        if not self.open_for_writes:
            self.blocked_writes.append((op, reply, trace))
            return
        if self.txn.lock_owner(op.key) is not None:
            # held by an in-flight cross-range transaction: no-wait policy
            # (core/txn.py) — refuse now, the client's backoff retries
            self.txn.lock_conflicts += 1
            reply(Result(ErrorCode.LOCKED))
            return
        # conditional check against the latest *proposed* version so
        # pipelined writes to one row serialize correctly (§5.1)
        cur = self.proposed_version.get((op.key, op.colname))
        if cur is None:
            cur = self.store.current_version(op.key, op.colname)
        if op.is_conditional and op.expected_version != cur:
            reply(Result(ErrorCode.VERSION_MISMATCH, version=cur))
            return
        if op.op == OpType.MULTI_PUT:
            cols = tuple((c, v, self._bump_version(op.key, c))
                         for c, v in (op.columns or ()))
        elif op.op in (OpType.DELETE, OpType.COND_DELETE):
            cols = ((op.colname, None, self._bump_version(op.key, op.colname)),)
        else:
            cols = ((op.colname, op.value,
                     self._bump_version(op.key, op.colname)),)
        lsn = make_lsn(self.epoch, self._next_seq)
        self._next_seq += 1
        rec = LogRecord(self.rid, lsn, op.op, op.key, cols)
        self.lst = max(self.lst, lsn)
        self.queue[lsn] = rec
        self.pending_reply[lsn] = reply
        if trace is not None:
            trace.lsn = lsn
            self._trace_by_lsn[lsn] = trace
        self.writes_served += 1
        self._heat(rec.nbytes())
        self._batch_append(rec)
        self._maybe_flush_batch()

    def propose_record(self, op: OpType, key: str, columns: tuple = (),
                       txn=None, trace=None) -> LogRecord:
        """Mint an LSN for a single control record (range op / 2PC record)
        and admit it to the replication pipeline: unresolved queue + batch
        accumulator + flush.  One place for the admission invariants that
        client_write spells out inline for data records.  A `trace` rides
        the record's replication milestones (registered before the flush
        below, which may run synchronously)."""
        lsn = make_lsn(self.epoch, self._next_seq)
        self._next_seq += 1
        rec = LogRecord(self.rid, lsn, op, key, columns, txn=txn)
        self.lst = max(self.lst, lsn)
        self.queue[lsn] = rec
        if trace is not None:
            trace.lsn = lsn
            self._trace_by_lsn[lsn] = trace
        self._batch_append(rec)
        self._maybe_flush_batch()
        return rec

    # --- leader-side proposal batching (§5 "batches writes", §C) -----------
    def _batch_append(self, rec: LogRecord) -> None:
        """Stage a record: WAL-buffered (rides along with the next force)
        and queued for the next multi-record propose."""
        self.node.wal.append(rec, force=False)
        jr = self.obs.journal
        if jr.enabled:
            jr.record("append", node=self.node.node_id, rid=self.rid,
                      epoch=lsn_epoch(rec.lsn), lsn=rec.lsn,
                      digest=record_digest(rec), op=rec.op.name)
        self._batch.append(rec)
        self._batch_bytes += rec.nbytes()

    def _maybe_flush_batch(self) -> None:
        cfg = self.cfg
        if not self._batch:
            return
        if cfg.batch != "adaptive" \
                or len(self._batch) >= cfg.batch_max_records \
                or self._batch_bytes >= cfg.batch_max_bytes:
            self._flush_batch()
            return
        if self.node.ingress_draining:
            # mid ingress-drain: later staged writes are about to be
            # admitted in this same CPU batch; on_ingress_drained flushes
            # once, covering all of them with one propose + one force
            return
        if self.node.cpu.busy_until <= self.node.sim.now + 1e-12:
            # CPU queue empty -> no load to amortise against: flush now and
            # keep the unbatched latency profile.  Otherwise writes are
            # arriving faster than they are served; let the batch grow.
            self._flush_batch()
        elif self._batch_timer is None:
            self._batch_timer = self.node.sim.schedule(
                cfg.batch_deadline, self._on_batch_deadline)

    def on_ingress_drained(self) -> None:
        """The node finished serving an ingress batch: flush whatever the
        batched handlers staged (one proposal batch per ingress batch)."""
        if self._batch:
            self._maybe_flush_batch()

    def _on_batch_deadline(self) -> None:
        self._batch_timer = None
        self._flush_batch()

    def _flush_batch(self) -> None:
        """One multi-record propose per in-sync follower ∥ one WAL force
        covering the whole batch (Fig. 4's two parallel arrows, amortised)."""
        if self._batch_timer is not None:
            self._batch_timer.cancel()
            self._batch_timer = None
        batch, self._batch = self._batch, []
        self._batch_bytes = 0
        if not batch or self.role not in (Role.LEADER, Role.TAKEOVER):
            return
        tail = batch[-1].lsn
        e0 = self.epoch
        self.batches_flushed += 1
        self.batched_records += len(batch)
        self._minc("proposal_batches")
        self._minc("proposal_batch_records", len(batch))
        now = self.node.sim.now
        traced = [self._trace_by_lsn[r.lsn] for r in batch
                  if r.lsn in self._trace_by_lsn]
        for tr in traced:
            tr.t_flush = now

        def on_forced():
            # EPOCH-BOUND like the follower path: a force in flight across
            # a regime change must not advance the new regime's watermark
            if self.epoch != e0 or self.role not in (Role.LEADER,
                                                     Role.TAKEOVER):
                return
            for tr in traced:
                tr.t_forced = self.node.sim.now
            self._on_self_forced(tail)
            self._maybe_flush_batch()   # drain what queued during the force

        self.node.wal.force(cb=on_forced, component="wal.force", rid=self.rid)
        nbytes = sum(r.nbytes() for r in batch) + 64
        cl = self._piggyback()
        for f in self.insync:
            self._send_batched(f, "on_propose", nbytes=nbytes,
                               epoch=self.epoch, records=list(batch),
                               commit_lsn=cl)
        if cl is not None and self.insync:
            # every insync follower just learned cmt: the periodic commit
            # broadcast for this watermark is redundant (suppressed in
            # _commit_tick) — the marker stopped paying its own message
            self._piggy_sent = max(self._piggy_sent, cl)

    def client_transaction(self, ops: list, reply: Callable,
                           trace=None) -> None:
        """Multi-operation transaction (§8.2, the paper's sketched
        extension): all ops target this cohort's range; the transaction
        creates multiple log records but invokes the replication protocol
        once, as a batch — consecutive LSNs proposed together, client
        acked when the LAST record commits (commits are in LSN order, so
        the batch is atomic at every replica: a prefix is never visible
        to strong reads because apply happens in one _apply_committed
        sweep only after quorum covers the tail record)."""
        if trace is not None:
            trace.t_cpu = self.node.sim.now
        if self.role != Role.LEADER or not self.node.has_session() \
                or (self.cfg.lease_enabled and not self.lease_valid()):
            reply(Result(ErrorCode.NOT_LEADER, leader_hint=self.leader_id))
            return
        if not all(self._owns(op.key) for op in ops):
            self._minc("wrong_range_replies")
            reply(Result(ErrorCode.WRONG_RANGE))
            return
        if not self.open_for_writes:
            self.blocked_writes.append((ops, reply, trace))
            return
        if self.txn.lock_conflict({op.key for op in ops}):
            self.txn.lock_conflicts += 1
            reply(Result(ErrorCode.LOCKED))
            return
        # validate every conditional against latest proposed state FIRST —
        # any mismatch aborts the whole transaction with nothing proposed
        for op in ops:
            cur = self.proposed_version.get((op.key, op.colname))
            if cur is None:
                cur = self.store.current_version(op.key, op.colname)
            if op.is_conditional and op.expected_version != cur:
                reply(Result(ErrorCode.VERSION_MISMATCH, version=cur))
                return
        records = []
        tail_lsn = make_lsn(self.epoch, self._next_seq + len(ops) - 1)
        for op in ops:
            if op.op in (OpType.DELETE, OpType.COND_DELETE):
                cols = ((op.colname, None,
                         self._bump_version(op.key, op.colname)),)
            else:
                cols = ((op.colname, op.value,
                         self._bump_version(op.key, op.colname)),)
            lsn = make_lsn(self.epoch, self._next_seq)
            self._next_seq += 1
            rec = LogRecord(self.rid, lsn, op.op, op.key, cols,
                            txn_tail=tail_lsn)
            self.lst = max(self.lst, lsn)
            self.queue[lsn] = rec
            records.append(rec)
        self.writes_served += 1
        self._heat(sum(r.nbytes() for r in records))
        # client acked on the LAST record's commit (atomic prefix rule);
        # the records ride the shared batch accumulator — atomicity comes
        # from txn_tail in _apply_committed, not from sharing one force
        self.pending_reply[records[-1].lsn] = reply
        if trace is not None:
            trace.lsn = records[-1].lsn
            self._trace_by_lsn[records[-1].lsn] = trace
        for rec in records:
            self._batch_append(rec)
        self._maybe_flush_batch()

    def _bump_version(self, key: str, colname: str) -> int:
        cur = self.proposed_version.get((key, colname))
        if cur is None:
            cur = self.store.current_version(key, colname)
        self.proposed_version[(key, colname)] = cur + 1
        return cur + 1

    def _on_self_forced(self, lsn: int) -> None:
        if self.role not in (Role.LEADER, Role.TAKEOVER):
            return
        self.forced_upto = max(self.forced_upto, lsn)
        self._jrec("flush", epoch=self.epoch, lsn=self.forced_upto)
        self._advance_commit()

    def on_propose(self, epoch: int, records: list[LogRecord],
                   commit_lsn: Optional[int]) -> None:
        """A leader batch: log every fresh record, force ONCE covering the
        whole batch, reply with one cumulative ack (the durability
        watermark — it supersedes every lower ack)."""
        if self.role is not Role.FOLLOWER or epoch != self.epoch:
            return
        self._leader_seen = self.node.sim.now
        fresh: list[LogRecord] = []
        dup = False
        for record in records:
            if record.lsn <= self._follower_forced or record.lsn <= self.cmt:
                dup = True      # durable duplicate (gap-forward overlap)
            elif record.lsn in self.queue:
                pass  # logged already; that batch's in-flight force acks it
            else:
                self.queue[record.lsn] = record
                self.lst = max(self.lst, record.lsn)
                fresh.append(record)
        if fresh:
            e0 = self.epoch
            tail = fresh[-1].lsn
            if self.cfg.bug_ack_before_force:
                # mutation corpus: claim durability the moment the batch
                # arrives, before our WAL force completes — the ack the
                # commit rule counts is a lie until the force lands
                self._ack(tail)
            jr = self.obs.journal
            for i, record in enumerate(fresh):
                if jr.enabled:
                    jr.record("append", node=self.node.node_id, rid=self.rid,
                              epoch=lsn_epoch(record.lsn), lsn=record.lsn,
                              digest=record_digest(record), op=record.op.name,
                              via="propose")
                last = i == len(fresh) - 1
                self.node.wal.append(
                    record, force=last,
                    cb=(lambda: self._on_follower_forced(tail, e0))
                    if last else None,
                    component="wal.force", rid=self.rid)
        elif dup:
            # nothing new to force: re-ack the watermark
            self._ack(max(self._follower_forced, self.cmt))
        if commit_lsn is not None:
            before = self.cmt
            self._apply_committed(min(commit_lsn, self.lst))
            if self.cmt > before:
                # piggybacked commit progress: persist the marker exactly
                # as a dedicated on_commit broadcast would have
                self.node.wal.append(CommitMarker(self.rid, self.cmt),
                                     force=False)

    _follower_forced = 0
    _dropped_catchup = False   # drop_first_catchup fault-hook latch

    def _on_follower_forced(self, lsn: int, epoch: int) -> None:
        """Durability callback, EPOCH-BOUND: a force that was in flight
        when the regime changed must not ack into the new epoch — the
        records it covers may have just been logically truncated (the
        async-callback-across-regimes hazard the paper's TCP assumption
        hides; see EXPERIMENTS.md §Paper-deviations)."""
        if epoch != self.epoch:
            return
        self._follower_forced = max(self._follower_forced, lsn)
        self._jrec("flush", epoch=self.epoch, lsn=self._follower_forced)
        # forces are FIFO and proposes arrive in LSN order, so the
        # watermark is the highest *contiguous* durable LSN: ack it once
        # for the whole batch instead of once per record
        self._ack(self._follower_forced)

    def _ack(self, lsn: int) -> None:
        if self.role is not Role.FOLLOWER:
            return
        self.acks_sent += 1
        self._jrec("ack", epoch=self.epoch, lsn=lsn)
        self._send_batched(self.leader_id, "on_ack", epoch=self.epoch,
                           follower=self.node.node_id, lsn=lsn, nbytes=96)

    def on_ack(self, epoch: int, follower: int, lsn: int) -> None:
        """Cumulative: `lsn` is the follower's durability watermark; it
        covers everything at or below it, so max() is the whole merge."""
        if self.role not in (Role.LEADER, Role.TAKEOVER) or epoch != self.epoch:
            return
        if follower not in self.insync:
            return
        self.acked[follower] = max(self.acked.get(follower, 0), lsn)
        self._advance_commit()

    def _advance_commit(self) -> None:
        """Commit rule (Fig. 4): a write commits once the *leader's* log
        force completed AND enough followers acked that a majority of the
        cohort holds it — for the paper's 3-replica cohorts that is
        min(own forced, max follower ack); mid-migration the cohort is
        briefly 4-wide and the rule generalizes to the (majority-1)-th
        highest follower ack.  Acks and forces are per-node prefix-closed
        (FIFO links, in-order forces)."""
        if self.role not in (Role.LEADER, Role.TAKEOVER):
            return  # may arrive deferred, after a step-down
        acks = sorted((self.acked.get(f, 0) for f in self.insync),
                      reverse=True)
        need = self._majority() - 1          # follower acks beside our force
        best = acks[need - 1] if len(acks) >= need else 0
        new_cmt = min(self.forced_upto, best)
        if new_cmt <= self.cmt:
            return
        self._jrec("commit", epoch=self.epoch, lsn=new_cmt,
                   n_cohort=len(self.peers) + 1)
        self._apply_committed(new_cmt)
        self._after_quorum_progress()

    def _apply_committed(self, upto: int) -> None:
        """Apply queue entries in LSN order through `upto`; leader replies to
        clients here (the write is now durable on a majority).

        Multi-op transactions (§8.2): a batch becomes visible atomically —
        if `upto` lands inside a batch (tail not yet quorum-covered), apply
        stops before the batch's first record (cmt is held back, which is
        protocol-safe: it is a conservative commit watermark)."""
        if upto <= self.cmt:
            return
        for lsn in sorted(l for l in self.queue if self.cmt < l <= upto):
            rec = self.queue[lsn]
            if rec.txn_tail and rec.txn_tail > upto:
                upto = lsn - 1 if lsn - 1 > self.cmt else self.cmt
                break
        if upto <= self.cmt:
            return
        for lsn in sorted(l for l in self.queue if self.cmt < l <= upto):
            rec = self.queue.pop(lsn)
            tr = self._trace_by_lsn.pop(lsn, None)
            if tr is not None:
                tr.t_commit = self.node.sim.now
                # the ack leaves through the node's reply envelope this
                # same instant (coalescing merges simultaneous acks, it
                # never delays one) — the ack_coalesce stage records that
                tr.t_acked = self.node.sim.now
            self.cmt = lsn   # range ops read cmt; keep it current in-loop
            if rec.op is OpType.SPLIT:
                self._apply_split(rec)
            elif rec.op is OpType.MEMBER_CHANGE:
                self._apply_member_change(rec)
                if self.role is Role.OFFLINE:
                    return   # the change retired this very replica
            elif rec.op in TXN_OPS:
                # 2PC state transition (core/txn.py): every replica applies
                # it at the same log position — prepares install locks +
                # staged writes, commits make them visible atomically
                self.txn.apply_record(rec)
            else:
                self.store.apply(rec)
            self.commits += 1
            cb = self.pending_reply.pop(lsn, None)
            if cb is not None:
                ver = rec.columns[0][2] if rec.columns else None
                cb(Result(ErrorCode.OK, version=ver))
        self.cmt = upto
        self._jrec("commit_idx", epoch=self.epoch, lsn=upto)
        flushed = self.store.maybe_flush(self.cmt)
        if flushed is not None:
            self.node.wal.note_flushed(self.rid, flushed)

    # ============================================ range management (ranges.py)
    def propose_split(self, split_key: Optional[str] = None) -> bool:
        """Live range split: run a SPLIT record through the normal Paxos
        pipeline as a barrier.  Every replica that applies it forks the
        child range locally with zero data copy; the child cohort (same
        members) then elects its own leader.  Returns False when this
        replica cannot split right now (not an open leader, another range
        op in flight, or nothing to split)."""
        if self.role is not Role.LEADER or not self.open_for_writes \
                or not self.node.has_session():
            return False
        if self.pending_split is not None or self._pending_member_change \
                or self.zk.exists(ranges_mod.migration_path(self.rid)):
            return False
        if self.txn.has_participant_state():
            # an unresolved 2PC transaction has staged writes pinned to
            # keys of this range; a split barrier could detach them away
            # from the replica holding the prepared state
            return False
        if split_key is None:
            split_key = self.store.median_key(self.range.lo, self.range.hi)
        if split_key is None or split_key <= self.range.lo \
                or not self.range.contains(split_key):
            return False
        child_rid = ranges_mod.alloc_range_id(
            self.zk, self.node.cluster.n_base_ranges)
        ranges_mod.seed_child_epoch(self.zk, child_rid, self.epoch)
        self.pending_split = (split_key, child_rid)
        self.propose_record(OpType.SPLIT, split_key,
                            (("child_rid", child_rid, 0),))
        self.log(f"SPLIT proposed at {split_key!r} -> child r{child_rid}")
        return True

    def _propose_member_change(self, members: tuple[int, ...]) -> bool:
        """One committed membership change at a time (Raft-style single-
        server reconfiguration: old/new majorities always intersect)."""
        if self.role is not Role.LEADER or not self.open_for_writes \
                or not self.node.has_session():
            return False
        if self.pending_split is not None or self._pending_member_change:
            return False
        members = tuple(sorted(set(members)))
        if self.node.node_id not in members or len(members) < 2:
            return False
        self._pending_member_change = True
        self.propose_record(OpType.MEMBER_CHANGE, "",
                            (("members", members, 0),))
        self.log(f"MEMBER_CHANGE proposed: {members}")
        return True

    def start_migration(self, src: int, dst: int) -> bool:
        """Move this range's replica from `src` to `dst` (§6 machinery as
        a migration primitive): record the intent in coordination, ADD dst
        (snapshot + WAL catch-up brings it in-sync), then — gated on dst
        being in-sync — RETIRE src.  A leader elected mid-migration picks
        the intent back up in `_check_migration`."""
        me = self.node.node_id
        if self.role is not Role.LEADER or not self.open_for_writes \
                or not self.node.has_session():
            return False
        if src == me or src not in self.peers or dst == me \
                or dst in self.peers or dst not in self.node.cluster.nodes:
            return False
        if self.pending_split is not None or self._pending_member_change:
            return False
        try:
            self.zk.create(ranges_mod.migration_path(self.rid),
                           data=(src, dst))
        except NodeExists:
            return False   # a migration is already in flight
        if not self._propose_member_change((me,) + self.peers + (dst,)):
            try:
                self.zk.delete(ranges_mod.migration_path(self.rid))
            except NoNode:
                pass
            return False
        self.obs.events.emit("migration_start", rid=self.rid, src=src,
                             dst=dst)
        self.log(f"migration started: n{src} -> n{dst}")
        return True

    def _check_migration(self) -> None:
        """Drive a recorded migration one step forward.  Idempotent and
        cheap; called after member changes apply, after followers sync,
        and from the commit tick so a freshly elected leader resumes an
        interrupted move unaided."""
        if self.role is not Role.LEADER or not self.open_for_writes \
                or not self.node.has_session():
            return
        try:
            src, dst = self.zk.get(ranges_mod.migration_path(self.rid))
        except NoNode:
            return
        if self._pending_member_change or self.pending_split is not None:
            return
        me = self.node.node_id
        members = (me,) + self.peers
        if src == me:
            # failover elected the retire target itself: abort the move by
            # removing the half-joined destination, never ourselves
            try:
                self.zk.delete(ranges_mod.migration_path(self.rid))
            except NoNode:
                pass
            self.obs.events.emit("migration_abort", rid=self.rid, src=src,
                                 dst=dst)
            self.log(f"migration aborted (leader is retire target n{src})")
            if dst in self.peers:
                self._propose_member_change(
                    tuple(m for m in members if m != dst))
            return
        if dst not in members:
            # phase 1 (ADD) was lost with the old leader: re-propose it
            self._propose_member_change(members + (dst,))
            return
        if src in members:
            # phase 2 gate: retire src only once dst holds everything
            # committed — otherwise a post-migration majority could exclude
            # every holder of acknowledged writes
            if dst in self.insync and self.acked.get(dst, 0) >= self.cmt:
                self._propose_member_change(
                    tuple(m for m in members if m != src))
            return
        # both phases committed: the move is complete
        try:
            self.zk.delete(ranges_mod.migration_path(self.rid))
        except NoNode:
            pass
        self.obs.events.emit("migration_complete", rid=self.rid, src=src,
                             dst=dst)
        self.log(f"migration complete: n{src} -> n{dst}")

    def _apply_split(self, rec: LogRecord) -> None:
        """Apply a committed SPLIT: narrow our range, fork the child range
        locally (zero copy), and register the child's metadata.  Runs on
        every replica at the same log position, so all three forks carry
        identical state."""
        split_key = rec.key
        child_rid = rec.columns[0][1]
        if self.pending_split is not None \
                and self.pending_split[1] == child_rid:
            self.pending_split = None
        if split_key <= self.range.lo or not self.range.contains(split_key):
            return   # replay of a split this replica already performed
        child_hi = self.range.hi
        members = tuple(sorted((self.node.node_id,) + self.peers))
        self.range = KeyRange(self.rid, self.range.lo, split_key)
        child_range = KeyRange(child_rid, split_key, child_hi)
        child_store = self.store.detach_range(split_key, child_hi,
                                              fork_lsn=rec.lsn)
        for kc in [kc for kc in self.proposed_version
                   if not self.range.contains(kc[0])]:
            del self.proposed_version[kc]
        self.obs.events.emit("split_applied", node=self.node.node_id,
                             rid=self.rid, child_rid=child_rid,
                             split_key=split_key)
        self._jrec("split", epoch=lsn_epoch(rec.lsn), lsn=rec.lsn,
                   child=child_rid, split_key=split_key,
                   n_cohort=len(members))
        self.log(f"SPLIT applied at {split_key!r}: forked child r{child_rid}"
                 f" [{split_key!r}, {child_hi!r})")
        # registration is idempotent — the first applier wins, later
        # repliers (and the leader's open-writes self-heal) no-op
        ranges_mod.seed_child_epoch(self.zk, child_rid, lsn_epoch(rec.lsn))
        ranges_mod.set_range_meta(self.zk, child_rid, split_key, child_hi,
                                  members)
        ranges_mod.set_range_meta(self.zk, self.rid, self.range.lo,
                                  split_key, members)
        self.node.fork_child_replica(child_range, self.peers, child_store,
                                     fork_lsn=rec.lsn)
        self.node.cluster.on_range_table_changed()

    def _apply_member_change(self, rec: LogRecord) -> None:
        """Apply a committed MEMBER_CHANGE: adopt the new member set, or
        retire this replica if it is no longer part of it."""
        members = tuple(rec.columns[0][1])
        me = self.node.node_id
        self._pending_member_change = False
        self._jrec("member_change", epoch=lsn_epoch(rec.lsn), lsn=rec.lsn,
                   members=sorted(members))
        if me not in members:
            meta = ranges_mod.get_range_meta(self.zk, self.rid)
            if meta is not None and me in meta[2]:
                # stale record replayed through catch-up, superseded by a
                # later re-add: adopt the registered set instead
                self.peers = tuple(sorted(m for m in meta[2] if m != me))
                self._jrec("member_change", epoch=lsn_epoch(rec.lsn),
                           lsn=rec.lsn, members=sorted(meta[2]),
                           superseded=True)
                return
            self.log(f"retired from cohort (members now {members})")
            if self.role in (Role.LEADER, Role.TAKEOVER):
                # abdicate cleanly so the cohort elects without waiting
                # out our session
                try:
                    self.zk.delete(f"{self.base}/leader")
                except NoNode:
                    pass
            ranges_mod.set_range_meta(self.zk, self.rid, self.range.lo,
                                      self.range.hi, members)
            self.node.cluster.on_range_table_changed()
            self.node.retire_replica(self.rid)
            return
        new_peers = tuple(sorted(m for m in members if m != me))
        removed = set(self.peers) - set(new_peers)
        added = set(new_peers) - set(self.peers)
        self.peers = new_peers
        self.log(f"member change applied: members={members}")
        if self.role in (Role.LEADER, Role.TAKEOVER):
            for r in removed:
                self.insync.discard(r)
                self.acked.pop(r, None)
            for a in added:
                self.acked.setdefault(a, 0)
            ranges_mod.set_range_meta(self.zk, self.rid, self.range.lo,
                                      self.range.hi, members)
            self.node.cluster.on_range_table_changed()
            self._watch_peer_sessions()
            # the quorum size may have shrunk (commit can advance) and the
            # migration may have its next phase due; both re-enter the
            # commit path, so run them after this apply sweep finishes
            self.node.sim.schedule(0.0, self._advance_commit)
            self.node.sim.schedule(0.0, self._check_migration)

    # --- periodic async commit messages (§5) -----------------------------------
    def _arm_commit_timer(self) -> None:
        if self._commit_timer is not None:
            self._commit_timer.cancel()
        self._commit_timer = self.node.sim.schedule(
            self.cfg.commit_period, self._commit_tick)

    _IDLE_REBCAST_TICKS = 20   # slow keepalive so a dropped broadcast heals

    def _commit_tick(self) -> None:
        if self.role not in (Role.LEADER, Role.TAKEOVER):
            return
        if self.cmt != self._last_commit_bcast:
            # progress: persist the marker, and broadcast unless the
            # watermark already piggybacked on a proposal batch to every
            # insync follower (then the dedicated message is pure overhead)
            self._last_commit_bcast = self.cmt
            self._idle_ticks = 0
            self.node.wal.append(CommitMarker(self.rid, self.cmt), force=False)
            if self._piggy_sent < self.cmt:
                for f in self.insync:
                    self._send_batched(f, "on_commit", epoch=self.epoch,
                                       commit_lsn=self.cmt, nbytes=96)
        else:
            # idle range: skip the marker append and the broadcast, except
            # for a slow keepalive rebroadcast (messages only, no append) so
            # a follower that missed the single progress broadcast — e.g.
            # through a brief partition — still converges
            self._idle_ticks += 1
            if self._idle_ticks >= self._IDLE_REBCAST_TICKS:
                self._idle_ticks = 0
                for f in self.insync:
                    self._send_batched(f, "on_commit", epoch=self.epoch,
                                       commit_lsn=self.cmt, nbytes=96)
        self._check_migration()   # heartbeat-paced migration resume
        self._arm_commit_timer()

    _idle_ticks = 0

    def on_commit(self, epoch: int, commit_lsn: int) -> None:
        if self.role is not Role.FOLLOWER or epoch != self.epoch:
            return
        self._leader_seen = self.node.sim.now
        before = self.cmt
        self._apply_committed(min(commit_lsn, self.lst))
        if self.cmt > before:
            # persist only actual progress; a duplicate broadcast must not
            # re-append an identical marker
            self.node.wal.append(CommitMarker(self.rid, self.cmt), force=False)

    # ===================================================== reads (§3, §5)
    def _read_gate(self, consistent: bool) -> Optional[Result]:
        """Role/session gate shared by single and batched reads."""
        if consistent:
            # strong reads are served only by a live leader (§5)
            if self.role is not Role.LEADER or not self.node.has_session():
                return Result(ErrorCode.NOT_LEADER,
                              leader_hint=self.leader_id)
        else:
            # timeline reads: any replica with a recovered store (§8.1 —
            # available with just 1 node up)
            if self.role is Role.OFFLINE:
                return Result(ErrorCode.UNAVAILABLE)
        return None

    def _read_one(self, key: str, colname: str, consistent: bool,
                  reply: Callable) -> None:
        if not self.range.contains(key):
            # the key moved to a child range (split narrowed this range);
            # the client must refresh its range table.  A merely *pending*
            # split does not gate reads — the data is still here and the
            # barrier only has to keep writes from landing above it.
            self._minc("wrong_range_replies")
            reply(Result(ErrorCode.WRONG_RANGE))
            return
        if consistent:
            owner = self.txn.lock_owner(key)
            if owner is not None:
                # mid-2PC key: defer until the transaction resolves so a
                # strong read never observes in-doubt state (readers hold
                # no locks, so waiting cannot deadlock)
                self.txn.defer_read(owner, key, colname, reply)
                return
        self.reads_served += 1
        self._heat()
        # Store.get contract: deletes surface as tombstone cells, not None
        # — report NOT_FOUND but keep the tombstone's version so clients
        # can conditional-put over a deleted key
        cell = self.store.get(key, colname)
        assert cell is None or not (cell.deleted and cell.value is not None)
        if cell is None or cell.deleted:
            reply(Result(ErrorCode.NOT_FOUND,
                         version=cell.version if cell else 0))
        else:
            reply(Result(ErrorCode.OK, value=cell.value, version=cell.version))

    def client_read(self, key: str, colname: str, consistent: bool,
                    reply: Callable) -> None:
        gate = self._read_gate(consistent)
        if gate is not None:
            reply(gate)
            return
        if consistent and not self.lease_valid():
            # no (valid) lease: fall back to a read-index round — confirm
            # with a follower majority that this regime still stands, then
            # read locally.  With a lease the round trip is skipped entirely
            self._confirm_leadership(
                lambda ok: self._read_one(key, colname, consistent, reply)
                if ok and self.role is Role.LEADER
                else reply(Result(ErrorCode.NOT_LEADER,
                                  leader_hint=self.leader_id)))
            return
        self._read_one(key, colname, consistent, reply)

    def client_multi_read(self, pairs: list[tuple[str, str]],
                          consistent: bool, reply: Callable) -> None:
        """Batched read service: one message covers every (key, colname)
        this range serves for a client `multi_get` — the read-side
        analogue of proposal batching (per-message CPU overhead is paid
        once for the batch).  Replies with an ordered list of Results;
        a single Result means a whole-batch gate failure (retry/redirect).
        Individual deferred reads (2PC locks) hold only their own slot."""
        gate = self._read_gate(consistent)
        if gate is not None:
            reply(gate)
            return
        if consistent and not self.lease_valid():
            self._confirm_leadership(
                lambda ok: self._serve_multi_read(pairs, consistent, reply)
                if ok and self.role is Role.LEADER
                else reply(Result(ErrorCode.NOT_LEADER,
                                  leader_hint=self.leader_id)))
            return
        self._serve_multi_read(pairs, consistent, reply)

    def _serve_multi_read(self, pairs: list[tuple[str, str]],
                          consistent: bool, reply: Callable) -> None:
        results: list[Optional[Result]] = [None] * len(pairs)
        pending = [len(pairs)]

        def one(i: int) -> Callable:
            def got(res: Result) -> None:
                results[i] = res
                pending[0] -= 1
                if pending[0] == 0:
                    reply(results)
            return got

        for i, (key, colname) in enumerate(pairs):
            self._read_one(key, colname, consistent, one(i))

    # ================================== cross-range 2PC (core/txn.py)
    def client_txn2(self, groups: dict, reply: Callable,
                    trace=None) -> None:
        self.txn.client_txn2(groups, reply, trace=trace)

    def on_txn_prepare(self, txid: str, coord_rid: int, ops: list) -> None:
        self.txn.on_txn_prepare(txid, coord_rid, ops)

    def on_txn_vote(self, txid: str, prid: int, ok: bool, versions,
                    reason: str) -> None:
        self.txn.on_txn_vote(txid, prid, ok, versions, reason)

    def on_txn_decide(self, txid: str, coord_rid: int, commit: bool) -> None:
        self.txn.on_txn_decide(txid, coord_rid, commit)

    def on_txn_decided_ack(self, txid: str, prid: int) -> None:
        self.txn.on_txn_decided_ack(txid, prid)
