"""Shared write-ahead log (one per node, shared by the node's 3 cohorts).

Implements the paper's §4.1/§6 log semantics on the simulator:

- records from multiple cohorts interleave in one physical log, each cohort
  using its own logical LSN sequence;
- group commit: concurrent force requests coalesce into one device force
  (`Disk.force` models this);
- *non-forced* appends (commit markers) become durable when any later force
  completes;
- crash loses the un-forced tail; durable records survive;
- *logical truncation* (§6.1.1): per-range skipped-LSN lists, persisted,
  consulted by local recovery so discarded records are never re-applied;
- segment rollover + GC once every record in a segment is captured in an
  SSTable (tracked via per-range `flushed_upto`).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional, Union

from .sim import Disk, Simulator
from .types import CommitMarker, LogRecord

Entry = Union[LogRecord, CommitMarker]


@dataclass
class _Pending:
    entry: Entry
    forced: bool
    cb: Optional[Callable]


class WAL:
    def __init__(self, sim: Simulator, disk: Disk, segment_bytes: int = 1 << 20):
        self.sim = sim
        self.disk = disk
        self.segment_bytes = segment_bytes

        # Durable state (survives crash):
        self.durable: list[Entry] = []
        self.durable_bytes = 0
        # per-range skipped-LSN lists, persisted out-of-band (§6.1.1 "saved to
        # a known location on disk")
        self.skipped: dict[int, set[int]] = {}
        # per-range flushed-to-SSTable watermark (enables segment GC)
        self.flushed_upto: dict[int, int] = {}
        # GC low-water mark: durable entries with index < gc_index discarded
        self._gc_dropped_upto: dict[int, int] = {}
        # per-range GC floor (core/txn.py): records at or above the floor
        # are pinned — an unresolved 2PC prepare/decision must survive in
        # the log until it resolves, whatever the SSTable watermark says
        self.gc_floor: dict[int, int] = {}

        # Volatile state (lost on crash):
        self._buffer: list[_Pending] = []
        self.appends = 0
        # observability hook: called as (kind, range_id, lsn) on GC-floor
        # pin/release transitions (wired by the owning node)
        self.on_gc_event: Optional[Callable[[str, int, Optional[int]], None]] \
            = None

    # -- write path ---------------------------------------------------------
    def append(self, entry: Entry, force: bool, cb: Optional[Callable] = None,
               component: str = "wal.force",
               rid: Optional[int] = None) -> None:
        """Append an entry.  If `force`, `cb()` fires when it is durable.
        Non-forced entries ride along with the next force (commit markers).
        `component`/`rid` label the resulting device force for the resource
        profiler (e.g. catch-up installs vs the normal data path)."""
        self.appends += 1
        if isinstance(entry, LogRecord):
            # re-appending an LSN supersedes an earlier logical truncation of
            # it (catch-up re-sends committed writes; the fresh durable copy
            # must be replayed by future local recovery)
            sk = self.skipped.get(entry.range_id)
            if sk is not None:
                sk.discard(entry.lsn)
        self._buffer.append(_Pending(entry, force, cb))
        if force:
            self.force(component=component, rid=rid)

    def force(self, cb: Optional[Callable] = None,
              component: str = "wal.force",
              rid: Optional[int] = None) -> None:
        """Force the buffered tail to disk with one device write; `cb()`
        fires when every buffered entry (and everything forced before it —
        the device is FIFO) is durable.  This is the leader-side batch
        force: a batch is appended record-by-record with `force=False` and
        covered by a single `force(cb)` at flush time.  An empty buffer
        still issues a zero-byte barrier so `cb` orders after any force
        already in flight."""
        batch = self._buffer
        self._buffer = []
        nbytes = sum(self._entry_bytes(p.entry) for p in batch)

        def on_durable():
            for p in batch:
                self.durable.append(p.entry)
                self.durable_bytes += self._entry_bytes(p.entry)
            for p in batch:
                if p.cb is not None:
                    p.cb()
            if cb is not None:
                cb()

        self.disk.force(nbytes, on_durable, component=component, rid=rid)

    @staticmethod
    def _entry_bytes(entry: Entry) -> int:
        return entry.nbytes() if isinstance(entry, LogRecord) else 16

    # -- crash/recovery -----------------------------------------------------
    def crash(self) -> None:
        """Lose the un-forced tail and any in-flight force callbacks."""
        self._buffer.clear()
        self.disk.crash()

    def recover_range(self, range_id: int) -> tuple[list[LogRecord], int]:
        """Scan the durable log for one range.

        Returns (records, last_committed_lsn) where `records` excludes
        logically-truncated LSNs.  In practice all 3 of a node's cohorts are
        recovered in one shared scan (§6); callers loop over ranges which is
        observationally identical.
        """
        skipped = self.skipped.get(range_id, set())
        records: list[LogRecord] = []
        cmt = 0
        for e in self.durable:
            if isinstance(e, LogRecord) and e.range_id == range_id:
                if e.lsn not in skipped:
                    records.append(e)
            elif isinstance(e, CommitMarker) and e.range_id == range_id:
                cmt = max(cmt, e.commit_lsn)
        return records, cmt

    def seed_range(self, range_id: int, fork_lsn: int) -> None:
        """Durably seed a forked child range's log state (§4-style live
        split).  Called while applying the parent's SPLIT record — which is
        already durable on this node — so the seed is modeled as riding
        that force: a commit marker at `fork_lsn` plus watermarks that send
        any catch-up request below `fork_lsn` to the SSTable/snapshot path
        (the child's log holds nothing below the fork point)."""
        self.durable.append(CommitMarker(range_id, fork_lsn))
        self.durable_bytes += 16
        self.flushed_upto[range_id] = max(
            self.flushed_upto.get(range_id, 0), fork_lsn)
        self._gc_dropped_upto[range_id] = max(
            self._gc_dropped_upto.get(range_id, 0), fork_lsn)

    def set_gc_floor(self, range_id: int, lsn: Optional[int]) -> None:
        """Pin (or release, with None) a range's GC floor: durable records
        with `lsn >= floor` are never garbage-collected.  Maintained by the
        transaction manager around unresolved 2PC state."""
        had = range_id in self.gc_floor
        if lsn is None:
            self.gc_floor.pop(range_id, None)
            if had and self.on_gc_event is not None:
                self.on_gc_event("gc_floor_release", range_id, None)
        else:
            self.gc_floor[range_id] = lsn
            if not had and self.on_gc_event is not None:
                self.on_gc_event("gc_floor_pin", range_id, lsn)

    def forget_range(self, range_id: int) -> None:
        """Drop a range's log state after its replica left this node
        (migration retire): records, markers, and watermarks."""
        keep = [e for e in self.durable if getattr(e, "range_id", None) != range_id]
        self.durable_bytes -= sum(self._entry_bytes(e) for e in self.durable
                                  if getattr(e, "range_id", None) == range_id)
        self.durable = keep
        self._buffer = [p for p in self._buffer
                        if getattr(p.entry, "range_id", None) != range_id]
        self.skipped.pop(range_id, None)
        self.flushed_upto.pop(range_id, None)
        self._gc_dropped_upto.pop(range_id, None)
        self.gc_floor.pop(range_id, None)

    # -- logical truncation ---------------------------------------------------
    def logically_truncate(self, range_id: int, lsns: Iterable[int]) -> None:
        self.skipped.setdefault(range_id, set()).update(lsns)

    def range_lsns_between(self, range_id: int, lo_excl: int, hi_incl: int) -> list[int]:
        skipped = self.skipped.get(range_id, set())
        return [e.lsn for e in self.durable
                if isinstance(e, LogRecord) and e.range_id == range_id
                and lo_excl < e.lsn <= hi_incl and e.lsn not in skipped]

    # -- catch-up source ------------------------------------------------------
    def records_between(self, range_id: int, lo_excl: int, hi_incl: int
                        ) -> Optional[list[LogRecord]]:
        """Committed-record fetch for catch-up.  Returns None if the log has
        been GC'd past `lo_excl` (caller falls back to SSTables, §6.1)."""
        if self._gc_dropped_upto.get(range_id, 0) > lo_excl:
            return None
        skipped = self.skipped.get(range_id, set())
        out = [e for e in self.durable
               if isinstance(e, LogRecord) and e.range_id == range_id
               and lo_excl < e.lsn <= hi_incl and e.lsn not in skipped]
        return out

    # -- GC -------------------------------------------------------------------
    def note_flushed(self, range_id: int, lsn: int) -> None:
        self.flushed_upto[range_id] = max(self.flushed_upto.get(range_id, 0), lsn)
        self._maybe_gc()

    def _maybe_gc(self) -> None:
        """Roll over old segments: drop durable entries whose range has
        flushed past them.  Skipped-LSN lists are GC'd with the log files."""
        if self.durable_bytes < 2 * self.segment_bytes:
            return
        keep: list[Entry] = []
        kept_bytes = 0
        for e in self.durable:
            if isinstance(e, LogRecord):
                fl = min(self.flushed_upto.get(e.range_id, 0),
                         self.gc_floor.get(e.range_id, 1 << 62) - 1)
                if e.lsn <= fl:
                    self._gc_dropped_upto[e.range_id] = max(
                        self._gc_dropped_upto.get(e.range_id, 0), e.lsn)
                    sk = self.skipped.get(e.range_id)
                    if sk is not None:
                        sk.discard(e.lsn)
                    continue
            elif isinstance(e, CommitMarker):
                # keep only the newest marker per range (cheap approximation
                # of marker compaction during rollover)
                pass
            keep.append(e)
            kept_bytes += self._entry_bytes(e)
        self.durable = keep
        self.durable_bytes = kept_bytes
