"""Hand-rolled optimizers (no optax in this environment).

AdamW for ≤~200B-param configs; Adafactor (factored second moments, no
momentum by default) for the trillion-parameter MoE where Adam state
would not fit a pod.  Both are pure pytree transforms so optimizer state
inherits the parameters' FSDP sharding.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    factored_min_dim: int = 128


def choose_optimizer(param_count: int) -> OptimizerConfig:
    if param_count > 200e9:
        return OptimizerConfig(name="adafactor")
    return OptimizerConfig(name="adamw")


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, opt_state, params, cfg: OptimizerConfig):
    count = opt_state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g32
        v2 = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m2 / c1
        vhat = v2 / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (-cfg.lr * step).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    updates = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    m = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[2], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    return updates, {"m": m, "v": v, "count": count}


# ---------------------------------------------------------------------------
# Adafactor
# ---------------------------------------------------------------------------


def _factored(p, min_dim: int) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= min_dim and p.shape[-2] >= min_dim


def adafactor_init(params, cfg: OptimizerConfig = OptimizerConfig()):
    def one(p):
        if _factored(p, cfg.factored_min_dim):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"stats": jax.tree.map(one, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, opt_state, params, cfg: OptimizerConfig):
    count = opt_state["count"] + 1
    t = count.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay_rate)

    def upd(g, stat, p):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + 1e-30
        if "vr" in stat:
            vr = beta2 * stat["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * stat["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            rfac = vr / jnp.mean(vr, axis=-1, keepdims=True)
            step = g32 / (jnp.sqrt(rfac)[..., None] *
                          jnp.sqrt(vc)[..., None, :] + cfg.eps)
            new = {"vr": vr, "vc": vc}
        else:
            v = beta2 * stat["v"] + (1 - beta2) * g2
            step = g32 / (jnp.sqrt(v) + cfg.eps)
            new = {"v": v}
        # update clipping (Adafactor's RMS clip)
        rms = jnp.sqrt(jnp.mean(jnp.square(step)) + 1e-30)
        step = step / jnp.maximum(1.0, rms)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (-cfg.lr * step).astype(p.dtype), new

    flat_out = jax.tree_util.tree_map_with_path(
        lambda path, g, p: upd(g, _stat_at(opt_state["stats"], path), p),
        grads, params)
    updates = jax.tree.map(lambda t: t[0], flat_out,
                           is_leaf=lambda t: isinstance(t, tuple))
    stats = jax.tree.map(lambda t: t[1], flat_out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return updates, {"stats": stats, "count": count}


def _stat_at(stats, path):
    node = stats
    for p in path:
        key = p.key if hasattr(p, "key") else p.idx
        node = node[key]
    return node


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------


def init_opt_state(params, cfg: OptimizerConfig):
    if cfg.name == "adafactor":
        return adafactor_init(params, cfg)
    return adamw_init(params)


def apply_optimizer(grads, opt_state, params, cfg: OptimizerConfig):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    if cfg.name == "adafactor":
        updates, new_state = adafactor_update(grads, opt_state, params, cfg)
    else:
        updates, new_state = adamw_update(grads, opt_state, params, cfg)
    new_params = jax.tree.map(lambda p, u: p + u.astype(p.dtype), params,
                              updates)
    return new_params, new_state, gnorm
