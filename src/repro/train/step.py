"""Step builders: train_step / prefill_step / serve_step.

These are the functions the dry-run lowers and the drivers jit.  All are
pure (state, batch) -> (state, metrics) style with donated state.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models import decode_step, forward, init_cache, init_params, loss_fn
from ..models.config import ModelConfig
from .optim import OptimizerConfig, apply_optimizer, init_opt_state


@dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    microbatches: int = 1          # grad accumulation steps per global step
    grad_compression: bool = False  # int8 + error feedback on the DP reduce


def init_train_state(rng: jax.Array, cfg: ModelConfig,
                     tcfg: TrainConfig) -> dict:
    params = init_params(rng, cfg)
    return {
        "params": params,
        "opt": init_opt_state(params, tcfg.optimizer),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, cfg)
        return loss, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        if tcfg.microbatches > 1:
            n = tcfg.microbatches

            def reshape(x):
                b = x.shape[0]
                return x.reshape(n, b // n, *x.shape[1:])
            mbatches = jax.tree.map(reshape, batch)

            def body(carry, mb):
                acc, loss_sum = carry
                loss, _metrics, grads = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_sum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), mbatches)
            grads = jax.tree.map(lambda g: g / n, gsum)
            loss = loss_sum / n
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if tcfg.grad_compression:
            from ..dist.compression import compress_decompress
            grads = compress_decompress(grads)

        new_params, new_opt, gnorm = apply_optimizer(
            grads, state["opt"], params, tcfg.optimizer)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        out_metrics = {"loss": loss.astype(jnp.float32),
                       "grad_norm": gnorm.astype(jnp.float32),
                       "step": new_state["step"]}
        out_metrics.update({k: v for k, v in metrics.items()
                            if k in ("ce", "aux")})
        return new_state, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """prefill_step(params, batch) -> last-token logits (B, V)."""

    def prefill_step(params, batch):
        logits, _aux, _mask = forward(params, batch, cfg)
        return logits[:, -1, :]

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """serve_step(params, cache, tokens) -> (logits, cache) — one new token
    against a seq_len-deep cache (the decode shapes lower THIS, not
    train_step)."""

    def serve_step(params, cache, tokens):
        logits, new_cache = decode_step(params, cache, tokens, cfg)
        return logits, new_cache

    return serve_step
