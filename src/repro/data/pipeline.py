"""Deterministic, seekable data pipeline.

Requirements at 1000-node scale: per-shard disjoint streams, O(1) seek to
any step (restart/elastic re-shard without replay), and an offset small
enough to commit to the metadata store every step.  A counter-mode PRNG
(threefry via jax, but computed with numpy for host-side speed) gives all
three: batch `i` of shard `s` is a pure function of (seed, s, i).

`MixtureStream` layers a deterministic document-mixture simulation on
top (length-varying "documents" packed into fixed-length sequences) so
the pipeline exercises realistic packing logic, still bit-reproducible.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1          # data-parallel shards
    mixture_docs: bool = True    # pack variable-length docs


def _philox(seed: int, shard: int, step: int) -> np.random.Generator:
    """Counter-mode randomness: a fresh Generator keyed by (seed, shard,
    step) — O(1) seek, no sequential state."""
    ss = np.random.SeedSequence([seed, shard, step])
    return np.random.Generator(np.random.Philox(ss))


class TokenStream:
    """Per-shard token stream; `batch_at(step)` is a pure function."""

    def __init__(self, cfg: DataConfig, shard: int):
        if shard >= cfg.num_shards:
            raise ValueError("shard out of range")
        self.cfg = cfg
        self.shard = shard
        if cfg.global_batch % cfg.num_shards:
            raise ValueError("global_batch must divide by num_shards")
        self.local_batch = cfg.global_batch // cfg.num_shards

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        g = _philox(cfg.seed, self.shard, step)
        B, S = self.local_batch, cfg.seq_len
        V = cfg.vocab_size
        if cfg.mixture_docs:
            # documents follow a noisy affine bigram chain so there is
            # learnable structure (the loss curve means something), packed
            # to fixed length with EOS separators
            tokens = np.empty((B, S + 1), np.int32)
            a = 31 % V or 1
            for b in range(B):
                row: list[int] = []
                while len(row) < S + 1:
                    dl = int(min(S, 16 + g.pareto(1.2) * 64))
                    t = int(g.integers(2, V))
                    doc = np.empty(dl, np.int64)
                    noise = g.random(dl)
                    rand = g.integers(2, V, dl)
                    for i in range(dl):
                        doc[i] = t
                        t = (t * a + 7) % (V - 2) + 2 \
                            if noise[i] < 0.8 else int(rand[i])
                    row.extend(doc.tolist())
                    row.append(1)  # EOS
                tokens[b] = np.asarray(row[:S + 1], np.int32)
        else:
            tokens = g.integers(2, V, (B, S + 1), dtype=np.int32)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def iter_from(self, step: int) -> Iterator[dict]:
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass
class PipelineState:
    """The committable offset: this is all a restart needs."""
    step: int = 0

    def to_bytes(self) -> bytes:
        return str(self.step).encode()

    @staticmethod
    def from_bytes(b: bytes) -> "PipelineState":
        return PipelineState(step=int(b.decode()))


class Prefetcher:
    """Bounded lookahead with a straggler deadline: if computing batch i
    exceeds `deadline_steps` of budget (simulated via a hook at 1000-node
    scale; host-time here), the batch is *deterministically skippable* —
    both the skip decision and the replacement are functions of the step,
    so every worker makes the same call without coordination."""

    def __init__(self, stream: TokenStream, start_step: int = 0,
                 lookahead: int = 2):
        self.stream = stream
        self.step = start_step
        self.lookahead = lookahead
        self._buf: dict[int, dict] = {}

    def next(self) -> tuple[int, dict]:
        for s in range(self.step, self.step + self.lookahead + 1):
            if s not in self._buf:
                self._buf[s] = self.stream.batch_at(s)
        batch = self._buf.pop(self.step)
        out_step = self.step
        self.step += 1
        self._buf = {s: b for s, b in self._buf.items() if s >= self.step}
        return out_step, batch
