"""Fault-tolerance manager: failure detection, elastic re-meshing,
straggler mitigation (DESIGN.md §3).

Failure detection reuses the paper's machinery directly: every training
host holds a session in the same coordination service Spinnaker uses for
leader election; a host death ⇒ session expiry ⇒ ephemeral-znode deletion
⇒ watch fires on the controller.  The controller then:

  1. fences the dead generation (bumps /train/<run>/generation — stragglers
     from the old generation see the bump and exit, mirroring the paper's
     epoch numbers);
  2. computes the largest feasible (data, model) grid from survivors;
  3. restores state *by logical key* from the Spinnaker checkpoint store
     (resharding-safe) and resumes from the committed data-pipeline offset.

Straggler mitigation: per-step host heartbeats with deadline; a host that
misses `straggler_grace` consecutive deadlines is treated as failed-slow
and evicted the same way (at 1000-node scale, slow == dead is the only
scalable policy; cf. the paper's use of ZooKeeper timeouts).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from ..core.coordination import Coordination, NoNode
from ..core.sim import Simulator


@dataclass
class FTConfig:
    session_timeout: float = 2.0
    heartbeat_interval: float = 0.5
    straggler_grace: int = 3          # missed step-deadlines before eviction
    step_deadline: float = 60.0       # wall seconds per step at scale


class HostAgent:
    """Runs on each training host: session + heartbeats + generation check."""

    def __init__(self, sim: Simulator, zk: Coordination, run_id: str,
                 host_id: int, cfg: FTConfig):
        self.sim = sim
        self.zk = zk
        self.run = run_id
        self.host_id = host_id
        self.cfg = cfg
        self.session = zk.create_session()
        self.generation_seen = 0
        self.alive = True
        try:
            zk.create(f"/train/{run_id}/hosts/{host_id}", data=sim.now,
                      ephemeral_session=self.session)
        except Exception:
            pass
        self._beat()

    def _beat(self):
        if not self.alive:
            return
        self.zk.heartbeat(self.session)
        self.sim.schedule(self.cfg.heartbeat_interval, self._beat)

    def fenced(self) -> bool:
        """True if a newer generation exists (this host must stop)."""
        try:
            gen = self.zk.get(f"/train/{self.run}/generation")
        except NoNode:
            gen = 0
        return gen > self.generation_seen

    def adopt_generation(self) -> int:
        try:
            self.generation_seen = self.zk.get(f"/train/{self.run}/generation")
        except NoNode:
            self.generation_seen = 0
        return self.generation_seen

    def crash(self):
        self.alive = False
        self.zk.expire_session(self.session)


class TrainingController:
    """Watches host membership; on change, fences and re-plans the mesh."""

    def __init__(self, sim: Simulator, zk: Coordination, run_id: str,
                 cfg: FTConfig, on_replan: Callable[[list[int], int], None]):
        self.sim = sim
        self.zk = zk
        self.run = run_id
        self.cfg = cfg
        self.on_replan = on_replan
        self.replans = 0
        self._known: set[int] = set()
        self._watch()

    def hosts(self) -> list[int]:
        return sorted(int(h) for h in
                      self.zk.get_children(f"/train/{self.run}/hosts"))

    def _watch(self):
        self.zk.watch_children(f"/train/{self.run}/hosts", self._on_change)

    def _on_change(self, _path: str = ""):
        current = set(self.hosts())
        if current != self._known and self._known:
            lost = self._known - current
            gained = current - self._known
            if lost or gained:
                gen = self.zk.fetch_and_add(f"/train/{self.run}/generation", 1)
                self.replans += 1
                self.on_replan(sorted(current), gen)
        self._known = current
        self._watch()

    def bootstrap(self):
        self._known = set(self.hosts())
        gen = self.zk.fetch_and_add(f"/train/{self.run}/generation", 1)
        self.on_replan(sorted(self._known), gen)
        return gen


class StragglerTracker:
    """Deadline-based straggler detection over per-step progress marks."""

    def __init__(self, cfg: FTConfig):
        self.cfg = cfg
        self.missed: dict[int, int] = {}

    def observe_step(self, durations: dict[int, float]) -> list[int]:
        """durations: host -> step wall time.  Returns hosts to evict."""
        evict = []
        for host, dur in durations.items():
            if dur > self.cfg.step_deadline:
                self.missed[host] = self.missed.get(host, 0) + 1
                if self.missed[host] >= self.cfg.straggler_grace:
                    evict.append(host)
            else:
                self.missed[host] = 0
        return evict


def plan_mesh(n_hosts: int, chips_per_host: int = 4,
              prefer_model: int = 16) -> tuple[int, int]:
    """Largest (data, model) grid from surviving chips; model axis shrinks
    before data so TP stays ICI-local."""
    chips = n_hosts * chips_per_host
    model = min(prefer_model, chips)
    while chips % model:
        model -= 1
    return chips // model, model
