"""Experiment plumbing: build a cluster, preload the keyspace, drive a
workload (optionally under a fault schedule), and emit a JSON-serializable
result block.

These are the functions `benchmarks/spinnaker_bench.py` composes into the
paper's §9 comparisons; they are importable on their own so tests and
notebooks can run one-off scenarios.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Optional

from ..baselines.cassandra import CassandraCluster, CassandraConfig
from ..core import ranges as ranges_mod
from ..core.cluster import ClusterConfig, SpinnakerCluster, key_of
from ..core.node import NodeConfig
from ..core.replica import ReplicaConfig
from ..core.sim import DiskParams, NetParams, Simulator
from ..obs import ObsConfig, stage_breakdown
from .drivers import (AckLedgerAdapter, CassandraAdapter, ClosedLoopDriver,
                      OpenLoopDriver, SpinnakerAdapter, TxnAdapter)
from .generators import OpStream, WorkloadSpec
from .metrics import OpLog
from .scenario import FaultSchedule, parse_schedule

_DISKS = {"hdd": DiskParams.hdd, "ssd": DiskParams.ssd,
          "mem": DiskParams.memory}


@dataclass
class ExperimentConfig:
    """Everything one run needs besides the WorkloadSpec."""
    n_nodes: int = 5
    disk: str = "ssd"                 # hdd | ssd | mem
    seed: int = 0
    commit_period: float = 0.05       # leader's periodic commit broadcast
    # proposal/mutation batching (both systems, so comparisons stay fair)
    batch: str = "adaptive"           # adaptive | off
    batch_max_records: int = 32
    batch_deadline: float = 0.5e-3
    # server-side ingress batching (both systems — recvmmsg-style: drain
    # everything that arrived while the CPU was busy as one batch job)
    ingress_batch: bool = True
    # admission control: shed client requests once the node's CPU backlog
    # (queue delay + staged ingress work) exceeds this many seconds of
    # service time; None disables the gate
    admission_limit: Optional[float] = None
    # base ranges per node (finer pre-split spreads range leadership so
    # zipfian hot keys land on different leaders — see ClusterConfig)
    ranges_per_node: int = 1
    # leader leases (chaos scenarios compare lease-on failover against the
    # lease-off quorum-read / stall behaviour)
    lease_enabled: bool = True
    lease_duration: float = 1.0
    # driver
    driver: str = "closed"            # closed | open
    n_clients: int = 16
    open_rate: float = 2000.0         # ops/s, open-loop only
    warmup: float = 1.0
    duration: float = 5.0
    window: float = 0.5               # timeline bucket width
    preload_keys: int = 0             # 0 = spec.num_keys, capped below
    preload_cap: int = 2000
    # align the cluster's range pre-split with the workload keyspace (on
    # mismatch the whole workload lands in range 0 and measures one cohort,
    # not the cluster); set False to keep the static default pre-split
    align_presplit: bool = True
    # observability: fraction of client ops traced (deterministic
    # error-diffusion sampling; 0 disables) and the metrics scrape period
    # (0 leaves the registry scrape-on-demand only)
    trace_sample: float = 1.0
    metrics_interval: float = 0.0
    # component-attributed resource profiler (pure accounting — zero
    # modeled cost); profile_interval > 0 records a utilization timeline
    profile: bool = True
    profile_interval: float = 0.0
    # protocol flight recorder + invariant watchdog (pure measurement —
    # a journaled run is bit-identical to an un-journaled one; the bench
    # watchdog scenario gates exactly that)
    journal: bool = True


def build_spinnaker(cfg: ExperimentConfig, num_keys: Optional[int] = None):
    """`num_keys` overrides the range-boundary pre-split: pass the
    workload's keyspace size to spread load across all cohorts (with the
    default 100k boundaries a small-keyspace workload lands entirely in
    range 0 and measures one cohort, not the cluster)."""
    sim = Simulator(seed=cfg.seed)
    ccfg = ClusterConfig(
        n_nodes=cfg.n_nodes,
        ranges_per_node=cfg.ranges_per_node,
        node=NodeConfig(replica=ReplicaConfig(
            commit_period=cfg.commit_period, batch=cfg.batch,
            batch_max_records=cfg.batch_max_records,
            batch_deadline=cfg.batch_deadline,
            lease_enabled=cfg.lease_enabled,
            lease_duration=cfg.lease_duration),
                        disk=_DISKS[cfg.disk](),
                        ingress_batch=cfg.ingress_batch,
                        admission_limit=cfg.admission_limit),
        obs=ObsConfig(trace_sample=cfg.trace_sample,
                      metrics_interval=cfg.metrics_interval,
                      profile=cfg.profile,
                      profile_interval=cfg.profile_interval,
                      journal=cfg.journal,
                      watchdog=cfg.journal))
    if num_keys is not None:
        ccfg.num_keys = num_keys
    cluster = SpinnakerCluster(sim, ccfg)
    cluster.start()
    cluster.settle()
    return sim, cluster


def build_cassandra(cfg: ExperimentConfig):
    sim = Simulator(seed=cfg.seed)
    cluster = CassandraCluster(
        sim, CassandraConfig(n_nodes=cfg.n_nodes, disk=_DISKS[cfg.disk](),
                             batch=cfg.batch,
                             ingress_batch=cfg.ingress_batch,
                             batch_max_records=cfg.batch_max_records,
                             batch_deadline=cfg.batch_deadline,
                             obs=ObsConfig(
                                 trace_sample=cfg.trace_sample,
                                 metrics_interval=cfg.metrics_interval,
                                 profile=cfg.profile,
                                 profile_interval=cfg.profile_interval)))
    return sim, cluster


def _aligned_presplit(cfg: ExperimentConfig,
                      spec: WorkloadSpec) -> Optional[int]:
    """Pre-split footgun guard: with the default 100k boundaries a smaller
    workload keyspace lands entirely in range 0, silently measuring one
    cohort.  By default the pre-split is auto-aligned to the workload's
    keyspace (with a warning); `cfg.align_presplit=False` keeps the static
    default for experiments that want the mismatch on purpose."""
    default_presplit = ClusterConfig.num_keys
    if spec.num_keys == default_presplit:
        return None
    if not cfg.align_presplit:
        warnings.warn(
            f"workload keyspace ({spec.num_keys} keys) does not match the "
            f"cluster pre-split ({default_presplit}); the load will "
            "concentrate in range 0 (align_presplit=False keeps this)",
            stacklevel=3)
        return None
    warnings.warn(
        f"aligning cluster pre-split to the workload keyspace "
        f"({spec.num_keys} keys, default pre-split {default_presplit}); "
        "set align_presplit=False to keep the static pre-split",
        stacklevel=3)
    return spec.num_keys


def _preload(sim, put, n_keys: int, deadline: float = 120.0) -> None:
    """Write keys 0..n_keys-1 so reads hit existing data."""
    done = [0]
    for i in range(n_keys):
        put(key_of(i), lambda r: done.__setitem__(0, done[0] + 1))
    limit = sim.now + deadline
    while done[0] < n_keys and sim.now < limit:
        sim.run(until=sim.now + 0.25)
    if done[0] < n_keys:
        raise RuntimeError(f"preload incomplete: {done[0]}/{n_keys}")


def _drive(sim, adapter, spec: WorkloadSpec, cfg: ExperimentConfig,
           schedule: Optional[FaultSchedule], cluster,
           preloaded: int) -> tuple[OpLog, float, object]:
    stream = OpStream(spec, seed=cfg.seed + 1)
    if spec.key_dist == "latest":
        # 'latest' skews toward recent inserts: start the horizon at the
        # preloaded prefix; drivers advance it on successful writes
        stream.insert_horizon = max(1, preloaded)
    log = OpLog()
    if schedule is not None:
        # schedule times are relative to the measured interval's start;
        # applied faults (and honest skips) land in the cluster event log
        # so fig9/10 timelines carry their own annotations
        obs = getattr(cluster, "obs", None)
        on_event = (None if obs is None
                    else lambda msg: obs.events.emit("fault", detail=msg))
        schedule.install(sim, cluster, at=sim.now + cfg.warmup,
                         on_event=on_event)
    if cfg.driver == "open":
        drv = OpenLoopDriver(sim, adapter, stream, log, rate=cfg.open_rate)
    else:
        drv = ClosedLoopDriver(sim, adapter, stream, log,
                               n_clients=cfg.n_clients)
    t_start = sim.now + cfg.warmup
    drv.run(cfg.duration, warmup=cfg.warmup)
    return log, t_start, drv


def _result(log: OpLog, cfg: ExperimentConfig, read_kind: str,
            write_kind: str, schedule: Optional[FaultSchedule],
            t_start: float) -> dict:
    out = {
        "reads": log.summary(read_kind, duration=cfg.duration),
        "writes": log.summary(write_kind, duration=cfg.duration),
        "total_ops": len(log),
        "duration_s": cfg.duration,
        "throughput": sum(h.total for h in log.hists.values()) / cfg.duration,
    }
    if schedule is not None:
        out["fault_events"] = list(schedule.applied)
        out["timeline"] = {}
        for kind in (read_kind, write_kind):
            rows = []
            for w in log.windows(cfg.window, kind=kind, t0=t_start,
                                 t1=t_start + cfg.duration):
                d = vars(w).copy()
                # report windows relative to the measured interval's start
                d["t_start"] = round(d["t_start"] - t_start, 6)
                d["t_end"] = round(d["t_end"] - t_start, 6)
                rows.append(d)
            out["timeline"][kind] = rows
    return out


def run_spinnaker_workload(spec: WorkloadSpec,
                           cfg: Optional[ExperimentConfig] = None,
                           consistent_reads: bool = True,
                           monotonic: bool = False,
                           schedule: Optional[FaultSchedule | str] = None
                           ) -> dict:
    """One Spinnaker run; returns the JSON-ready result block."""
    cfg = cfg or ExperimentConfig()
    if isinstance(schedule, str):
        schedule = parse_schedule(schedule)
    sim, cluster = build_spinnaker(cfg, num_keys=_aligned_presplit(cfg, spec))
    loader = cluster.make_client("preload")
    n_pre = min(cfg.preload_keys or spec.num_keys, cfg.preload_cap,
                spec.num_keys)
    _preload(sim, lambda k, cb: loader.put(k, "c", b"x" * spec.value_size,
                                           cb), n_pre)
    adapter = SpinnakerAdapter(cluster.make_client("bench"),
                               consistent=consistent_reads,
                               monotonic=monotonic)
    log, t_start, _drv = _drive(sim, adapter, spec, cfg, schedule, cluster,
                                n_pre)
    read_kind = "read" if consistent_reads else "timeline_read"
    out = _result(log, cfg, read_kind, "write", schedule, t_start)
    # concurrency outcomes (atomic RMW conflicts/retries, lock bounces)
    out["driver"] = adapter.metrics()
    if spec.rmw_frac:
        out["rmw"] = log.summary("rmw", duration=cfg.duration)
    out["trace_audit"] = cluster.obs.tracer.audit_writes()
    if schedule is not None:
        out["cluster_events"] = cluster.obs.events.export(t0=t_start)
    return out


def run_spinnaker_saturation(spec: WorkloadSpec,
                             cfg: Optional[ExperimentConfig] = None,
                             rates: Optional[list[float]] = None,
                             dwell: float = 2.0,
                             settle: float = 0.3) -> dict:
    """Open-loop rate-ramp on ONE cluster (§C saturation methodology).

    For each offered rate, Poisson arrivals are driven for `settle+dwell`
    sim-seconds (the settle prefix at the new rate is not recorded) and the
    achieved write throughput + latency percentiles are sampled.  The
    saturation knee is where achieved throughput stops tracking the offered
    rate and the latency percentiles collapse; comparing curves with
    `cfg.batch` "off" vs "adaptive" isolates what proposal batching buys.
    """
    cfg = cfg or ExperimentConfig()
    rates = rates or [1000, 2000, 5000, 10000, 20000, 40000]
    # align range boundaries with the workload keyspace so the ramp loads
    # every cohort, not just range 0
    sim, cluster = build_spinnaker(cfg, num_keys=spec.num_keys)
    loader = cluster.make_client("preload")
    n_pre = min(cfg.preload_keys or spec.num_keys, cfg.preload_cap,
                spec.num_keys)
    _preload(sim, lambda k, cb: loader.put(k, "c", b"x" * spec.value_size,
                                           cb), n_pre)
    adapter = SpinnakerAdapter(cluster.make_client("bench"), consistent=True)
    stream = OpStream(spec, seed=cfg.seed + 1)
    stream.insert_horizon = max(1, n_pre)
    points = []
    for rate in rates:
        log = OpLog()
        drv = OpenLoopDriver(sim, adapter, stream, log, rate=rate)
        drv.run(dwell, warmup=settle)
        w = log.summary("write", duration=dwell)
        points.append({
            "offered_rate": rate,
            "achieved_tput": w["count"] / dwell,
            "write_p50_ms": w["p50_ms"],
            "write_p99_ms": w["p99_ms"],
            "errors": w["errors"],
            "shed": drv.shed,
        })
    # leader-side batching telemetry, aggregated over the whole ramp
    flushes = records = 0
    for node in cluster.nodes.values():
        for rep in node.replicas.values():
            flushes += rep.batches_flushed
            records += rep.batched_records
    return {
        "batch": cfg.batch,
        "disk": cfg.disk,
        "points": points,
        "peak_write_tput": max((p["achieved_tput"] for p in points),
                               default=0.0),
        "mean_batch_records": records / flushes if flushes else 0.0,
    }


def run_spinnaker_rebalance(spec: WorkloadSpec,
                            cfg: Optional[ExperimentConfig] = None,
                            schedule: Optional[FaultSchedule | str] = None,
                            kill_leader: bool = True,
                            autobalance: bool = False) -> dict:
    """Elastic-range scenario: drive zipfian write-heavy load while the
    hottest range live-splits, one of its replicas migrates to the least
    loaded node, and (by default) the range leader is killed mid-migration.

    Every acknowledged write is ledgered (key -> max acked version); after
    the run the ledger is audited with strong reads — a single lost
    acknowledged write fails the scenario.  The result block also records
    write availability through the events, tail latency, the final range
    table, and whether the migration resolved unaided.
    """
    cfg = cfg or ExperimentConfig()
    if isinstance(schedule, str):
        schedule = parse_schedule(schedule)
    sim, cluster = build_spinnaker(cfg, num_keys=_aligned_presplit(cfg, spec))
    n_base = len(cluster.ranges)
    loader = cluster.make_client("preload")
    n_pre = min(cfg.preload_keys or spec.num_keys, cfg.preload_cap,
                spec.num_keys)
    _preload(sim, lambda k, cb: loader.put(k, "c", b"x" * spec.value_size,
                                           cb), n_pre)
    # zipfian's hottest key is index 0; its range is the one to shed
    hot_rid = cluster.range_of(key_of(0))
    if schedule is None:
        d = cfg.duration
        lines = [f"at {d * 0.2:.2f}s split range {hot_rid}",
                 f"at {d * 0.45:.2f}s move range {hot_rid}"]
        if kill_leader:
            # mid-migration: right after the move starts, before the
            # destination can be in-sync and the source retired
            lines.append(f"at {d * 0.45 + 0.25:.2f}s crash leader of "
                         f"{hot_rid}")
            lines.append(f"at {d * 0.8:.2f}s restart crashed")
        if autobalance:
            lines.insert(0, "at 0.0s autobalance on")
        schedule = parse_schedule("\n".join(lines))

    ledger: dict[int, int] = {}
    adapter = AckLedgerAdapter(cluster.make_client("bench"), ledger,
                               consistent=True)
    log, t_start, drv = _drive(sim, adapter, spec, cfg, schedule, cluster,
                               n_pre)
    out = _result(log, cfg, "read", "write", schedule, t_start)
    # ops still in flight when the run ended: with capped-backoff retries
    # an op stuck on an unavailable range never reaches its retry budget
    # inside a short run, so it would otherwise vanish from the error count
    # and leave the availability gate vacuous.  A healthy run carries only
    # the natural in-flight tail (a handful of ops).
    stalled = getattr(drv, "outstanding", 0)

    # -- post-run audit ------------------------------------------------------
    sim.run_for(3.0)          # let in-flight recovery/migration finish
    cluster.settle(timeout=30.0)
    auditor = cluster.make_client("audit")
    lost = []
    for idx, ver in sorted(ledger.items()):
        r = auditor.sync_get(key_of(idx), "c", consistent=True)
        if not r.ok or (r.version or 0) < ver:
            lost.append({"key": idx, "acked_version": ver,
                         "read": r.code.value, "read_version": r.version})
    # writes must land on both sides of every split boundary
    serving = {}
    for rid, kr in sorted(cluster.ranges.items()):
        r = auditor.sync(auditor.put, kr.lo, "c", b"probe")
        serving[rid] = bool(r.ok)
    intents = [rid for rid in cluster.ranges
               if cluster.zk.exists(ranges_mod.migration_path(rid))]
    non_empty = 0
    for rid, kr in cluster.ranges.items():
        rep = cluster.leader_replica(rid)
        if rep is not None and rep.store.keys_in_range(kr.lo, kr.hi):
            non_empty += 1
    w = out["writes"]
    attempts = w["count"] + w["errors"] + stalled
    out["rebalance"] = {
        "n_ranges_start": n_base,
        "n_ranges_end": len(cluster.ranges),
        "range_table": {rid: [kr.lo, kr.hi, list(cluster.members[rid])]
                        for rid, kr in sorted(cluster.ranges.items())},
        "hot_rid": hot_rid,
        "acked_writes_ledgered": len(ledger),
        "lost_acked_writes": lost,
        "all_ranges_serving_writes": all(serving.values()),
        "serving": serving,
        "non_empty_ranges": non_empty,
        "unresolved_migrations": intents,
        "stalled_ops_at_end": stalled,
        "write_availability": (w["count"] / attempts) if attempts else 1.0,
        "wrong_range_redirects": adapter.client.wrong_range_redirects,
        "balancer_actions": list(cluster.balancer.actions)
        if cluster.balancer is not None else [],
    }
    out["trace_audit"] = cluster.obs.tracer.audit_writes()
    if schedule is not None:
        out["cluster_events"] = cluster.obs.events.export(t0=t_start)
    return out


def _slow_txn_chains(cluster, top_n: int = 5) -> list[dict]:
    """Slowest decided 2PC transactions, keyed by txid: the milestone
    chain (ms relative to t_start) plus the txid's own journal entries —
    the `--report` drill-down for 'why was this transfer slow'."""
    journal = cluster.obs.journal
    ranked = []
    for tr in cluster.obs.tracer.txns.values():
        stamps = [s for s in ([tr.t_decided, tr.t_client_ack]
                              + list(tr.prepare_sent.values())
                              + list(tr.voted.values())
                              + list(tr.resolved.values())) if s is not None]
        if tr.outcome is None or not stamps:
            continue
        ranked.append((max(stamps) - tr.t_start, tr))
    ranked.sort(key=lambda x: (-x[0], x[1].txid))
    out = []
    for e2e, tr in ranked[:top_n]:
        def rel(t, _t0=tr.t_start):
            return None if t is None else round((t - _t0) * 1e3, 3)
        out.append({
            "txid": tr.txid,
            "coordinator": tr.coordinator,
            "participants": list(tr.participants),
            "outcome": tr.outcome,
            "t_start": round(tr.t_start, 6),
            "e2e_ms": round(e2e * 1e3, 3),
            "prepare_sent_ms": {r: rel(t)
                                for r, t in sorted(tr.prepare_sent.items())},
            "vote_ms": {r: rel(t) for r, t in sorted(tr.voted.items())},
            "decide_ms": rel(tr.t_decided),
            "resolve_ms": {r: rel(t) for r, t in sorted(tr.resolved.items())},
            "client_ack_ms": rel(tr.t_client_ack),
            "journal": journal.txn_entries(tr.txid) if journal.enabled
            else [],
        })
    return out


def run_spinnaker_txn(spec: WorkloadSpec,
                      cfg: Optional[ExperimentConfig] = None,
                      cross_frac: Optional[float] = None,
                      schedule: Optional[FaultSchedule | str] = None,
                      initial_balance: int = 1_000,
                      amount: int = 1) -> dict:
    """Cross-range transaction scenario (PR 4): drive a read/transfer mix
    where TXN ops move `amount` between two accounts — a fraction across
    ranges (Paxos-backed 2PC) and the rest inside one range (the §8.2
    fast path) — optionally under a fault schedule (e.g. ``crash txn
    coordinator`` for a mid-2PC leader kill).  The cross fraction comes
    from ``spec.txn_cross_frac`` unless `cross_frac` overrides it.

    Two audits close the run:

    - **no acknowledged transaction lost**: every acked transfer's
      (key, version) pairs must be readable at >= the acked version;
    - **no partial commit**: transfers are zero-sum, so the strong-read
      balance total over the whole keyspace must equal the preloaded
      total — a single torn transfer (one leg applied, the other not)
      breaks it.

    The op mix must carry only read/txn mass: blind writes would clobber
    balances and make the sum audit vacuous."""
    cfg = cfg or ExperimentConfig()
    if cross_frac is None:
        cross_frac = spec.txn_cross_frac
    if spec.write_frac or spec.rmw_frac or spec.cond_frac:
        raise ValueError("txn scenario needs a read/txn-only mix "
                         "(blind writes would break the balance-sum audit)")
    if isinstance(schedule, str):
        schedule = parse_schedule(schedule)
    sim, cluster = build_spinnaker(cfg, num_keys=_aligned_presplit(cfg, spec))
    loader = cluster.make_client("preload")
    n_pre = min(cfg.preload_keys or spec.num_keys, cfg.preload_cap,
                spec.num_keys)
    _preload(sim, lambda k, cb: loader.put(k, "c", initial_balance, cb),
             n_pre)
    ledger: list = []
    adapter = TxnAdapter(cluster.make_client("bench"), spec.num_keys,
                         cross_frac=cross_frac, amount=amount,
                         ledger=ledger, consistent=True)
    log, t_start, drv = _drive(sim, adapter, spec, cfg, schedule, cluster,
                               n_pre)
    out = {
        "reads": log.summary("read", duration=cfg.duration),
        "txn_local": log.summary("txn_local", duration=cfg.duration),
        "txn_cross": log.summary("txn_cross", duration=cfg.duration),
        "total_ops": len(log),
        "duration_s": cfg.duration,
        "throughput": sum(h.total for h in log.hists.values()) / cfg.duration,
    }
    if schedule is not None:
        out["fault_events"] = list(schedule.applied)
        out["timeline"] = {}
        for kind in ("txn_cross", "txn_local"):
            rows = []
            for w in log.windows(cfg.window, kind=kind, t0=t_start,
                                 t1=t_start + cfg.duration):
                d = vars(w).copy()
                d["t_start"] = round(d["t_start"] - t_start, 6)
                d["t_end"] = round(d["t_end"] - t_start, 6)
                rows.append(d)
            out["timeline"][kind] = rows

    # -- post-run audit ------------------------------------------------------
    sim.run_for(3.0)          # drain in-flight 2PC resolution / elections
    cluster.settle(timeout=30.0)
    auditor = cluster.make_client("audit")
    lost = []
    for legs in ledger:
        for key, ver in legs:
            r = auditor.sync_get(key, "c", consistent=True)
            if not r.ok or (r.version or 0) < ver:
                lost.append({"key": key, "acked_version": ver,
                             "read": r.code.value, "read_version": r.version})
    balance = 0
    for lo in range(0, spec.num_keys, 64):
        pairs = [(key_of(i), "c")
                 for i in range(lo, min(lo + 64, spec.num_keys))]
        rs = auditor.sync(auditor.multi_get, pairs, True)
        balance += sum(r.value for r in rs if r.ok
                       and isinstance(r.value, int))
    expected = n_pre * initial_balance
    leftover_locks = sum(len(rep.txn.locks)
                         for node in cluster.nodes.values()
                         for rep in node.replicas.values())
    leftover_prepared = sum(len(rep.txn.prepared)
                            for node in cluster.nodes.values()
                            for rep in node.replicas.values())
    srv = {"prepares": 0, "commits": 0, "aborts": 0, "votes_no": 0,
           "reads_deferred": 0, "lock_conflicts": 0}
    for node in cluster.nodes.values():
        for rep in node.replicas.values():
            for k in srv:
                srv[k] += getattr(rep.txn, k)
    out["txn"] = {
        "cross_frac": cross_frac,
        **adapter.metrics(),
        "acked_txns_ledgered": len(ledger),
        "lost_acked_txns": lost,
        "balance_expected": expected,
        "balance_read": balance,
        "partial_commit": balance != expected,
        "unresolved_intents": sorted(cluster.zk.get_children("/txn")),
        "leftover_locks": leftover_locks,
        "leftover_prepared": leftover_prepared,
        "server": srv,
        # audited after the settle: every committed 2PC txn must show the
        # full prepare -> vote -> decide -> per-participant resolve chain
        "trace_audit": cluster.obs.tracer.audit_txns(),
        "slow_txn_chains": _slow_txn_chains(cluster),
    }
    out["trace_audit"] = cluster.obs.tracer.audit_writes()
    if schedule is not None:
        out["cluster_events"] = cluster.obs.events.export(t0=t_start)
    return out


def run_cassandra_workload(spec: WorkloadSpec,
                           cfg: Optional[ExperimentConfig] = None,
                           quorum: bool = True,
                           schedule: Optional[FaultSchedule | str] = None
                           ) -> dict:
    """One Cassandra-baseline run (quorum or eventual consistency)."""
    cfg = cfg or ExperimentConfig()
    if isinstance(schedule, str):
        schedule = parse_schedule(schedule)
    sim, cluster = build_cassandra(cfg)
    loader = cluster.make_client("preload")
    n_pre = min(cfg.preload_keys or spec.num_keys, cfg.preload_cap,
                spec.num_keys)
    _preload(sim, lambda k, cb: loader.write(k, "c", b"x" * spec.value_size,
                                             True, cb), n_pre)
    adapter = CassandraAdapter(cluster.make_client("bench"), quorum=quorum)
    log, t_start, _drv = _drive(sim, adapter, spec, cfg, schedule, cluster,
                                n_pre)
    prefix = "" if quorum else "eventual_"
    out = _result(log, cfg, f"{prefix}read", f"{prefix}write", schedule,
                  t_start)
    out["trace_audit"] = cluster.obs.tracer.audit_writes()
    if schedule is not None:
        out["cluster_events"] = cluster.obs.events.export(t0=t_start)
    return out


def run_spinnaker_profiled(spec: WorkloadSpec,
                           cfg: Optional[ExperimentConfig] = None,
                           consistent_reads: bool = True) -> dict:
    """One Spinnaker run with the full resource profile attached: the
    usual workload result block plus `out["profile"]` — per-node x
    per-component busy-time attribution, utilization timeline, and
    per-range heat (`Profiler.summary()`)."""
    cfg = cfg or ExperimentConfig()
    sim, cluster = build_spinnaker(cfg, num_keys=_aligned_presplit(cfg, spec))
    loader = cluster.make_client("preload")
    n_pre = min(cfg.preload_keys or spec.num_keys, cfg.preload_cap,
                spec.num_keys)
    _preload(sim, lambda k, cb: loader.put(k, "c", b"x" * spec.value_size,
                                           cb), n_pre)
    adapter = SpinnakerAdapter(cluster.make_client("bench"),
                               consistent=consistent_reads)
    log, t_start, _drv = _drive(sim, adapter, spec, cfg, None, cluster, n_pre)
    read_kind = "read" if consistent_reads else "timeline_read"
    out = _result(log, cfg, read_kind, "write", None, t_start)
    out["trace_audit"] = cluster.obs.tracer.audit_writes()
    cluster.obs.stop()
    out["profile"] = cluster.obs.profiler.summary()
    if cfg.metrics_interval > 0:
        out["metrics"] = cluster.obs.metrics.summary()
    return out


def run_cassandra_profiled(spec: WorkloadSpec,
                           cfg: Optional[ExperimentConfig] = None,
                           quorum: bool = True) -> dict:
    """Cassandra-baseline counterpart of `run_spinnaker_profiled`."""
    cfg = cfg or ExperimentConfig()
    sim, cluster = build_cassandra(cfg)
    loader = cluster.make_client("preload")
    n_pre = min(cfg.preload_keys or spec.num_keys, cfg.preload_cap,
                spec.num_keys)
    _preload(sim, lambda k, cb: loader.write(k, "c", b"x" * spec.value_size,
                                             True, cb), n_pre)
    adapter = CassandraAdapter(cluster.make_client("bench"), quorum=quorum)
    log, t_start, _drv = _drive(sim, adapter, spec, cfg, None, cluster, n_pre)
    prefix = "" if quorum else "eventual_"
    out = _result(log, cfg, f"{prefix}read", f"{prefix}write", None, t_start)
    out["trace_audit"] = cluster.obs.tracer.audit_writes()
    cluster.obs.stop()
    out["profile"] = cluster.obs.profiler.summary()
    if cfg.metrics_interval > 0:
        out["metrics"] = cluster.obs.metrics.summary()
    return out


def _breakdown_block(cluster, log, cfg: ExperimentConfig,
                     write_kind: str) -> dict:
    """Latency-breakdown result block shared by both systems: per-stage
    p50 decomposition from the traces, cross-checked against the OpLog's
    independently measured percentiles."""
    cluster.obs.stop()      # flush the tail scrape before summarizing
    bd = stage_breakdown(cluster.obs.tracer.traces, kind=write_kind)
    # annotate each slowest trace with the implicated protocol-journal
    # window (what the trace's range was going through while the op ran)
    journal = getattr(cluster.obs, "journal", None)
    if journal is not None and journal.enabled:
        for t in bd.get("top_slowest", []):
            rid = cluster.range_of(t["key"])
            t["rid"] = rid
            t["journal"] = journal.window_summary(t["t_issue"], t["t_done"],
                                                  rid)
    w = log.summary(write_kind, duration=cfg.duration)
    bd["measured_write_p50_ms"] = w["p50_ms"]
    bd["measured_write_p99_ms"] = w["p99_ms"]
    bd["write_throughput"] = w.get("throughput", 0.0)
    bd["trace_audit"] = cluster.obs.tracer.audit_writes()
    if cfg.metrics_interval > 0:
        bd["metrics"] = cluster.obs.metrics.summary()
    return bd


def run_spinnaker_breakdown(spec: WorkloadSpec,
                            cfg: Optional[ExperimentConfig] = None) -> dict:
    """Strong-write latency breakdown: drive the mix with full tracing and
    decompose write p50 into client_queue / net_req / cpu / batch_wait /
    wal_force / commit_wait / reply_net stage contributions."""
    cfg = cfg or ExperimentConfig()
    sim, cluster = build_spinnaker(cfg, num_keys=_aligned_presplit(cfg, spec))
    loader = cluster.make_client("preload")
    n_pre = min(cfg.preload_keys or spec.num_keys, cfg.preload_cap,
                spec.num_keys)

    def pre_put(k, cb):
        # keep preload's burst writes out of the "write" trace population
        # (2000 simultaneous ops would pollute the stage rank band)
        loader.next_trace_kind = "preload"
        loader.put(k, "c", b"x" * spec.value_size, cb)

    _preload(sim, pre_put, n_pre)
    adapter = SpinnakerAdapter(cluster.make_client("bench"), consistent=True)
    log, _t_start, _drv = _drive(sim, adapter, spec, cfg, None, cluster,
                                 n_pre)
    return _breakdown_block(cluster, log, cfg, "write")


def _restart_stragglers(cluster) -> list[int]:
    """Defensively restart nodes a schedule left down (generated schedules
    restart their own crashes; this keeps hand-written ones honest)."""
    revived = []
    for nid, node in sorted(cluster.nodes.items()):
        if not node.up:
            cluster.restart_node(nid)
            revived.append(nid)
    return revived


def _aggregate_robustness(clients) -> dict:
    agg = {"retries": 0, "backoff_time_s": 0.0, "attempt_timeouts": 0,
           "retry_exhausted": 0, "error_counts": {}}
    for c in clients:
        s = c.robustness_summary()
        agg["retries"] += s["retries"]
        agg["backoff_time_s"] = round(
            agg["backoff_time_s"] + s["backoff_time_s"], 6)
        agg["attempt_timeouts"] += s["attempt_timeouts"]
        agg["retry_exhausted"] += s["retry_exhausted"]
        for code, n in s["error_counts"].items():
            agg["error_counts"][code] = agg["error_counts"].get(code, 0) + n
    agg["error_counts"] = dict(sorted(agg["error_counts"].items()))
    return agg


def run_spinnaker_chaos(seed: int = 0,
                        cfg: Optional[ExperimentConfig] = None,
                        schedule: Optional[FaultSchedule | str] = None,
                        duration: float = 18.0,
                        n_history_clients: int = 4,
                        history_keys: int = 24,
                        probe_period: float = 0.25,
                        recovery_bound: float = 4.0,
                        write_frac: float = 0.5,
                        export_journal: bool = False) -> dict:
    """One chaos run: drive history clients + per-range probe writers
    under a (generated or supplied) gray-failure schedule, then audit.

    Four audits close the run, all of which must pass for `ok`:

    - **linearizability** (`chaos.linearizability`): the recorded client
      history is checked per cell — no duplicate or reordered commit
      versions, no stale strong reads, no reads from the future;
    - **availability** (`chaos.availability`): the applied fault timeline
      is replayed into per-cohort majority-healthy windows; each window
      longer than `recovery_bound` must keep serving the cohort's probe
      writes within that bound (a minority-partitioned leader stalling a
      healthy majority fails exactly here);
    - **no lost acked writes**: every acknowledged (cell, version) must
      read back at >= that version after the run settles;
    - **trace audit**: sampled write traces show no torn commit chains.
    """
    from ..chaos import (HistoryRecorder, audit_availability,
                         check_linearizability, generate_chaos_schedule)

    cfg = cfg or ExperimentConfig(seed=seed, duration=duration)
    num_keys = max(history_keys, 2 * cfg.n_nodes)
    sim, cluster = build_spinnaker(cfg, num_keys=num_keys)
    loader = cluster.make_client("preload")
    _preload(sim, lambda k, cb: loader.put(k, "c", b"seed", cb), num_keys)

    sched_text = None
    if schedule is None:
        schedule = generate_chaos_schedule(
            seed, n_nodes=cfg.n_nodes, duration=duration,
            n_ranges=len(cluster.ranges))
    if isinstance(schedule, str):
        sched_text = schedule
        schedule = parse_schedule(schedule)
    cohorts = {rid: tuple(m) for rid, m in cluster.members.items()}

    # one probe key per base range (lowest preloaded key the range owns)
    probe_keys = {}
    for i in range(num_keys):
        rid = cluster.range_of(key_of(i))
        probe_keys.setdefault(rid, key_of(i))

    t0 = sim.now + 0.2           # schedule-relative time origin
    on_event = (lambda msg: cluster.obs.events.emit("fault", detail=msg))
    schedule.install(sim, cluster, at=t0, on_event=on_event)

    stop = [False]
    clients = []

    # history clients: closed-loop read/write mix over the shared keyspace
    recorders = []
    import random as _random
    for ci in range(n_history_clients):
        client = cluster.make_client(f"hist{ci}")
        clients.append(client)
        rec = HistoryRecorder(client, sim,
                              base_versions={(key_of(i), "c"): 1
                                             for i in range(num_keys)})
        recorders.append(rec)
        rng = _random.Random(seed * 1009 + ci)

        def loop(rec=rec, rng=rng):
            if stop[0]:
                return
            key = key_of(rng.randrange(history_keys))
            if rng.random() < write_frac:
                rec.put(key, "c", lambda r: loop())
            else:
                rec.get(key, "c", lambda r: loop())

        sim.schedule(0.01 * ci, loop)

    # probe writers: open-loop, one per cohort, fresh op every period so
    # recovery is observed promptly even while older probes back off
    probe_acks: dict[int, list] = {rid: [] for rid in cohorts}
    probe_recs = {}

    def make_probe(rid, key, rec):
        # factory so each cohort's tick chain re-schedules *itself* (a bare
        # `tick` in the loop body would late-bind to the last iteration)
        def tick():
            if stop[0]:
                return
            rec.put(key, "probe",
                    lambda r: (r.ok and probe_acks[rid].append(
                        round(sim.now - t0, 6))))
            sim.schedule(probe_period, tick)
        return tick

    for rid, key in sorted(probe_keys.items()):
        client = cluster.make_client(f"probe{rid}")
        clients.append(client)
        rec = HistoryRecorder(client, sim)
        probe_recs[rid] = rec
        sim.schedule(0.05, make_probe(rid, key, rec))

    sim.run(until=t0 + duration)
    stop[0] = True

    # -- post-run: heal, revive, settle, audit -------------------------------
    cluster.heal()
    revived = _restart_stragglers(cluster)
    sim.run_for(3.0)             # drain in-flight retries / elections
    cluster.settle(timeout=30.0)
    sim.run_for(1.0)

    history = [op for rec in recorders for op in rec.history]
    probe_history = [op for rec in probe_recs.values() for op in rec.history]
    base = {(key_of(i), "c"): 1 for i in range(num_keys)}
    violations = check_linearizability(history + probe_history, base)

    availability = audit_availability(
        schedule.applied_events, cohorts, probe_acks, t_end=duration,
        recovery_bound=recovery_bound, n_nodes=cfg.n_nodes)

    auditor = cluster.make_client("audit")
    acked_max: dict[tuple, int] = {}
    for op in history + probe_history:
        if op.kind == "write" and op.ok and op.version is not None:
            cell = (op.key, op.col)
            acked_max[cell] = max(acked_max.get(cell, 0), op.version)
    lost = []
    for (key, col), ver in sorted(acked_max.items()):
        r = auditor.sync_get(key, col, consistent=True)
        if not r.ok or (r.version or 0) < ver:
            lost.append({"key": key, "col": col, "acked_version": ver,
                         "read": r.code.value, "read_version": r.version})

    trace_audit = cluster.obs.tracer.audit_writes()
    watchdog = cluster.obs.watchdog.summary()
    ok = (not violations and availability["ok"] and not lost
          and trace_audit.get("ok", True) and watchdog["ok"])
    extra = {}
    if export_journal:
        # full flight-recorder dump for the offline explainer
        # (benchmarks/explain.py) — opt-in, it dwarfs the result dict
        extra["journal_jsonl"] = cluster.obs.journal.to_jsonl()
    return {
        **extra,
        "seed": seed,
        "lease_enabled": cfg.lease_enabled,
        "duration_s": duration,
        "schedule": sched_text,
        "fault_events": list(schedule.applied),
        "history_ops": len(history),
        "probe_writes_acked": {rid: len(a)
                               for rid, a in sorted(probe_acks.items())},
        "linearizability": {"ok": not violations, "violations": violations},
        "availability": availability,
        "lost_acked_writes": lost,
        "revived_stragglers": revived,
        "client_robustness": _aggregate_robustness(clients),
        "trace_audit": trace_audit,
        "watchdog": watchdog,
        "ok": ok,
    }


def run_spinnaker_minority_leader(lease_enabled: bool = True,
                                  seed: int = 0,
                                  partition_at: float = 1.0,
                                  heal_at: float = 9.0,
                                  t_end: float = 14.0,
                                  probe_period: float = 0.1) -> dict:
    """The chaos harness's signature scenario: symmetric-partition a
    range's leader into the minority while its ZooKeeper session (direct,
    not routed through the data network) stays alive.

    Without leases the stale leader keeps the leadership znode, the
    majority side never re-elects, and the range stalls until the
    partition heals — the availability red flag.  With time-bounded
    leases the majority followers depose the silent leader after its
    lease window provably lapsed and fail over within
    `lease_duration + election` seconds; the cut-off leader abdicates and
    fences its own strong path.  Returns failover / stall measurements
    from the cluster event log plus client-observed write gaps."""
    cfg = ExperimentConfig(seed=seed, lease_enabled=lease_enabled)
    num_keys = 20
    sim, cluster = build_spinnaker(cfg, num_keys=num_keys)
    loader = cluster.make_client("preload")
    _preload(sim, lambda k, cb: loader.put(k, "c", b"seed", cb), num_keys)

    rid = 0
    probe_key = next(key_of(i) for i in range(num_keys)
                     if cluster.range_of(key_of(i)) == rid)
    old = cluster.leader_replica(rid)
    old_leader, old_epoch = old.node.node_id, old.epoch
    lease_duration = old.cfg.lease_duration

    t0 = sim.now
    others = {n for n in cluster.nodes if n != old_leader}
    sim.schedule(partition_at, lambda: cluster.partition({old_leader},
                                                         others))
    sim.schedule(heal_at, cluster.heal)

    acks: list[float] = []
    stop = [False]
    client = cluster.make_client("probe")

    def tick():
        if stop[0]:
            return
        client.put(probe_key, "probe", b"p",
                   lambda r: (r.ok and acks.append(sim.now - t0)))
        sim.schedule(probe_period, tick)

    sim.schedule(0.0, tick)

    # sample the cut-off leader's state well after its lease must have
    # lapsed (evidence of self-fencing, recorded mid-partition)
    sample = {}

    def snap():
        rep = cluster.nodes[old_leader].replicas.get(rid)
        from ..core.replica import Role
        sample["old_leader_role"] = rep.role.name if rep else "GONE"
        sample["old_leader_lease_valid"] = (
            bool(rep.lease_valid()) if rep else False)

    sim.schedule(partition_at + lease_duration + 1.0, snap)

    sim.run(until=t0 + t_end)
    stop[0] = True
    cluster.settle(timeout=30.0)

    failover_s = None
    for ev in cluster.obs.events.events:
        if (ev["kind"] == "leader_open" and ev.get("rid") == rid
                and ev.get("epoch", 0) > old_epoch
                and ev["t"] >= t0 + partition_at):
            failover_s = round(ev["t"] - (t0 + partition_at), 6)
            break

    gap_after_partition = None
    for t in acks:
        if t > partition_at:
            gap_after_partition = round(t - partition_at, 6)
            break
    return {
        "lease_enabled": lease_enabled,
        "lease_duration_s": lease_duration,
        "partition_at_s": partition_at,
        "heal_at_s": heal_at,
        "old_leader": old_leader,
        "failover_s": failover_s,          # None: no re-election happened
        "stalled_until_heal": failover_s is None
        or failover_s > heal_at - partition_at,
        "first_ack_gap_s": gap_after_partition,
        "probe_acks": len(acks),
        **sample,
    }


def run_cassandra_breakdown(spec: WorkloadSpec,
                            cfg: Optional[ExperimentConfig] = None) -> dict:
    """Same decomposition for the Cassandra baseline (quorum writes):
    client_queue / net_req / cpu / durable_wait / reply_net."""
    cfg = cfg or ExperimentConfig()
    sim, cluster = build_cassandra(cfg)
    loader = cluster.make_client("preload")
    n_pre = min(cfg.preload_keys or spec.num_keys, cfg.preload_cap,
                spec.num_keys)

    def pre_put(k, cb):
        loader.next_trace_kind = "preload"
        loader.write(k, "c", b"x" * spec.value_size, True, cb)

    _preload(sim, pre_put, n_pre)
    adapter = CassandraAdapter(cluster.make_client("bench"), quorum=True)
    log, _t_start, _drv = _drive(sim, adapter, spec, cfg, None, cluster,
                                 n_pre)
    return _breakdown_block(cluster, log, cfg, "write")
