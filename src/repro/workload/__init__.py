"""Workload engine (paper §9): YCSB-style load generation, open/closed-loop
drivers on the discrete-event simulator, a fault-schedule DSL, and the
experiment plumbing behind `benchmarks/spinnaker_bench.py`.

Layers:

- `generators` — key/op/value/inter-arrival sampling, vectorized in JAX so
  millions of ops are pre-sampled in batches instead of per-op Python;
- `metrics`   — log-binned latency histograms, p50/p95/p99, and sliding-
  window throughput/availability timelines (Figs. 9-10);
- `drivers`   — closed-loop (N clients) and open-loop (Poisson) drivers
  plus adapters for the Spinnaker and Cassandra client libraries;
- `scenario`  — declarative fault timelines ("at 10s crash node 2 ...");
- `experiment`— build-cluster/preload/drive/collect, one call per curve.
"""

from .drivers import (AckLedgerAdapter, CassandraAdapter, ClosedLoopDriver,
                      OpenLoopDriver, SpinnakerAdapter, TxnAdapter)
from .generators import Op, OpKind, OpStream, WorkloadSpec
from .metrics import LatencyHistogram, OpLog, WindowSummary
from .scenario import FaultEvent, FaultSchedule, parse_schedule
from .experiment import (ExperimentConfig, run_cassandra_breakdown,
                         run_cassandra_profiled, run_cassandra_workload,
                         run_spinnaker_breakdown, run_spinnaker_chaos,
                         run_spinnaker_minority_leader,
                         run_spinnaker_profiled, run_spinnaker_rebalance,
                         run_spinnaker_saturation, run_spinnaker_txn,
                         run_spinnaker_workload)

__all__ = [
    "AckLedgerAdapter",
    "CassandraAdapter",
    "ClosedLoopDriver",
    "ExperimentConfig",
    "FaultEvent",
    "FaultSchedule",
    "LatencyHistogram",
    "Op",
    "OpKind",
    "OpLog",
    "OpenLoopDriver",
    "OpStream",
    "SpinnakerAdapter",
    "TxnAdapter",
    "WindowSummary",
    "WorkloadSpec",
    "parse_schedule",
    "run_cassandra_breakdown",
    "run_cassandra_profiled",
    "run_cassandra_workload",
    "run_spinnaker_breakdown",
    "run_spinnaker_chaos",
    "run_spinnaker_minority_leader",
    "run_spinnaker_profiled",
    "run_spinnaker_rebalance",
    "run_spinnaker_saturation",
    "run_spinnaker_txn",
    "run_spinnaker_workload",
]
