"""Latency histograms and sliding-window timelines.

`LatencyHistogram` keeps log-spaced bins (bounded memory at millions of
ops) and answers percentiles by CDF interpolation; `OpLog` tags every
completed op with (time, kind, ok, latency) and can slice the run into
fixed windows — throughput, error rate, and percentiles per window — which
is exactly the shape of the paper's Figs. 9-10 (availability and latency
through a failure).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# 1 µs .. 1000 s, 240 bins per decade.  30/decade (7.97% bin growth) was
# too coarse for tail reporting: a tight p95/p99 pair would collapse into
# one bin and read back as the identical edge value.  240/decade keeps the
# quantization error under 1% while the histogram stays ~17 KB.
_LO, _HI, _PER_DECADE = 1e-6, 1e3, 240


class LatencyHistogram:
    """Log-binned latency histogram with interpolated percentiles."""

    def __init__(self):
        decades = math.log10(_HI / _LO)
        self.n_bins = int(decades * _PER_DECADE) + 2
        self.counts = np.zeros(self.n_bins, dtype=np.int64)
        self.total = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = 0.0

    def _bin(self, v: float) -> int:
        if v <= _LO:
            return 0
        idx = int(math.log10(v / _LO) * _PER_DECADE) + 1
        return min(idx, self.n_bins - 1)

    def add(self, v: float) -> None:
        self.counts[self._bin(v)] += 1
        self.total += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def merge(self, other: "LatencyHistogram") -> None:
        self.counts += other.counts
        self.total += other.total
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else math.nan

    def percentile(self, p: float) -> float:
        """p in [0, 100]; returns the bin's upper edge (<1% log error)."""
        if not self.total:
            return math.nan
        target = p / 100.0 * self.total
        cum = np.cumsum(self.counts)
        idx = int(np.searchsorted(cum, max(target, 1)))
        idx = min(idx, self.n_bins - 1)
        edge = _LO * 10 ** (idx / _PER_DECADE)
        return float(min(max(edge, self.min), self.max))

    def summary(self) -> dict:
        return {
            "count": int(self.total),
            "mean_ms": self.mean * 1e3,
            "p50_ms": self.percentile(50) * 1e3,
            "p95_ms": self.percentile(95) * 1e3,
            "p99_ms": self.percentile(99) * 1e3,
            "min_ms": (self.min if self.total else math.nan) * 1e3,
            "max_ms": self.max * 1e3,
        }


@dataclass
class WindowSummary:
    """One sliding-window sample of a timeline."""
    t_start: float
    t_end: float
    kind: str
    throughput: float          # successful ops/s
    error_rate: float          # failed / issued
    p50_ms: float
    p95_ms: float
    p99_ms: float


class OpLog:
    """Append-only record of completed ops; the single sink every driver
    writes into.

    Columns live in pre-allocated numpy arrays (doubling growth) with
    kinds interned to small int codes, so `count` and `windows` are
    vectorized scans instead of per-row Python loops — material at the
    10^5+ ops a saturation run produces."""

    def __init__(self):
        self._cap = 1024
        self._n = 0
        self._t = np.empty(self._cap, dtype=np.float64)
        self._lat = np.empty(self._cap, dtype=np.float64)
        self._kc = np.empty(self._cap, dtype=np.int32)     # kind codes
        self._okv = np.empty(self._cap, dtype=bool)
        self._code_of: dict[str, int] = {}
        self._name_of: list[str] = []
        self.hists: dict[str, LatencyHistogram] = {}

    def _grow(self) -> None:
        self._cap *= 2
        for name in ("_t", "_lat", "_kc", "_okv"):
            old = getattr(self, name)
            new = np.empty(self._cap, dtype=old.dtype)
            new[:self._n] = old[:self._n]
            setattr(self, name, new)

    def record(self, t_done: float, kind: str, ok: bool,
               latency: float) -> None:
        if self._n == self._cap:
            self._grow()
        code = self._code_of.get(kind)
        if code is None:
            code = self._code_of[kind] = len(self._name_of)
            self._name_of.append(kind)
        i = self._n
        self._t[i] = t_done
        self._lat[i] = latency
        self._kc[i] = code
        self._okv[i] = ok
        self._n = i + 1
        if ok:
            self.hists.setdefault(kind, LatencyHistogram()).add(latency)

    def __len__(self) -> int:
        return self._n

    def count(self, kind: Optional[str] = None, ok: Optional[bool] = None
              ) -> int:
        n = self._n
        if n == 0:
            return 0
        mask = np.ones(n, dtype=bool)
        if kind is not None:
            code = self._code_of.get(kind)
            if code is None:
                return 0
            mask &= self._kc[:n] == code
        if ok is not None:
            mask &= self._okv[:n] == ok
        return int(mask.sum())

    def summary(self, kind: str, duration: Optional[float] = None) -> dict:
        h = self.hists.get(kind)
        out = h.summary() if h else LatencyHistogram().summary()
        out["errors"] = self.count(kind=kind, ok=False)
        if duration:
            out["throughput"] = out["count"] / duration
        return out

    def windows(self, width: float, kind: Optional[str] = None,
                t0: Optional[float] = None, t1: Optional[float] = None
                ) -> list[WindowSummary]:
        """Slice [t0, t1) into `width`-second windows (Figs. 9-10 series).
        The final window is clamped to `t1`, and its throughput divides by
        the clamped width — a 0.5 s tail no longer reads as half the rate
        it actually sustained."""
        n = self._n
        if n == 0:
            return []
        t = self._t[:n]
        lat = self._lat[:n]
        ok = self._okv[:n]
        sel = np.ones(n, dtype=bool)
        if kind is not None:
            code = self._code_of.get(kind)
            if code is None:
                return []
            sel &= self._kc[:n] == code
        t0 = float(t.min()) if t0 is None else t0
        t1 = float(t.max()) + 1e-9 if t1 is None else t1
        out = []
        w0 = t0
        while w0 < t1:
            w1 = min(w0 + width, t1)
            m = sel & (t >= w0) & (t < w1)
            good = m & ok
            n_issued = int(m.sum())
            n_ok = int(good.sum())
            if n_ok:
                ls = np.sort(lat[good])
                pct = lambda p: float(
                    ls[min(len(ls) - 1, int(p / 100 * len(ls)))]) * 1e3
                p50, p95, p99 = pct(50), pct(95), pct(99)
            else:
                p50 = p95 = p99 = math.nan
            out.append(WindowSummary(
                t_start=w0, t_end=w1, kind=kind or "all",
                throughput=n_ok / (w1 - w0),
                error_rate=(n_issued - n_ok) / n_issued if n_issued else 0.0,
                p50_ms=p50, p95_ms=p95, p99_ms=p99))
            w0 += width
        return out
