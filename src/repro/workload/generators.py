"""YCSB-style workload generators, vectorized in JAX.

The hot inner loops — Zipfian CDF inversion, op-mix choice, value sizing,
Poisson inter-arrival sampling — run as one jitted program that fills a
whole batch of ops at a time; the per-op Python path is an array index
into pre-sampled numpy buffers.  Key distributions:

- `uniform`: every key equally likely;
- `zipfian`: rank r drawn with P(r) ∝ 1/r^theta (YCSB theta=0.99), with a
  bijective multiplicative scramble so hot ranks spread over the keyspace
  (and therefore over range partitions) instead of piling on node 0;
- `latest`: zipfian over recency — hot keys are the most recently written,
  skewing toward the tail of the keyspace.
"""

from __future__ import annotations

import enum
import math
from dataclasses import dataclass
from functools import partial
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class OpKind(enum.IntEnum):
    READ = 0
    WRITE = 1
    RMW = 2         # read-modify-write: strong read, then conditional put
    COND = 3        # conditional put at the last-read version
    TXN = 4         # multi-key transaction (adapter picks the partner keys)


@dataclass(frozen=True)
class Op:
    kind: OpKind
    key_index: int
    value_size: int


@dataclass
class WorkloadSpec:
    """One workload = key distribution + op mix + value sizing."""
    num_keys: int = 10_000
    key_dist: str = "zipfian"          # uniform | zipfian | latest
    zipf_theta: float = 0.99
    scramble: bool = True
    # op mix (normalized at build time)
    read_frac: float = 0.80
    write_frac: float = 0.15
    rmw_frac: float = 0.03
    cond_frac: float = 0.02
    txn_frac: float = 0.0              # multi-key transactions (PR 4)
    # fraction of TXN ops that deliberately span ranges (the adapter
    # resolves partner keys against the live range table, so "cross"
    # means a real 2PC and "local" the single-cohort fast path)
    txn_cross_frac: float = 0.5
    # value sizes (bytes)
    value_size: int = 4096
    value_size_dist: str = "fixed"     # fixed | uniform
    value_size_min: int = 256

    def mix(self) -> np.ndarray:
        m = np.array([self.read_frac, self.write_frac, self.rmw_frac,
                      self.cond_frac, self.txn_frac], dtype=np.float64)
        s = m.sum()
        if s <= 0:
            raise ValueError("op mix must have positive mass")
        return m / s


def _coprime_multiplier(n: int) -> int:
    """Odd multiplicative-hash constant coprime to n (bijective mod n)."""
    a = 2654435761 % n
    while a < 2 or math.gcd(a, n) != 1:
        a = (a + 1) % n or 3
    return a


def _zipf_cdf(n: int, theta: float) -> jnp.ndarray:
    # one-time precompute in f64 on the host; inversion happens in JAX
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-theta)
    c = np.cumsum(w)
    return jnp.asarray(c / c[-1], jnp.float32)


@partial(jax.jit, static_argnames=("num_keys", "vfix", "vmin", "vmax",
                                   "batch"))
def _sample_batch(key, cdf: Optional[jnp.ndarray], mix_cdf: jnp.ndarray,
                  num_keys: int, vfix: int, vmin: int, vmax: int,
                  batch: int):
    """One fused sampling step: (key ranks, op kinds, value sizes, gaps)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    u = jax.random.uniform(k1, (batch,))
    if cdf is None:                       # uniform keys
        ranks = jnp.floor(u * num_keys).astype(jnp.int32)
    else:                                 # zipfian CDF inversion
        ranks = jnp.searchsorted(cdf, u).astype(jnp.int32)
    ranks = jnp.clip(ranks, 0, num_keys - 1)
    ops = jnp.searchsorted(mix_cdf, jax.random.uniform(k2, (batch,)))
    if vmax > vmin:
        vsz = jax.random.randint(k3, (batch,), vmin, vmax + 1)
    else:
        vsz = jnp.full((batch,), vfix, jnp.int32)
    # unit-rate exponential gaps; the driver scales by 1/rate
    gaps = -jnp.log1p(-jax.random.uniform(k4, (batch,)))
    return ranks, ops.astype(jnp.int32), vsz.astype(jnp.int32), \
        gaps.astype(jnp.float32)


class OpStream:
    """Iterator of `Op`s backed by JAX batch sampling.

    `next_op()` costs an array read; a new jitted batch is drawn every
    `batch` ops.  Streams with the same (spec, seed) are identical, which
    makes every benchmark bit-reproducible.
    """

    def __init__(self, spec: WorkloadSpec, seed: int = 0, batch: int = 8192):
        if spec.num_keys < 1:
            raise ValueError("num_keys must be >= 1")
        if spec.key_dist not in ("uniform", "zipfian", "latest"):
            raise ValueError(f"unknown key_dist {spec.key_dist!r}")
        self.spec = spec
        self.batch = batch
        self._key = jax.random.PRNGKey(seed)
        self._cdf = None
        if spec.key_dist in ("zipfian", "latest"):
            self._cdf = _zipf_cdf(spec.num_keys, spec.zipf_theta)
        self._mix_cdf = jnp.asarray(np.cumsum(spec.mix()), jnp.float32)
        self._mult = _coprime_multiplier(spec.num_keys) \
            if (spec.scramble and spec.key_dist == "zipfian"
                and spec.num_keys > 1) else 1
        self._offset = (seed * 40503 + 12345) % spec.num_keys
        if spec.value_size_dist == "uniform":
            self._vmin, self._vmax = spec.value_size_min, spec.value_size
        else:
            self._vmin = self._vmax = spec.value_size
        self._i = self.batch          # force refill on first use
        self._keys = self._ops = self._vsz = self._gaps = None
        self.sampled = 0
        # `latest` support: the most recently inserted key index; drivers
        # bump this on successful writes
        self.insert_horizon = spec.num_keys

    def _refill(self) -> None:
        self._key, sub = jax.random.split(self._key)
        keys, ops, vsz, gaps = _sample_batch(
            sub, self._cdf, self._mix_cdf, self.spec.num_keys,
            self.spec.value_size, self._vmin, self._vmax, self.batch)
        keys = np.asarray(keys)
        if self._mult > 1:
            # bijective scramble rank -> key in int64 on the host (the
            # product overflows int32 for large keyspaces under jit)
            keys = ((keys.astype(np.int64) * self._mult + self._offset)
                    % self.spec.num_keys).astype(np.int32)
        self._keys = keys
        self._ops = np.asarray(ops)
        self._vsz = np.asarray(vsz)
        self._gaps = np.asarray(gaps)
        self._i = 0
        self.sampled += self.batch

    def _key_index(self, rank: int) -> int:
        if self.spec.key_dist == "latest":
            # rank 0 = newest key; clip to the current horizon
            return max(0, min(self.insert_horizon, self.spec.num_keys) - 1
                       - rank)
        return int(rank)

    def next_op(self) -> Op:
        if self._i >= self.batch:
            self._refill()
        i = self._i
        self._i += 1
        return Op(kind=OpKind(int(self._ops[i])),
                  key_index=self._key_index(int(self._keys[i])),
                  value_size=int(self._vsz[i]))

    def next_gap(self, rate: float) -> float:
        """Next Poisson inter-arrival time at `rate` ops/s."""
        if self._i >= self.batch:
            self._refill()
        g = float(self._gaps[self._i]) / rate
        # gaps ride along with ops in the same buffer; consuming a gap does
        # not consume the op at the same slot (open-loop drivers call
        # next_gap then next_op, which advances the cursor once)
        return g

    def __iter__(self) -> Iterator[Op]:
        while True:
            yield self.next_op()
