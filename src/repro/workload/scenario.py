"""Declarative fault-schedule DSL.

A scenario is a timeline of one-line directives, e.g.::

    # Fig. 9: kill the leader mid-load, watch availability recover
    at 10s   crash node 2 lose_disk
    at 25s   restart node 2
    at 40s   partition {0,1} | {2,3,4}
    at 55s   heal
    at 60s   crash leader of 0

Grammar (one directive per line, '#' starts a comment):

    at <T>[s] crash node <i> [lose_disk] [no_expire]
    at <T>[s] crash leader of <rid> [lose_disk] [no_expire]
    at <T>[s] restart node <i>
    at <T>[s] restart crashed          # most recently crashed node
    at <T>[s] partition {i,j,...} | {k,...} [| ...]
    at <T>[s] heal
    at <T>[s] split range <rid> [at <key>]       # live split (median default)
    at <T>[s] move range <rid> [from <i>] [to <j>]   # replica migration
    at <T>[s] autobalance on|off                 # hotspot balancer
    at <T>[s] crash txn coordinator [lose_disk] [no_expire]  # mid-2PC kill
    at <T>[s] partition oneway {i,...} -> {j,...}   # asymmetric cut (cumulative)
    at <T>[s] drop link <i> <j> p=<p>            # directed link loses msgs
    at <T>[s] dup link <i> <j> p=<p>             # directed link duplicates
    at <T>[s] slow link <i> <j> x<f>             # directed link delay spike
    at <T>[s] slow disk on <i> x<f>              # gray log device
    at <T>[s] slow cpu on <i> x<f>               # gray CPU
    at <T>[s] flap session of <i> [for <d>s]     # ZK session expiry + rejoin

`heal` clears every injected network fault — symmetric AND one-way
partitions, per-link drop/dup/delay — and resets disk/CPU gray
multipliers; crashed nodes need an explicit `restart`.

`crash leader of <rid>` resolves *at fire time* — whoever leads cohort
`rid` then is killed, so the same scenario file exercises every failover
regime regardless of which node won the previous election.
`crash txn coordinator` also resolves at fire time: it kills the node
currently coordinating the most in-flight 2PC transactions (falling back
to the node holding the most prepared participant state), which is how
the txn scenarios land a kill genuinely mid-two-phase-commit.  The range
events likewise resolve at fire time (`move range` picks a follower
source and an up non-member destination when omitted) and require a
cluster with elastic range management (Spinnaker); they are recorded as
honest no-ops elsewhere.  Times are absolute sim-time seconds (offset by
`install(at=...)`).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Callable, Optional

_AT = re.compile(r"^at\s+([0-9.]+)s?\s+(.*)$")
_CRASH_NODE = re.compile(r"^crash\s+node\s+(\d+)\s*(.*)$")
_CRASH_LEADER = re.compile(r"^crash\s+leader\s+of\s+(\d+)\s*(.*)$")
_CRASH_TXN_COORD = re.compile(r"^crash\s+txn\s+coordinator\s*(.*)$")
_RESTART = re.compile(r"^restart\s+(node\s+\d+|crashed)$")
_PARTITION = re.compile(r"^partition\s+(.*)$")
_GROUP = re.compile(r"\{([0-9,\s]*)\}")
_SPLIT = re.compile(r"^split\s+range\s+(\d+)(?:\s+at\s+(\S+))?$")
_MOVE = re.compile(
    r"^move\s+range\s+(\d+)(?:\s+from\s+(\d+))?(?:\s+to\s+(\d+))?$")
_AUTOBALANCE = re.compile(r"^autobalance\s+(on|off)$")
_ONEWAY = re.compile(r"^partition\s+oneway\s+(\{[0-9,\s]*\})\s*->\s*"
                     r"(\{[0-9,\s]*\})$")
_LINK = re.compile(r"^(drop|dup)\s+link\s+(\d+)\s+(\d+)\s+p=([0-9.]+)$")
_SLOW_LINK = re.compile(r"^slow\s+link\s+(\d+)\s+(\d+)\s+x([0-9.]+)$")
_SLOW_NODE = re.compile(r"^slow\s+(disk|cpu)\s+on\s+(\d+)\s+x([0-9.]+)$")
_FLAP = re.compile(r"^flap\s+session\s+of\s+(\d+)(?:\s+for\s+([0-9.]+)s?)?$")


@dataclass(frozen=True)
class FaultEvent:
    t: float
    action: str   # crash | crash_leader | crash_txn_coord | restart |
                  # partition | partition_oneway | link | slow_disk |
                  # slow_cpu | flap | heal | split | move | autobalance
    node: Optional[int] = None
    rid: Optional[int] = None
    lose_disk: bool = False
    expire_session: bool = True
    groups: tuple = ()
    key: Optional[str] = None    # split point ('split range ... at <key>')
    src: Optional[int] = None    # move source / link source node
    dst: Optional[int] = None    # move destination / link destination node
    on: bool = True              # autobalance on/off
    drop_p: Optional[float] = None   # link drop probability
    dup_p: Optional[float] = None    # link duplication probability
    factor: Optional[float] = None   # link delay / disk / cpu multiplier
    outage: float = 1.0              # session-flap outage duration (s)

    def describe(self) -> str:
        if self.action == "crash":
            return f"t={self.t}: crash node {self.node}" + \
                (" (disk lost)" if self.lose_disk else "")
        if self.action == "crash_leader":
            return f"t={self.t}: crash leader of range {self.rid}"
        if self.action == "crash_txn_coord":
            return f"t={self.t}: crash txn coordinator"
        if self.action == "restart":
            return f"t={self.t}: restart node {self.node}"
        if self.action == "partition":
            return f"t={self.t}: partition " + \
                "|".join("{" + ",".join(map(str, g)) + "}"
                         for g in self.groups)
        if self.action == "split":
            at = f" at {self.key}" if self.key else ""
            return f"t={self.t}: split range {self.rid}{at}"
        if self.action == "move":
            src = f" from {self.src}" if self.src is not None else ""
            dst = f" to {self.dst}" if self.dst is not None else ""
            return f"t={self.t}: move range {self.rid}{src}{dst}"
        if self.action == "autobalance":
            return f"t={self.t}: autobalance {'on' if self.on else 'off'}"
        if self.action == "partition_oneway":
            a, b = self.groups
            return (f"t={self.t}: partition oneway "
                    "{" + ",".join(map(str, a)) + "} -> "
                    "{" + ",".join(map(str, b)) + "}")
        if self.action == "link":
            parts = []
            if self.drop_p:
                parts.append(f"drop p={self.drop_p}")
            if self.dup_p:
                parts.append(f"dup p={self.dup_p}")
            if self.factor is not None and self.factor != 1.0:
                parts.append(f"delay x{self.factor}")
            what = ", ".join(parts) or "clear"
            return f"t={self.t}: link {self.src}->{self.dst} {what}"
        if self.action == "slow_disk":
            return f"t={self.t}: slow disk on node {self.node} x{self.factor}"
        if self.action == "slow_cpu":
            return f"t={self.t}: slow cpu on node {self.node} x{self.factor}"
        if self.action == "flap":
            return (f"t={self.t}: flap session of node {self.node} "
                    f"for {self.outage}s")
        return f"t={self.t}: heal"


def _parse_flags(rest: str) -> dict:
    flags = set(rest.split())
    unknown = flags - {"lose_disk", "no_expire"}
    if unknown:
        raise ValueError(f"unknown crash flags: {sorted(unknown)}")
    return {"lose_disk": "lose_disk" in flags,
            "expire_session": "no_expire" not in flags}


def parse_schedule(text: str) -> "FaultSchedule":
    events = []
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        m = _AT.match(line)
        if not m:
            raise ValueError(f"line {lineno}: expected 'at <T>s ...': {raw!r}")
        t, body = float(m.group(1)), m.group(2).strip()
        if body == "heal":
            events.append(FaultEvent(t, "heal"))
            continue
        cm = _CRASH_NODE.match(body)
        if cm:
            events.append(FaultEvent(t, "crash", node=int(cm.group(1)),
                                     **_parse_flags(cm.group(2))))
            continue
        lm = _CRASH_LEADER.match(body)
        if lm:
            events.append(FaultEvent(t, "crash_leader", rid=int(lm.group(1)),
                                     **_parse_flags(lm.group(2))))
            continue
        tm = _CRASH_TXN_COORD.match(body)
        if tm:
            events.append(FaultEvent(t, "crash_txn_coord",
                                     **_parse_flags(tm.group(1))))
            continue
        rm = _RESTART.match(body)
        if rm:
            tgt = rm.group(1)
            node = None if tgt == "crashed" else int(tgt.split()[1])
            events.append(FaultEvent(t, "restart", node=node))
            continue
        om = _ONEWAY.match(body)
        if om:   # before _PARTITION: both start with 'partition'
            src = tuple(int(x) for x in _GROUP.match(om.group(1)).group(1)
                        .split(",") if x.strip())
            dst = tuple(int(x) for x in _GROUP.match(om.group(2)).group(1)
                        .split(",") if x.strip())
            if not src or not dst:
                raise ValueError(
                    f"line {lineno}: oneway partition needs non-empty "
                    f"groups: {raw!r}")
            events.append(FaultEvent(t, "partition_oneway",
                                     groups=(src, dst)))
            continue
        km = _LINK.match(body)
        if km:
            p = float(km.group(4))
            events.append(FaultEvent(
                t, "link", src=int(km.group(2)), dst=int(km.group(3)),
                drop_p=p if km.group(1) == "drop" else None,
                dup_p=p if km.group(1) == "dup" else None))
            continue
        slm = _SLOW_LINK.match(body)
        if slm:
            events.append(FaultEvent(t, "link", src=int(slm.group(1)),
                                     dst=int(slm.group(2)),
                                     factor=float(slm.group(3))))
            continue
        snm = _SLOW_NODE.match(body)
        if snm:
            events.append(FaultEvent(t, f"slow_{snm.group(1)}",
                                     node=int(snm.group(2)),
                                     factor=float(snm.group(3))))
            continue
        fm = _FLAP.match(body)
        if fm:
            outage = float(fm.group(2)) if fm.group(2) else 1.0
            events.append(FaultEvent(t, "flap", node=int(fm.group(1)),
                                     outage=outage))
            continue
        pm = _PARTITION.match(body)
        if pm:
            groups = tuple(
                tuple(int(x) for x in g.split(",") if x.strip())
                for g in _GROUP.findall(pm.group(1)))
            if len(groups) < 2:
                raise ValueError(
                    f"line {lineno}: partition needs >=2 groups: {raw!r}")
            events.append(FaultEvent(t, "partition", groups=groups))
            continue
        sm = _SPLIT.match(body)
        if sm:
            events.append(FaultEvent(t, "split", rid=int(sm.group(1)),
                                     key=sm.group(2)))
            continue
        mm = _MOVE.match(body)
        if mm:
            src = int(mm.group(2)) if mm.group(2) is not None else None
            dst = int(mm.group(3)) if mm.group(3) is not None else None
            events.append(FaultEvent(t, "move", rid=int(mm.group(1)),
                                     src=src, dst=dst))
            continue
        am = _AUTOBALANCE.match(body)
        if am:
            events.append(FaultEvent(t, "autobalance",
                                     on=am.group(1) == "on"))
            continue
        raise ValueError(f"line {lineno}: cannot parse {raw!r}")
    return FaultSchedule(sorted(events, key=lambda e: e.t))


@dataclass
class FaultSchedule:
    """Parsed timeline; `install` arms it on a simulator + cluster."""
    events: list[FaultEvent] = field(default_factory=list)
    applied: list[str] = field(default_factory=list)
    # structured mirror of `applied` (skips excluded): events as they
    # actually fired, with fire-time-resolved nodes — the availability
    # auditor replays this, not the pre-resolution schedule
    applied_events: list[FaultEvent] = field(default_factory=list)
    last_crashed: Optional[int] = None

    def install(self, sim, cluster, at: float = 0.0,
                on_event: Optional[Callable[[str], None]] = None) -> None:
        """Schedule every event at `at + event.t` against `cluster`.

        Works with any cluster exposing crash_node/restart_node and a
        `net` with partition support; `crash leader of` additionally needs
        `leader_replica` (Spinnaker only)."""
        for ev in self.events:
            sim.at(at + ev.t, self._fire, ev, cluster, on_event)

    def _crash(self, cluster, node: int, ev: FaultEvent) -> None:
        if _takes_expire(cluster):
            cluster.crash_node(node, lose_disk=ev.lose_disk,
                               expire_session=ev.expire_session)
        else:
            cluster.crash_node(node, lose_disk=ev.lose_disk)
        self.last_crashed = node

    @staticmethod
    def _find_txn_coordinator(cluster) -> Optional[int]:
        """Node currently coordinating the most in-flight 2PC transactions
        (resolved at fire time); falls back to the node holding the most
        prepared participant state.  None when no 2PC state exists."""
        best, best_score = None, (0, 0)
        for nid, node in sorted(getattr(cluster, "nodes", {}).items()):
            if not node.up:
                continue
            n_active = n_prepared = 0
            for rep in node.replicas.values():
                txn = getattr(rep, "txn", None)
                if txn is None:
                    continue
                n_active += len(txn.active)
                n_prepared += len(txn.prepared)
            score = (n_active, n_prepared)
            if score > best_score:
                best, best_score = nid, score
        return best

    def _fire(self, ev: FaultEvent, cluster, on_event) -> None:
        if ev.action == "crash":
            self._crash(cluster, ev.node, ev)
        elif ev.action == "crash_txn_coord":
            nid = self._find_txn_coordinator(cluster)
            if nid is None:
                msg = f"t={ev.t}: crash txn coordinator skipped " \
                      "(no in-flight transactions)"
                self.applied.append(msg)
                if on_event is not None:
                    on_event(msg)
                return
            self._crash(cluster, nid, ev)
            ev = FaultEvent(ev.t, "crash", node=nid, lose_disk=ev.lose_disk)
        elif ev.action == "crash_leader":
            rep = cluster.leader_replica(ev.rid)
            if rep is None:
                # record the no-op honestly: an artifact claiming a kill
                # that never happened would make recovery checks vacuous
                msg = f"t={ev.t}: crash leader of range {ev.rid} " \
                      "skipped (no open leader)"
                self.applied.append(msg)
                if on_event is not None:
                    on_event(msg)
                return
            nid = rep.node.node_id
            self._crash(cluster, nid, ev)
            ev = FaultEvent(ev.t, "crash", node=nid, lose_disk=ev.lose_disk)
        elif ev.action == "restart":
            node = ev.node if ev.node is not None else self.last_crashed
            if node is not None:
                cluster.restart_node(node)
                ev = FaultEvent(ev.t, "restart", node=node)
        elif ev.action == "partition":
            cluster.net.set_partition(ev.groups)
        elif ev.action == "partition_oneway":
            if hasattr(cluster, "partition_oneway"):
                cluster.partition_oneway(set(ev.groups[0]),
                                         set(ev.groups[1]))
            else:
                cluster.net.set_oneway_partition(set(ev.groups[0]),
                                                 set(ev.groups[1]))
        elif ev.action == "link":
            if hasattr(cluster, "set_link_fault"):
                cluster.set_link_fault(ev.src, ev.dst, drop_p=ev.drop_p,
                                       dup_p=ev.dup_p,
                                       delay_factor=ev.factor)
            else:
                cluster.net.update_link_fault(ev.src, ev.dst,
                                              drop_p=ev.drop_p,
                                              dup_p=ev.dup_p,
                                              delay_factor=ev.factor)
        elif ev.action in ("slow_disk", "slow_cpu", "flap"):
            ok = self._fire_gray_node_event(ev, cluster)
            if not ok:
                msg = f"{ev.describe()} skipped (not supported)"
                self.applied.append(msg)
                if on_event is not None:
                    on_event(msg)
                return
        elif ev.action == "heal":
            if hasattr(cluster, "heal"):
                cluster.heal()   # also resets disk/CPU gray multipliers
            else:
                cluster.net.clear_faults()
        elif ev.action in ("split", "move", "autobalance"):
            ok = self._fire_range_event(ev, cluster)
            if not ok:
                msg = f"{ev.describe()} skipped (not accepted)"
                self.applied.append(msg)
                if on_event is not None:
                    on_event(msg)
                return
        msg = ev.describe()
        self.applied.append(msg)
        self.applied_events.append(ev)
        if on_event is not None:
            on_event(msg)

    @staticmethod
    def _fire_gray_node_event(ev: FaultEvent, cluster) -> bool:
        """Node-local gray faults need the chaos cluster API (slow_disk /
        slow_cpu / flap_session); record an honest skip elsewhere."""
        nodes = getattr(cluster, "nodes", None)
        if nodes is None or ev.node not in nodes:
            return False
        if ev.action == "slow_disk":
            if hasattr(cluster, "slow_disk"):
                cluster.slow_disk(ev.node, ev.factor)
                return True
            disk = getattr(nodes[ev.node], "disk", None)
            if disk is None or not hasattr(disk, "slow_factor"):
                return False
            disk.slow_factor = ev.factor
            return True
        if ev.action == "slow_cpu":
            if hasattr(cluster, "slow_cpu"):
                cluster.slow_cpu(ev.node, ev.factor)
                return True
            cpu = getattr(nodes[ev.node], "cpu", None)
            if cpu is None or not hasattr(cpu, "slow_factor"):
                return False
            cpu.slow_factor = ev.factor
            return True
        # flap
        if hasattr(cluster, "flap_session"):
            cluster.flap_session(ev.node, ev.outage)
            return True
        if hasattr(nodes[ev.node], "flap_session"):
            nodes[ev.node].flap_session(ev.outage)
            return True
        return False

    @staticmethod
    def _fire_range_event(ev: FaultEvent, cluster) -> bool:
        """Range-management events need the elastic-range cluster API;
        record an honest skip on clusters (or states) that lack it."""
        if ev.action == "split":
            if not hasattr(cluster, "admin_split"):
                return False
            return cluster.admin_split(ev.rid, ev.key)
        if ev.action == "move":
            if not hasattr(cluster, "admin_move"):
                return False
            return cluster.admin_move(ev.rid, ev.src, ev.dst)
        if not hasattr(cluster, "set_autobalance"):
            return False
        cluster.set_autobalance(ev.on)
        return True


def _takes_expire(cluster) -> bool:
    import inspect
    try:
        return "expire_session" in inspect.signature(
            cluster.crash_node).parameters
    except (TypeError, ValueError):
        return False
