"""Declarative fault-schedule DSL.

A scenario is a timeline of one-line directives, e.g.::

    # Fig. 9: kill the leader mid-load, watch availability recover
    at 10s   crash node 2 lose_disk
    at 25s   restart node 2
    at 40s   partition {0,1} | {2,3,4}
    at 55s   heal
    at 60s   crash leader of 0

Grammar (one directive per line, '#' starts a comment):

    at <T>[s] crash node <i> [lose_disk] [no_expire]
    at <T>[s] crash leader of <rid> [lose_disk] [no_expire]
    at <T>[s] restart node <i>
    at <T>[s] restart crashed          # most recently crashed node
    at <T>[s] partition {i,j,...} | {k,...} [| ...]
    at <T>[s] heal

`crash leader of <rid>` resolves *at fire time* — whoever leads cohort
`rid` then is killed, so the same scenario file exercises every failover
regime regardless of which node won the previous election.  Times are
absolute sim-time seconds (offset by `install(at=...)`).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Callable, Optional

_AT = re.compile(r"^at\s+([0-9.]+)s?\s+(.*)$")
_CRASH_NODE = re.compile(r"^crash\s+node\s+(\d+)\s*(.*)$")
_CRASH_LEADER = re.compile(r"^crash\s+leader\s+of\s+(\d+)\s*(.*)$")
_RESTART = re.compile(r"^restart\s+(node\s+\d+|crashed)$")
_PARTITION = re.compile(r"^partition\s+(.*)$")
_GROUP = re.compile(r"\{([0-9,\s]*)\}")


@dataclass(frozen=True)
class FaultEvent:
    t: float
    action: str                  # crash | crash_leader | restart | partition | heal
    node: Optional[int] = None
    rid: Optional[int] = None
    lose_disk: bool = False
    expire_session: bool = True
    groups: tuple = ()

    def describe(self) -> str:
        if self.action == "crash":
            return f"t={self.t}: crash node {self.node}" + \
                (" (disk lost)" if self.lose_disk else "")
        if self.action == "crash_leader":
            return f"t={self.t}: crash leader of range {self.rid}"
        if self.action == "restart":
            return f"t={self.t}: restart node {self.node}"
        if self.action == "partition":
            return f"t={self.t}: partition " + \
                "|".join("{" + ",".join(map(str, g)) + "}"
                         for g in self.groups)
        return f"t={self.t}: heal"


def _parse_flags(rest: str) -> dict:
    flags = set(rest.split())
    unknown = flags - {"lose_disk", "no_expire"}
    if unknown:
        raise ValueError(f"unknown crash flags: {sorted(unknown)}")
    return {"lose_disk": "lose_disk" in flags,
            "expire_session": "no_expire" not in flags}


def parse_schedule(text: str) -> "FaultSchedule":
    events = []
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        m = _AT.match(line)
        if not m:
            raise ValueError(f"line {lineno}: expected 'at <T>s ...': {raw!r}")
        t, body = float(m.group(1)), m.group(2).strip()
        if body == "heal":
            events.append(FaultEvent(t, "heal"))
            continue
        cm = _CRASH_NODE.match(body)
        if cm:
            events.append(FaultEvent(t, "crash", node=int(cm.group(1)),
                                     **_parse_flags(cm.group(2))))
            continue
        lm = _CRASH_LEADER.match(body)
        if lm:
            events.append(FaultEvent(t, "crash_leader", rid=int(lm.group(1)),
                                     **_parse_flags(lm.group(2))))
            continue
        rm = _RESTART.match(body)
        if rm:
            tgt = rm.group(1)
            node = None if tgt == "crashed" else int(tgt.split()[1])
            events.append(FaultEvent(t, "restart", node=node))
            continue
        pm = _PARTITION.match(body)
        if pm:
            groups = tuple(
                tuple(int(x) for x in g.split(",") if x.strip())
                for g in _GROUP.findall(pm.group(1)))
            if len(groups) < 2:
                raise ValueError(
                    f"line {lineno}: partition needs >=2 groups: {raw!r}")
            events.append(FaultEvent(t, "partition", groups=groups))
            continue
        raise ValueError(f"line {lineno}: cannot parse {raw!r}")
    return FaultSchedule(sorted(events, key=lambda e: e.t))


@dataclass
class FaultSchedule:
    """Parsed timeline; `install` arms it on a simulator + cluster."""
    events: list[FaultEvent] = field(default_factory=list)
    applied: list[str] = field(default_factory=list)
    last_crashed: Optional[int] = None

    def install(self, sim, cluster, at: float = 0.0,
                on_event: Optional[Callable[[str], None]] = None) -> None:
        """Schedule every event at `at + event.t` against `cluster`.

        Works with any cluster exposing crash_node/restart_node and a
        `net` with partition support; `crash leader of` additionally needs
        `leader_replica` (Spinnaker only)."""
        for ev in self.events:
            sim.at(at + ev.t, self._fire, ev, cluster, on_event)

    def _crash(self, cluster, node: int, ev: FaultEvent) -> None:
        if _takes_expire(cluster):
            cluster.crash_node(node, lose_disk=ev.lose_disk,
                               expire_session=ev.expire_session)
        else:
            cluster.crash_node(node, lose_disk=ev.lose_disk)
        self.last_crashed = node

    def _fire(self, ev: FaultEvent, cluster, on_event) -> None:
        if ev.action == "crash":
            self._crash(cluster, ev.node, ev)
        elif ev.action == "crash_leader":
            rep = cluster.leader_replica(ev.rid)
            if rep is None:
                # record the no-op honestly: an artifact claiming a kill
                # that never happened would make recovery checks vacuous
                msg = f"t={ev.t}: crash leader of range {ev.rid} " \
                      "skipped (no open leader)"
                self.applied.append(msg)
                if on_event is not None:
                    on_event(msg)
                return
            nid = rep.node.node_id
            self._crash(cluster, nid, ev)
            ev = FaultEvent(ev.t, "crash", node=nid, lose_disk=ev.lose_disk)
        elif ev.action == "restart":
            node = ev.node if ev.node is not None else self.last_crashed
            if node is not None:
                cluster.restart_node(node)
                ev = FaultEvent(ev.t, "restart", node=node)
        elif ev.action == "partition":
            cluster.net.set_partition(ev.groups)
        elif ev.action == "heal":
            cluster.net.clear_partition()
        msg = ev.describe()
        self.applied.append(msg)
        if on_event is not None:
            on_event(msg)


def _takes_expire(cluster) -> bool:
    import inspect
    try:
        return "expire_session" in inspect.signature(
            cluster.crash_node).parameters
    except (TypeError, ValueError):
        return False
