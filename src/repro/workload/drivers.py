"""Load drivers running inside the discrete-event `Simulator`.

Adapters translate a generator `Op` into one async call against a store's
client library; drivers decide *when* ops are issued:

- `ClosedLoopDriver`: N virtual clients, each with at most one op in
  flight (the paper's §C methodology — load grows with the client count);
- `OpenLoopDriver`: Poisson arrivals at a target rate, independent of
  completion times — the driver that exposes latency collapse at
  saturation and availability gaps during failures (Figs. 9-10).

Both record completions into an `OpLog`.
"""

from __future__ import annotations

from typing import Callable, Optional

from ..core.cluster import key_of
from .generators import Op, OpKind, OpStream
from .metrics import OpLog


class SpinnakerAdapter:
    """Maps Ops onto the Spinnaker client library.

    reads: strong (leader) when `consistent`, else timeline with an
    optional monotonic session guarantee; RMW = strong read then put;
    COND = strong read then conditional_put at the version just seen.
    """

    def __init__(self, client, consistent: bool = True,
                 monotonic: bool = False, colname: str = "c"):
        self.client = client
        self.consistent = consistent
        self.monotonic = monotonic
        self.colname = colname

    def kind_name(self, op: Op) -> str:
        if op.kind == OpKind.READ:
            return "read" if self.consistent else "timeline_read"
        return {OpKind.WRITE: "write", OpKind.RMW: "rmw",
                OpKind.COND: "cond_put"}[op.kind]

    def issue(self, op: Op, done: Callable[[bool], None]) -> None:
        key = key_of(op.key_index)
        col = self.colname
        value = b"x" * op.value_size
        c = self.client
        if op.kind == OpKind.READ:
            # NOT_FOUND is a successful read of an absent key
            c.get(key, col, self.consistent,
                  lambda r: done(r.ok or r.code.value == "not_found"),
                  monotonic=self.monotonic)
        elif op.kind == OpKind.WRITE:
            c.put(key, col, value, lambda r: done(r.ok))
        elif op.kind == OpKind.RMW:
            c.get(key, col, True,
                  lambda r: c.put(key, col, value, lambda r2: done(r2.ok))
                  if r.ok or r.code.value == "not_found" else done(False))
        else:  # COND: optimistic concurrency at the observed version
            def after_read(r):
                if not (r.ok or r.code.value == "not_found"):
                    done(False)
                    return
                ver = r.version or 0
                # a VERSION_MISMATCH is a *successful* CAS rejection
                # (another client won the race), not unavailability
                c.conditional_put(
                    key, col, value, ver,
                    lambda r2: done(r2.ok
                                    or r2.code.value == "version_mismatch"))
            c.get(key, col, True, after_read)


class AckLedgerAdapter(SpinnakerAdapter):
    """SpinnakerAdapter that additionally records the highest acknowledged
    version per written key.

    The ledger is the audit trail behind the rebalance scenarios' "no lost
    acknowledged writes" check: after a run that splits/migrates ranges
    under load (with leader kills mixed in), every ledger entry must be
    readable at >= its acked version — a write the cluster confirmed can
    never disappear, no matter where its key lives now."""

    def __init__(self, client, ledger: dict, **kw):
        super().__init__(client, **kw)
        self.ledger = ledger            # key_index -> max acked version

    def issue(self, op: Op, done: Callable[[bool], None]) -> None:
        if op.kind != OpKind.WRITE:
            super().issue(op, done)
            return
        key = key_of(op.key_index)

        def on_put(r):
            if r.ok and r.version is not None:
                prev = self.ledger.get(op.key_index, 0)
                self.ledger[op.key_index] = max(prev, r.version)
            done(r.ok)

        self.client.put(key, self.colname, b"x" * op.value_size, on_put)


class CassandraAdapter:
    """Maps Ops onto the Cassandra baseline client; there is no CAS, so
    COND degrades to read-then-write (the consistency gap §9 points at)."""

    def __init__(self, client, quorum: bool = True, colname: str = "c"):
        self.client = client
        self.quorum = quorum
        self.colname = colname

    def kind_name(self, op: Op) -> str:
        base = {OpKind.READ: "read", OpKind.WRITE: "write",
                OpKind.RMW: "rmw", OpKind.COND: "cond_put"}[op.kind]
        return base if self.quorum else f"eventual_{base}"

    def issue(self, op: Op, done: Callable[[bool], None]) -> None:
        key = key_of(op.key_index)
        col = self.colname
        value = b"x" * op.value_size
        c = self.client
        if op.kind == OpKind.READ:
            c.read(key, col, self.quorum,
                   lambda r: done(r.ok or r.code.value == "not_found"))
        elif op.kind == OpKind.WRITE:
            c.write(key, col, value, self.quorum, lambda r: done(r.ok))
        else:  # RMW and COND both become read-then-write
            c.read(key, col, self.quorum,
                   lambda r: c.write(key, col, value, self.quorum,
                                     lambda r2: done(r2.ok))
                   if (r.ok or r.code.value == "not_found") else done(False))


class ClosedLoopDriver:
    """N clients, one outstanding op each; think_time inserts client-side
    pauses between completion and the next issue."""

    def __init__(self, sim, adapter, stream: OpStream, log: OpLog,
                 n_clients: int = 8, think_time: float = 0.0):
        self.sim = sim
        self.adapter = adapter
        self.stream = stream
        self.log = log
        self.n_clients = n_clients
        self.think_time = think_time
        self._t_end = 0.0
        self.issued = 0

    def run(self, duration: float, warmup: float = 0.0) -> None:
        """Drive for warmup+duration sim-seconds; ops completing during
        warmup are not recorded."""
        t_rec = self.sim.now + warmup
        self._t_end = t_rec + duration
        for _ in range(self.n_clients):
            self._loop(t_rec)
        self.sim.run(until=self._t_end)

    def _loop(self, t_rec: float) -> None:
        if self.sim.now >= self._t_end:
            return
        op = self.stream.next_op()
        kind = self.adapter.kind_name(op)
        t0 = self.sim.now
        self.issued += 1

        def done(ok: bool):
            if t0 >= t_rec and self.sim.now <= self._t_end:
                self.log.record(self.sim.now, kind, ok, self.sim.now - t0)
            if ok and op.kind != OpKind.READ:
                self.stream.insert_horizon = max(
                    self.stream.insert_horizon, op.key_index + 1)
            if self.think_time > 0:
                self.sim.schedule(self.think_time, self._loop, t_rec)
            else:
                self._loop(t_rec)

        self.adapter.issue(op, done)


class OpenLoopDriver:
    """Poisson arrivals at `rate` ops/s; completions never gate arrivals.

    `max_outstanding` bounds in-flight ops so a dead cluster cannot grow
    the event heap without limit — arrivals past the bound are recorded as
    failed (shed), which is what a real open-loop generator reports."""

    def __init__(self, sim, adapter, stream: OpStream, log: OpLog,
                 rate: float, max_outstanding: int = 10_000):
        self.sim = sim
        self.adapter = adapter
        self.stream = stream
        self.log = log
        self.rate = rate
        self.max_outstanding = max_outstanding
        self.outstanding = 0
        self.shed = 0
        self._t_end = 0.0

    def run(self, duration: float, warmup: float = 0.0) -> None:
        t_rec = self.sim.now + warmup
        self._t_end = t_rec + duration
        self._arrive(t_rec)
        self.sim.run(until=self._t_end)

    def _arrive(self, t_rec: float) -> None:
        if self.sim.now >= self._t_end:
            return
        gap = self.stream.next_gap(self.rate)
        op = self.stream.next_op()
        kind = self.adapter.kind_name(op)
        t0 = self.sim.now

        if self.outstanding >= self.max_outstanding:
            self.shed += 1
            if t0 >= t_rec:
                self.log.record(t0, kind, False, 0.0)
        else:
            self.outstanding += 1

            def done(ok: bool):
                self.outstanding -= 1
                if t0 >= t_rec and self.sim.now <= self._t_end:
                    self.log.record(self.sim.now, kind, ok,
                                    self.sim.now - t0)
                if ok and op.kind != OpKind.READ:
                    self.stream.insert_horizon = max(
                        self.stream.insert_horizon, op.key_index + 1)

            self.adapter.issue(op, done)
        self.sim.schedule(gap, self._arrive, t_rec)
