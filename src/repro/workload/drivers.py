"""Load drivers running inside the discrete-event `Simulator`.

Adapters translate a generator `Op` into one async call against a store's
client library; drivers decide *when* ops are issued:

- `ClosedLoopDriver`: N virtual clients, each with at most one op in
  flight (the paper's §C methodology — load grows with the client count);
- `OpenLoopDriver`: Poisson arrivals at a target rate, independent of
  completion times — the driver that exposes latency collapse at
  saturation and availability gaps during failures (Figs. 9-10).

Both record completions into an `OpLog`.
"""

from __future__ import annotations

from typing import Callable, Optional

from ..core.cluster import key_of
from ..core.types import OpType, WriteOp
from .generators import Op, OpKind, OpStream
from .metrics import OpLog


class SpinnakerAdapter:
    """Maps Ops onto the Spinnaker client library.

    reads: strong (leader) when `consistent`, else timeline with an
    optional monotonic session guarantee; RMW = strong read then a
    *conditional* put at the version just read, retried on conflict —
    the atomic path, not the racy read-then-blind-put it used to be;
    COND = one-shot conditional_put at the version just seen.

    Concurrency outcomes are surfaced in driver metrics: `rmw_conflicts`
    counts CAS rejections, `rmw_retries` the re-reads they triggered,
    `rmw_giveups` the RMWs that exhausted their retry budget (still a
    *successful* concurrency outcome — some other client won — but
    reported so contention is visible).
    """

    RMW_RETRIES = 4        # re-read budget per RMW before giving up the race

    def __init__(self, client, consistent: bool = True,
                 monotonic: bool = False, colname: str = "c"):
        self.client = client
        self.consistent = consistent
        self.monotonic = monotonic
        self.colname = colname
        self.rmw_conflicts = 0
        self.rmw_retries = 0
        self.rmw_giveups = 0

    def kind_name(self, op: Op) -> str:
        if op.kind == OpKind.READ:
            return "read" if self.consistent else "timeline_read"
        return {OpKind.WRITE: "write", OpKind.RMW: "rmw",
                OpKind.COND: "cond_put", OpKind.TXN: "txn"}[op.kind]

    def metrics(self) -> dict:
        return {"rmw_conflicts": self.rmw_conflicts,
                "rmw_retries": self.rmw_retries,
                "rmw_giveups": self.rmw_giveups,
                "lock_retries": self.client.lock_retries,
                "wrong_range_redirects": self.client.wrong_range_redirects}

    def issue(self, op: Op, done: Callable[[bool], None]) -> None:
        key = key_of(op.key_index)
        col = self.colname
        value = b"x" * op.value_size
        c = self.client
        # label the sampled trace with the workload kind, not the wire kind
        c.next_trace_kind = self.kind_name(op)
        if op.kind == OpKind.READ:
            # NOT_FOUND is a successful read of an absent key
            c.get(key, col, self.consistent,
                  lambda r: done(r.ok or r.code.value == "not_found"),
                  monotonic=self.monotonic)
        elif op.kind == OpKind.WRITE:
            c.put(key, col, value, lambda r: done(r.ok))
        elif op.kind == OpKind.RMW:
            self._rmw(key, col, value, done, tries=0)
        elif op.kind == OpKind.TXN:
            # plain adapter has no partner-key policy: a TXN op degrades
            # to an atomic RMW on its key (TxnAdapter does the real thing)
            self._rmw(key, col, value, done, tries=0)
        else:  # COND: optimistic concurrency at the observed version
            def after_read(r):
                if not (r.ok or r.code.value == "not_found"):
                    done(False)
                    return
                ver = r.version or 0
                # a VERSION_MISMATCH is a *successful* CAS rejection
                # (another client won the race), not unavailability
                c.next_trace_kind = "cond_put"
                c.conditional_put(
                    key, col, value, ver,
                    lambda r2: done(r2.ok
                                    or r2.code.value == "version_mismatch"))
            c.get(key, col, True, after_read)

    def _rmw(self, key: str, col: str, value, done: Callable[[bool], None],
             tries: int) -> None:
        """Atomic read-modify-write: conditional put at the read version,
        re-read + retry on conflict (bounded)."""
        c = self.client

        def after_read(r):
            if not (r.ok or r.code.value == "not_found"):
                done(False)
                return
            ver = r.version or 0

            def after_cas(r2):
                if r2.ok:
                    done(True)
                elif r2.code.value == "version_mismatch":
                    self.rmw_conflicts += 1
                    if tries < self.RMW_RETRIES:
                        self.rmw_retries += 1
                        self._rmw(key, col, value, done, tries + 1)
                    else:
                        self.rmw_giveups += 1
                        done(True)     # lost the race cleanly
                else:
                    done(False)

            c.next_trace_kind = "rmw"
            c.conditional_put(key, col, value, ver, after_cas)

        c.next_trace_kind = "rmw"
        c.get(key, col, True, after_read)


class AckLedgerAdapter(SpinnakerAdapter):
    """SpinnakerAdapter that additionally records the highest acknowledged
    version per written key.

    The ledger is the audit trail behind the rebalance scenarios' "no lost
    acknowledged writes" check: after a run that splits/migrates ranges
    under load (with leader kills mixed in), every ledger entry must be
    readable at >= its acked version — a write the cluster confirmed can
    never disappear, no matter where its key lives now."""

    def __init__(self, client, ledger: dict, **kw):
        super().__init__(client, **kw)
        self.ledger = ledger            # key_index -> max acked version

    def issue(self, op: Op, done: Callable[[bool], None]) -> None:
        if op.kind != OpKind.WRITE:
            super().issue(op, done)
            return
        key = key_of(op.key_index)

        def on_put(r):
            if r.ok and r.version is not None:
                prev = self.ledger.get(op.key_index, 0)
                self.ledger[op.key_index] = max(prev, r.version)
            done(r.ok)

        self.client.next_trace_kind = "write"
        self.client.put(key, self.colname, b"x" * op.value_size, on_put)


class TxnAdapter(SpinnakerAdapter):
    """SpinnakerAdapter whose TXN ops are *balance transfers* between the
    op's key and a partner key — the workload behind `--scenario txn`.

    A transfer strong-reads both accounts (one range-aware multi_get),
    then issues a conditional transaction moving `amount` from one to the
    other at the versions just read.  Partner choice is deterministic per
    key: a `txn_cross_frac` fraction of transfers picks a partner in a
    *different* range (resolved against the client's live range table, so
    it really exercises the 2PC path), the rest a same-range partner (the
    §8.2 single-cohort fast path).  OpLog kinds `txn_cross` / `txn_local`
    keep the two latency populations separate.

    Every acked transfer is ledgered ((key, version) pairs) and the whole
    workload preserves the global balance sum — the two facts the
    post-run audit checks: no acknowledged transaction lost, no partial
    commit visible."""

    def __init__(self, client, num_keys: int, cross_frac: float = 0.5,
                 amount: int = 1, ledger: Optional[list] = None, **kw):
        super().__init__(client, **kw)
        self.num_keys = num_keys
        self.cross_frac = cross_frac
        self.amount = amount
        self.ledger = ledger if ledger is not None else []
        self.txn_attempts = 0
        self.txn_commits = 0
        # clean CAS aborts (version mismatch at prepare/validate).  Lock
        # bounces never reach this callback — the client retries LOCKED
        # internally; they surface as `lock_retries` in metrics().
        self.txn_aborts = 0
        self.txn_failures = 0        # availability failures (timeouts)

    def metrics(self) -> dict:
        out = super().metrics()
        out.update({"txn_attempts": self.txn_attempts,
                    "txn_commits": self.txn_commits,
                    "txn_aborts": self.txn_aborts,
                    "txn_failures": self.txn_failures,
                    "txn_abort_rate": self.txn_aborts
                    / max(1, self.txn_attempts),
                    "txn2_issued": self.client.txn2_issued,
                    "mread_batches": self.client.mread_batches})
        return out

    def _is_cross(self, op: Op) -> bool:
        if self.cross_frac <= 0.0:
            return False
        if self.cross_frac >= 1.0:
            return True
        # deterministic per key (kind_name and issue must agree)
        return ((op.key_index * 2654435761 + 12345) % 1000) / 1000.0 \
            < self.cross_frac

    def kind_name(self, op: Op) -> str:
        if op.kind == OpKind.TXN:
            return "txn_cross" if self._is_cross(op) else "txn_local"
        return super().kind_name(op)

    def _partner(self, idx: int, cross: bool) -> int:
        """Partner account: same range as `idx` for local transfers, a
        different range for cross ones (checked against the cached range
        table; bounded probe walk)."""
        table = self.client.range_table
        home = table.lookup(key_of(idx))
        if cross:
            step = max(1, self.num_keys // 7)
            cand = (idx + self.num_keys // 2) % self.num_keys
            for _ in range(8):
                if cand != idx and table.lookup(key_of(cand)) != home:
                    return cand
                cand = (cand + step) % self.num_keys
            return cand                      # single-range keyspace: degrade
        for cand in (idx + 1, idx - 1):
            if 0 <= cand < self.num_keys \
                    and table.lookup(key_of(cand)) == home:
                return cand
        return idx                           # 1-key range: degenerate no-op

    def issue(self, op: Op, done: Callable[[bool], None]) -> None:
        if op.kind != OpKind.TXN:
            super().issue(op, done)
            return
        k1i = op.key_index
        k2i = self._partner(k1i, self._is_cross(op))
        if k2i == k1i:
            done(True)
            return
        k1, k2, col = key_of(k1i), key_of(k2i), self.colname
        c = self.client
        self.txn_attempts += 1

        def after_read(rs):
            r1, r2 = rs
            if not all(r.ok or r.code.value == "not_found" for r in rs):
                self.txn_failures += 1
                done(False)
                return
            b1 = r1.value if isinstance(r1.value, int) else 0
            b2 = r2.value if isinstance(r2.value, int) else 0
            ops = [WriteOp(OpType.COND_PUT, k1, col, b1 - self.amount,
                           expected_version=r1.version or 0),
                   WriteOp(OpType.COND_PUT, k2, col, b2 + self.amount,
                           expected_version=r2.version or 0)]

            def after_txn(res):
                if res.ok:
                    self.txn_commits += 1
                    self.ledger.append(((k1, (r1.version or 0) + 1),
                                        (k2, (r2.version or 0) + 1)))
                    done(True)
                elif res.code.value == "version_mismatch":
                    self.txn_aborts += 1
                    done(True)       # clean concurrency abort, nothing lost
                else:
                    self.txn_failures += 1
                    done(False)

            c.next_trace_kind = self.kind_name(op)
            c.transaction(ops, after_txn)

        c.next_trace_kind = self.kind_name(op)
        c.multi_get([(k1, col), (k2, col)], True, after_read)


class CassandraAdapter:
    """Maps Ops onto the Cassandra baseline client; there is no CAS (and
    no transactions), so COND — and TXN — degrade to read-then-write on
    the op's own key (the consistency gap §9 points at)."""

    def __init__(self, client, quorum: bool = True, colname: str = "c"):
        self.client = client
        self.quorum = quorum
        self.colname = colname

    def kind_name(self, op: Op) -> str:
        base = {OpKind.READ: "read", OpKind.WRITE: "write",
                OpKind.RMW: "rmw", OpKind.COND: "cond_put",
                OpKind.TXN: "txn"}[op.kind]
        return base if self.quorum else f"eventual_{base}"

    def issue(self, op: Op, done: Callable[[bool], None]) -> None:
        key = key_of(op.key_index)
        col = self.colname
        value = b"x" * op.value_size
        c = self.client
        label = self.kind_name(op)
        c.next_trace_kind = label

        def write_leg(r):
            if not (r.ok or r.code.value == "not_found"):
                done(False)
                return
            c.next_trace_kind = label
            c.write(key, col, value, self.quorum, lambda r2: done(r2.ok))

        if op.kind == OpKind.READ:
            c.read(key, col, self.quorum,
                   lambda r: done(r.ok or r.code.value == "not_found"))
        elif op.kind == OpKind.WRITE:
            c.write(key, col, value, self.quorum, lambda r: done(r.ok))
        else:  # RMW, COND, and TXN all become read-then-write
            c.read(key, col, self.quorum, write_leg)


class ClosedLoopDriver:
    """N clients, one outstanding op each; think_time inserts client-side
    pauses between completion and the next issue."""

    def __init__(self, sim, adapter, stream: OpStream, log: OpLog,
                 n_clients: int = 8, think_time: float = 0.0):
        self.sim = sim
        self.adapter = adapter
        self.stream = stream
        self.log = log
        self.n_clients = n_clients
        self.think_time = think_time
        self._t_end = 0.0
        self.issued = 0

    def run(self, duration: float, warmup: float = 0.0) -> None:
        """Drive for warmup+duration sim-seconds; ops completing during
        warmup are not recorded."""
        t_rec = self.sim.now + warmup
        self._t_end = t_rec + duration
        for _ in range(self.n_clients):
            self._loop(t_rec)
        self.sim.run(until=self._t_end)

    def _loop(self, t_rec: float) -> None:
        if self.sim.now >= self._t_end:
            return
        op = self.stream.next_op()
        kind = self.adapter.kind_name(op)
        t0 = self.sim.now
        self.issued += 1

        def done(ok: bool):
            if t0 >= t_rec and self.sim.now <= self._t_end:
                self.log.record(self.sim.now, kind, ok, self.sim.now - t0)
            if ok and op.kind != OpKind.READ:
                self.stream.insert_horizon = max(
                    self.stream.insert_horizon, op.key_index + 1)
            if self.think_time > 0:
                self.sim.schedule(self.think_time, self._loop, t_rec)
            else:
                self._loop(t_rec)

        self.adapter.issue(op, done)


class OpenLoopDriver:
    """Poisson arrivals at `rate` ops/s; completions never gate arrivals.

    `max_outstanding` bounds in-flight ops so a dead cluster cannot grow
    the event heap without limit — arrivals past the bound are recorded as
    failed (shed), which is what a real open-loop generator reports."""

    def __init__(self, sim, adapter, stream: OpStream, log: OpLog,
                 rate: float, max_outstanding: int = 10_000):
        self.sim = sim
        self.adapter = adapter
        self.stream = stream
        self.log = log
        self.rate = rate
        self.max_outstanding = max_outstanding
        self.outstanding = 0
        self.shed = 0
        self._t_end = 0.0

    def run(self, duration: float, warmup: float = 0.0) -> None:
        t_rec = self.sim.now + warmup
        self._t_end = t_rec + duration
        self._arrive(t_rec)
        self.sim.run(until=self._t_end)

    def _arrive(self, t_rec: float) -> None:
        if self.sim.now >= self._t_end:
            return
        gap = self.stream.next_gap(self.rate)
        op = self.stream.next_op()
        kind = self.adapter.kind_name(op)
        t0 = self.sim.now

        if self.outstanding >= self.max_outstanding:
            self.shed += 1
            if t0 >= t_rec:
                self.log.record(t0, kind, False, 0.0)
        else:
            self.outstanding += 1

            def done(ok: bool):
                self.outstanding -= 1
                if t0 >= t_rec and self.sim.now <= self._t_end:
                    self.log.record(self.sim.now, kind, ok,
                                    self.sim.now - t0)
                if ok and op.kind != OpKind.READ:
                    self.stream.insert_horizon = max(
                        self.stream.insert_horizon, op.key_index + 1)

            self.adapter.issue(op, done)
        self.sim.schedule(gap, self._arrive, t_rec)
