"""Batched serving engine: continuous-batching decode over a fixed slot
pool, with timeline-consistent weight refresh from the replicated store.

The engine owns a KV/SSM cache sized (slots, max_seq); requests are
admitted into free slots, prefilled token-by-token (teacher forcing
through the shared decode step keeps one compiled program for everything
— at 1000-node scale you never want a second XLA program per prompt
length), then decoded until EOS/max_tokens.  Weight refresh uses the
paper's *timeline* consistency: the engine polls the checkpoint store's
manifest with a timeline read (stale ≤ commit period) and hot-swaps
params between batches — serving never blocks the training commit path.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decode_step, init_cache
from ..models.config import ModelConfig


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    output: list[int] = field(default_factory=list)
    done: bool = False


@dataclass
class ServeConfig:
    slots: int = 4
    max_seq: int = 256
    eos_id: int = 1
    greedy: bool = True
    refresh_every_batches: int = 0     # 0 = no weight refresh polling


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig,
                 store=None, run_id: str = "run0"):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.store = store
        self.run_id = run_id
        self.cache = init_cache(cfg, scfg.slots, scfg.max_seq)
        self.slot_req: list[Optional[Request]] = [None] * scfg.slots
        self.slot_pos = np.zeros(scfg.slots, np.int32)   # per-slot progress
        self.queue: list[Request] = []
        self.finished: dict[int, Request] = {}
        self.batches_run = 0
        self.weights_step = -1
        self._step = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))

    # -- admission ---------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.scfg.slots):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                self.slot_pos[i] = 0

    # -- decode loop ------------------------------------------------------------
    def _gather_tokens(self) -> jnp.ndarray:
        """Next input token per slot: prompt token (prefill phase) or the
        last generated token (decode phase); idle slots feed EOS."""
        toks = np.full((self.scfg.slots, 1), self.scfg.eos_id, np.int32)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            p = int(self.slot_pos[i])
            if p < len(req.prompt):
                toks[i, 0] = req.prompt[p]
            elif req.output:
                toks[i, 0] = req.output[-1]
        return jnp.asarray(toks)

    def step_batch(self) -> int:
        """One lockstep decode step across all slots.  Returns #active."""
        self._admit()
        active = sum(r is not None for r in self.slot_req)
        if active == 0:
            return 0
        logits, self.cache = self._step(self.params, self.cache,
                                        self._gather_tokens())
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.slot_pos[i] += 1
            p = int(self.slot_pos[i])
            if p < len(req.prompt):
                continue                      # still prefilling
            tok = int(nxt[i])
            req.output.append(tok)
            if (tok == self.scfg.eos_id
                    or len(req.output) >= req.max_new_tokens
                    or p + 1 >= self.scfg.max_seq):
                req.done = True
                self.finished[req.rid] = req
                self.slot_req[i] = None
        self.batches_run += 1
        if (self.scfg.refresh_every_batches
                and self.batches_run % self.scfg.refresh_every_batches == 0):
            self.maybe_refresh_weights()
        return active

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.slot_req):
                return
            self.step_batch()
        raise RuntimeError("serving did not drain")

    # -- timeline weight refresh (§5's consistency menu, applied) -----------------
    def maybe_refresh_weights(self) -> bool:
        if self.store is None:
            return False
        from ..checkpoint.store import CheckpointError
        try:
            step = self.store.latest_step(self.run_id, consistent=False)
            if step is None or step <= self.weights_step:
                return False
            # timeline reads may race a checkpoint mid-commit or hit a
            # stale replica — that is the contract (§5); skip this round
            _, flat = self.store.restore(run_id=self.run_id,
                                         consistent=False)
        except CheckpointError:
            return False
        self.params = _unflatten_like(self.params, flat)
        self.weights_step = step
        return True


def _unflatten_like(tree, flat: dict):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = flat.get(name)
        out.append(jnp.asarray(arr, leaf.dtype) if arr is not None else leaf)
    return jax.tree_util.tree_unflatten(treedef, [l for _, l in leaves]) \
        if not flat else jax.tree_util.tree_unflatten(treedef, out)
