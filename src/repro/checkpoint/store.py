"""Spinnaker-backed replicated checkpoint & metadata store.

This is the paper's technique deployed as the framework's fault-tolerance
plane (DESIGN.md §3):

- training state is flattened to (key → bytes) with keys range-partitioned
  across a Spinnaker cluster (3-way cohorts, chained declustering);
- a checkpoint commit = quorum writes of every chunk, then ONE
  `conditionalPut` on the manifest key — the paper's per-row optimistic
  concurrency is the *split-brain fence*: a zombie trainer holding a stale
  manifest version loses the conditional and cannot clobber a newer
  checkpoint;
- a restarting trainer restores with STRONG reads (must see the committed
  manifest); serving replicas poll with TIMELINE reads (staleness bounded
  by the commit period — §5's trade-off, applied verbatim).

The Spinnaker cluster runs on the deterministic simulator; the store
drives the event loop to completion for each synchronous call (in
production these would be real sockets — the protocol logic is
identical).
"""

from __future__ import annotations

import json
import zlib
from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

from ..core import (ClusterConfig, DiskParams, ErrorCode, NodeConfig,
                    ReplicaConfig, Result, Simulator, SpinnakerCluster)


class CheckpointError(Exception):
    pass


class StaleTrainerError(CheckpointError):
    """Raised when the manifest conditionalPut loses: another trainer
    committed a newer checkpoint (we are a zombie — stop)."""


@dataclass
class StoreConfig:
    n_nodes: int = 5
    chunk_bytes: int = 1 << 20
    commit_period: float = 1.0
    disk: str = "ssd"            # checkpoints want SSD logs (App. D.4)
    seed: int = 0


class SpinnakerCheckpointStore:
    """Synchronous facade over a simulated Spinnaker cluster."""

    def __init__(self, cfg: StoreConfig | None = None):
        self.cfg = cfg or StoreConfig()
        self.sim = Simulator(seed=self.cfg.seed)
        disk = DiskParams.ssd() if self.cfg.disk == "ssd" else \
            (DiskParams.memory() if self.cfg.disk == "memory"
             else DiskParams.hdd())
        ccfg = ClusterConfig(
            n_nodes=self.cfg.n_nodes,
            node=NodeConfig(
                replica=ReplicaConfig(commit_period=self.cfg.commit_period,
                                      flush_threshold=64 << 20),
                disk=disk))
        self.cluster = SpinnakerCluster(self.sim, ccfg)
        self.cluster.start()
        self.cluster.settle()
        self.client = self.cluster.make_client("ckpt-writer")
        self.reader = self.cluster.make_client("ckpt-reader")
        self._manifest_version: Optional[int] = None

    # -- low-level sync ops --------------------------------------------------
    def _put(self, key: str, value: Any) -> Result:
        res = self.client.sync_put(key, "d", value)
        if not res.ok:
            raise CheckpointError(f"put {key}: {res.code}")
        return res

    def _get(self, key: str, consistent: bool = True) -> Result:
        c = self.client if consistent else self.reader
        return c.sync(c.get, key, "d", consistent)

    # -- pytree <-> chunks -------------------------------------------------------
    @staticmethod
    def _flatten(tree) -> list[tuple[str, np.ndarray]]:
        import jax
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        out = []
        for path, leaf in leaves:
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            out.append((name, np.asarray(leaf)))
        return out

    def save(self, step: int, tree, run_id: str = "run0") -> dict:
        """Commit a checkpoint; fences against concurrent trainers."""
        leaves = self._flatten(tree)
        index = []
        for name, arr in leaves:
            data = arr.tobytes()
            crc = zlib.crc32(data)
            nchunks = max(1, (len(data) + self.cfg.chunk_bytes - 1)
                          // self.cfg.chunk_bytes)
            for i in range(nchunks):
                chunk = data[i * self.cfg.chunk_bytes:
                             (i + 1) * self.cfg.chunk_bytes]
                self._put(self._chunk_key(run_id, step, name, i), chunk)
            index.append({"name": name, "dtype": str(arr.dtype),
                          "shape": list(arr.shape), "nchunks": nchunks,
                          "crc": crc})
        manifest = {"step": step, "index": index}
        self._commit_manifest(run_id, manifest)
        return manifest

    def _chunk_key(self, run_id: str, step: int, name: str, i: int) -> str:
        # hash-prefix spreads chunks across range partitions
        h = zlib.crc32(f"{run_id}/{step}/{name}/{i}".encode()) % 100_000
        return f"k{h:012d}/{run_id}/{step}/{name}/{i}"

    def _commit_manifest(self, run_id: str, manifest: dict) -> None:
        """conditionalPut fence (§3 of the paper → §3 of DESIGN.md)."""
        key = f"k{0:012d}/manifest/{run_id}"
        blob = json.dumps(manifest)
        if self._manifest_version is None:
            cur = self._get(key, consistent=True)
            if cur.code == ErrorCode.NOT_FOUND:
                res = self.client.sync_put(key, "d", blob)
                if not res.ok:
                    raise CheckpointError(f"manifest put: {res.code}")
                self._manifest_version = res.version
                return
            self._manifest_version = cur.version
        res = self.client.sync_cond_put(key, "d", blob,
                                        self._manifest_version)
        if res.code == ErrorCode.VERSION_MISMATCH:
            raise StaleTrainerError(
                f"manifest advanced to v{res.version}; this trainer is "
                f"fenced out")
        if not res.ok:
            raise CheckpointError(f"manifest cond_put: {res.code}")
        self._manifest_version = res.version

    # -- restore -------------------------------------------------------------------
    def latest_step(self, run_id: str = "run0",
                    consistent: bool = True) -> Optional[int]:
        res = self._get(f"k{0:012d}/manifest/{run_id}", consistent)
        if not res.ok:
            return None
        return json.loads(res.value)["step"]

    def restore(self, step: Optional[int] = None, run_id: str = "run0",
                consistent: bool = True) -> tuple[int, dict[str, np.ndarray]]:
        """Strong read for trainer restart; timeline for serving refresh."""
        res = self._get(f"k{0:012d}/manifest/{run_id}", consistent)
        if not res.ok:
            raise CheckpointError(f"no manifest: {res.code}")
        if consistent:
            # adopt the committed version so our next save fences correctly
            self._manifest_version = res.version
        manifest = json.loads(res.value)
        if step is not None and manifest["step"] != step:
            raise CheckpointError(
                f"manifest has step {manifest['step']}, wanted {step}")
        step = manifest["step"]
        out: dict[str, np.ndarray] = {}
        for ent in manifest["index"]:
            parts = []
            for i in range(ent["nchunks"]):
                r = self._get(self._chunk_key(run_id, step, ent["name"], i),
                              consistent)
                if not r.ok:
                    raise CheckpointError(
                        f"chunk {ent['name']}/{i}: {r.code}")
                parts.append(r.value)
            data = b"".join(parts)
            if zlib.crc32(data) != ent["crc"]:
                raise CheckpointError(f"crc mismatch on {ent['name']}")
            out[ent["name"]] = np.frombuffer(
                data, dtype=np.dtype(ent["dtype"])).reshape(ent["shape"])
        return step, out

    def restore_tree(self, like_tree, step: Optional[int] = None,
                     run_id: str = "run0"):
        """Restore into the structure of `like_tree` (resharding-safe:
        lookup is by logical key, not device layout)."""
        import jax
        step, flat = self.restore(step, run_id)
        leaves = jax.tree_util.tree_flatten_with_path(like_tree)
        out = []
        for path, leaf in leaves[0]:
            name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            if name not in flat:
                raise CheckpointError(f"missing leaf {name}")
            arr = flat[name]
            out.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                       else arr)
        return step, jax.tree_util.tree_unflatten(leaves[1], out)

    # -- failure injection passthrough (tests/examples) ----------------------------
    def crash_storage_node(self, nid: int, lose_disk: bool = False) -> None:
        self.cluster.crash_node(nid, lose_disk=lose_disk)

    def restart_storage_node(self, nid: int) -> None:
        self.cluster.restart_node(nid)
        self.sim.run_for(5.0)
