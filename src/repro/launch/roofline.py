"""Roofline term derivation from dry-run artifacts (TPU v5e targets).

Terms (per chip — cost_analysis of the post-SPMD module is per-device):
    compute    = HLO_flops / peak_flops
    memory     = HLO_bytes / hbm_bw
    collective = link_bytes_per_chip / link_bw

plus MODEL_FLOPS (6·N·D train / 2·N·D forward, N_active for MoE) and the
useful-compute ratio MODEL_FLOPS / (HLO_flops × chips), which exposes
remat recompute and dispatch waste.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass

from ..models.config import ModelConfig
from .shapes import SHAPES, ShapeSpec

# TPU v5e hardware constants (per brief)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    link_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    step_time_s: float          # max of the three terms (overlap-ideal)
    mfu: float                  # model_flops / (chips·peak·step_time)
    args_bytes_per_chip: float = 0.0
    temp_bytes_per_chip: float = 0.0

    def to_dict(self):
        return asdict(self)


def model_flops(cfg: ModelConfig, spec: ShapeSpec) -> float:
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if spec.kind == "train":
        tokens = spec.seq_len * spec.global_batch
        return 6.0 * n * tokens
    if spec.kind == "prefill":
        tokens = spec.seq_len * spec.global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * spec.global_batch


def derive(arch: str, shape: str, mesh_name: str, chips: int,
           cost: dict, mem: object, link_bytes_per_chip: float,
           cfg: ModelConfig) -> Roofline:
    spec = SHAPES[shape]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    coll_s = link_bytes_per_chip / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, spec)
    useful = mf / max(1.0, flops * chips)
    step = max(compute_s, memory_s, coll_s)
    mfu = mf / max(1e-12, chips * PEAK_FLOPS * step)
    args_b = getattr(mem, "argument_size_in_bytes", 0) if mem else 0
    temp_b = getattr(mem, "temp_size_in_bytes", 0) if mem else 0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=byts,
        link_bytes_per_chip=link_bytes_per_chip,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        dominant=dominant, model_flops=mf, useful_ratio=useful,
        step_time_s=step, mfu=mfu,
        args_bytes_per_chip=float(args_b), temp_bytes_per_chip=float(temp_b))
