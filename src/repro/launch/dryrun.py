import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × shape × mesh) cell:
  1. lower + compile the FULL config under GSPMD on the production mesh —
     this is the runnability proof, and memory_analysis() is exact
     (buffer assignment accounts for loop reuse);
  2. lower + compile two reduced-DEPTH configs (L1 = one layer period,
     L2 = two periods) with layers UNROLLED, because XLA's cost analysis
     counts a while-loop body exactly once — per-layer flops / bytes /
     collective traffic are the (L2 − L1) delta, extrapolated to L exactly
     (scanned layers are identical by construction);
  3. derive the three roofline terms and write one JSON per cell
     (resumable).

    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

The XLA_FLAGS line above MUST stay before any jax import: jax locks the
device count at first backend init.  Only this entry point forces 512
host devices; tests and benches see the real device.
"""

import argparse
import json
import time
import traceback
from pathlib import Path


def _analysis_depths(cfg) -> tuple[int, int, int]:
    """(L1, L2, period): delta of one full period captures the repeating
    unit (hybrid: attn_every mamba blocks + one shared-attention slot)."""
    period = cfg.attn_every if cfg.family == "hybrid" and cfg.attn_every \
        else 1
    return period, 2 * period, period


def _lower(cfg, shape: str, mesh, pol, weight_quant: bool = False):
    """Lower + compile one step for `cfg`; returns (compiled, lower_s,
    compile_s)."""
    import jax

    from ..dist.sharding import MeshContext
    from ..models import init_params
    from ..train.optim import choose_optimizer
    from ..train.step import (TrainConfig, init_train_state,
                              make_prefill_step, make_serve_step,
                              make_train_step)
    from .shapes import SHAPES, input_specs

    spec = SHAPES[shape]
    t0 = time.time()
    with MeshContext(mesh, cfg, pol) as ctx:
        if spec.kind == "train":
            tcfg = TrainConfig(optimizer=choose_optimizer(cfg.param_count()))
            step = make_train_step(cfg, tcfg)
            state_shape = jax.eval_shape(
                lambda: init_train_state(jax.random.PRNGKey(0), cfg, tcfg))
            state_shard = {
                "params": ctx.param_shardings(state_shape["params"]),
                "opt": _opt_shardings(ctx, state_shape["opt"]),
                "step": ctx.replicated(),
            }
            batch = input_specs(cfg, shape)
            jitted = jax.jit(step,
                             in_shardings=(state_shard,
                                           ctx.batch_sharding(batch)),
                             out_shardings=(state_shard, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shape, batch)
        elif spec.kind == "prefill":
            step = make_prefill_step(cfg)
            params_shape = jax.eval_shape(
                lambda: init_params(jax.random.PRNGKey(0), cfg))
            batch = input_specs(cfg, shape)
            jitted = jax.jit(step,
                             in_shardings=(ctx.param_shardings(params_shape),
                                           ctx.batch_sharding(batch)))
            lowered = jitted.lower(params_shape, batch)
        else:  # decode
            step = make_serve_step(cfg)
            if weight_quant:
                from ..models.quant import quantize_tree
                params_shape = jax.eval_shape(
                    lambda: quantize_tree(
                        init_params(jax.random.PRNGKey(0), cfg)))
            else:
                params_shape = jax.eval_shape(
                    lambda: init_params(jax.random.PRNGKey(0), cfg))
            specs = input_specs(cfg, shape)
            cache_shape, tok = specs["cache"], specs["tokens"]
            cache_shard = ctx.cache_sharding(cache_shape)
            jitted = jax.jit(step,
                             in_shardings=(ctx.param_shardings(params_shape),
                                           cache_shard,
                                           ctx.batch_sharding(tok)),
                             out_shardings=(None, cache_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shape, cache_shape, tok)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: Path,
             seq_parallel: bool = False, shard_params_on_pod=None,
             overwrite: bool = False, tag: str = "",
             attn_impl: str = None, moe_impl: str = None,
             weight_quant: bool = False, serve_stationary: bool = False,
             remat_off: bool = False, remat_policy: str = None,
             decode_attn_impl: str = None, skip_full: bool = False) -> dict:
    import jax

    from ..configs import get_config
    from ..dist.sharding import ShardingPolicy
    from . import hlo as hlo_mod
    from . import roofline as roof_mod
    from .mesh import make_production_mesh
    from .shapes import SHAPES, applicable

    cfg = get_config(arch)
    if attn_impl:
        cfg = cfg.scaled(attn_impl=attn_impl)
    if moe_impl:
        cfg = cfg.scaled(moe_impl=moe_impl)
    if remat_off:
        cfg = cfg.scaled(remat=False)
    if decode_attn_impl:
        cfg = cfg.scaled(decode_attn_impl=decode_attn_impl)
    if remat_policy:
        cfg = cfg.scaled(remat_policy=remat_policy)
    spec = SHAPES[shape]
    ok, reason = applicable(cfg, shape)
    cell_id = f"{arch}__{shape}__{mesh_name}" + (f"__{tag}" if tag else "")
    out_path = out_dir / f"{cell_id}.json"
    if out_path.exists() and not overwrite:
        return json.loads(out_path.read_text())
    if not ok:
        rec = {"cell": cell_id, "status": "skipped", "reason": reason}
        out_dir.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=2))
        return rec

    multi_pod = mesh_name == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    if shard_params_on_pod is None:
        shard_params_on_pod = multi_pod and cfg.param_count() > 4e11
    pol = ShardingPolicy.for_mesh(mesh, seq_parallel=seq_parallel,
                                  shard_params_on_pod=shard_params_on_pod)
    if serve_stationary:
        # weight-stationary serving: params replicated over the data axes
        # (TP-only sharding); decode loses its per-step FSDP all-gathers
        pol.fsdp_axes = ()

    # --- 1. full-config compile: runnability proof + memory analysis -------
    mem = None
    full_collectives = None
    t_lower = t_compile = 0.0
    if not skip_full:
        compiled_full, t_lower, t_compile = _lower(cfg, shape, mesh, pol,
                                                   weight_quant)
        try:
            mem = compiled_full.memory_analysis()
        except Exception:
            mem = None
        full_collectives = hlo_mod.parse_collectives(
            compiled_full.as_text(), chips)
        del compiled_full

    # --- 2. depth-extrapolated cost analysis --------------------------------
    L1, L2, period = _analysis_depths(cfg)
    L = cfg.num_layers
    costs = []
    colls = []
    for depth in (L1, L2):
        cfg_a = cfg.scaled(num_layers=depth, scan_layers=False)
        compiled_a, _, _ = _lower(cfg_a, shape, mesh, pol, weight_quant)
        ca = compiled_a.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # older jax: one dict per program
            ca = ca[0] if ca else {}
        costs.append(ca)
        colls.append(hlo_mod.parse_collectives(compiled_a.as_text(), chips))
        del compiled_a

    def extrap(v1: float, v2: float) -> float:
        return v1 + (v2 - v1) * (L - L1) / float(L2 - L1)

    flops = extrap(float(costs[0].get("flops", 0)),
                   float(costs[1].get("flops", 0)))
    byts = extrap(float(costs[0].get("bytes accessed", 0)),
                  float(costs[1].get("bytes accessed", 0)))
    link_bytes = extrap(colls[0].total_link_bytes, colls[1].total_link_bytes)

    roof = roof_mod.derive(arch, shape, mesh_name, chips,
                           {"flops": flops, "bytes accessed": byts}, mem,
                           link_bytes, cfg)

    per_layer_coll = {}
    for op in set(list(colls[0].counts) + list(colls[1].counts)):
        per_layer_coll[op] = {
            "count_per_period": colls[1].counts.get(op, 0)
            - colls[0].counts.get(op, 0),
            "link_bytes_per_period": colls[1].link_bytes.get(op, 0.0)
            - colls[0].link_bytes.get(op, 0.0),
        }

    rec = {
        "cell": cell_id,
        "status": "ok",
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "seq_parallel": seq_parallel,
        "shard_params_on_pod": shard_params_on_pod,
        "attn_impl": attn_impl or cfg.attn_impl,
        "moe_impl": moe_impl or cfg.moe_impl,
        "weight_quant": weight_quant,
        "serve_stationary": serve_stationary,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "analysis_depths": [L1, L2],
        "cost_extrapolated": {"flops": flops, "bytes_accessed": byts,
                              "link_bytes": link_bytes},
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        } if mem else None,
        "collectives_per_period": per_layer_coll,
        "collectives_full_hlo_bodyonce": full_collectives.table()
        if full_collectives else None,
        "roofline": roof.to_dict(),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def _opt_shardings(ctx, opt_shape):
    """Optimizer state follows its parameter's sharding; scalars replicate.

    AdamW m/v mirror the param tree exactly; Adafactor factored stats drop
    the last (vr) or second-to-last (vc) axis."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..dist.sharding import _drop_indivisible, param_spec, path_str

    def one(path, leaf):
        ps = path_str(path)
        if leaf.ndim == 0 or ps.endswith("count"):
            return ctx.replicated()
        parts = [p for p in ps.split("/")
                 if p not in ("m", "v", "stats", "vr", "vc")]

        class _K:
            def __init__(self, k):
                self.key = k

        pseudo = tuple(_K(p) for p in parts)
        spec = param_spec(pseudo, leaf, ctx.pol, ctx.cfg)
        tail = ps.rsplit("/", 1)[-1]
        if tail == "vr":
            spec = P(*(list(spec)[:-1]))
        elif tail == "vc":
            s = list(spec)
            if len(s) >= 2:
                spec = P(*(s[:-2] + s[-1:]))
        spec = _drop_indivisible(spec, leaf, ctx.mesh)
        return NamedSharding(ctx.mesh, spec)

    return jax.tree_util.tree_map_with_path(one, opt_shape)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="architecture id (see repro.configs)")
    ap.add_argument("--shape",
                    help="train_4k|prefill_32k|decode_32k|long_500k")
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--overwrite", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--attn-impl", default=None,
                    choices=[None, "xla", "xla_chunked", "xla_bhsd"])
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, "gspmd", "shard_map"])
    ap.add_argument("--weight-quant", action="store_true",
                    help="int8 weight-only serving quantization")
    ap.add_argument("--remat-off", action="store_true",
                    help="disable activation checkpointing")
    ap.add_argument("--remat-policy", default=None,
                    choices=[None, "full", "dots"])
    ap.add_argument("--decode-attn-impl", default=None,
                    choices=[None, "xla", "shard_map"])
    ap.add_argument("--serve-stationary", action="store_true",
                    help="replicate weights over data axes for decode")
    ap.add_argument("--skip-full", action="store_true",
                    help="skip the full-depth compile (analysis only)")
    args = ap.parse_args()

    from ..configs import list_archs
    from .shapes import SHAPES

    out_dir = Path(args.out)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                for mesh in meshes:
                    cells.append((arch, shape, mesh))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        for mesh in meshes:
            cells.append((args.arch, args.shape, mesh))

    failures = 0
    for arch, shape, mesh in cells:
        cid = f"{arch}__{shape}__{mesh}"
        try:
            t0 = time.time()
            rec = run_cell(arch, shape, mesh, out_dir,
                           seq_parallel=args.seq_parallel,
                           overwrite=args.overwrite, tag=args.tag,
                           attn_impl=args.attn_impl,
                           moe_impl=args.moe_impl,
                           weight_quant=args.weight_quant,
                           serve_stationary=args.serve_stationary,
                           remat_off=args.remat_off,
                           remat_policy=args.remat_policy,
                           decode_attn_impl=args.decode_attn_impl,
                           skip_full=args.skip_full)
            status = rec.get("status")
            if status == "ok":
                r = rec["roofline"]
                msg = (f"[OK ] {cid}: dominant={r['dominant']} "
                       f"mfu={r['mfu']:.3f} compile={rec['compile_s']}s "
                       f"({time.time()-t0:.0f}s)")
                if rec.get("memory") and rec["memory"]["argument_bytes"]:
                    per_dev = (rec["memory"]["argument_bytes"]
                               + (rec["memory"]["temp_bytes"] or 0))
                    msg += f" mem/dev={per_dev/1e9:.1f}GB"
                    if per_dev > 16e9:
                        msg += " (>16GB HBM!)"
                print(msg, flush=True)
            else:
                print(f"[SKIP] {cid}: {rec.get('reason')}", flush=True)
        except Exception as e:
            failures += 1
            print(f"[FAIL] {cid}: {e}", flush=True)
            (out_dir / f"{cid}.error.txt").parent.mkdir(parents=True,
                                                        exist_ok=True)
            (out_dir / f"{cid}.error.txt").write_text(traceback.format_exc())
    print(f"done: {len(cells) - failures}/{len(cells)} cells ok", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
