"""Post-SPMD HLO analysis: collective inventory and per-chip link bytes.

cost_analysis() has no collective traffic, so we parse the compiled
(per-device) HLO text.  For each collective we derive the bytes a single
chip moves over ICI links under ring algorithms:

    all-gather      : (N-1)/N × result_bytes
    reduce-scatter  : (N-1)   × result_bytes          (input = N × result)
    all-reduce      : 2(N-1)/N × result_bytes
    all-to-all      : (N-1)/N × result_bytes
    collective-permute : result_bytes

N = participating group size parsed from replica_groups.  Async
`-start`/`-done` pairs are counted once (on the start op).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=lambda: defaultdict(int))
    result_bytes: dict = field(default_factory=lambda: defaultdict(int))
    link_bytes: dict = field(default_factory=lambda: defaultdict(float))

    @property
    def total_link_bytes(self) -> float:
        return sum(self.link_bytes.values())

    def table(self) -> list[dict]:
        return [{"op": op, "count": self.counts[op],
                 "result_bytes": self.result_bytes[op],
                 "link_bytes_per_chip": self.link_bytes[op]}
                for op in sorted(self.counts)]


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if m is None:
            continue
        type_str, op = m.group(1), m.group(2)
        base = op.replace("-start", "")
        rb = shape_bytes(type_str)
        n = max(2, _group_size(line, n_devices))
        if base == "all-gather":
            link = (n - 1) / n * rb
        elif base == "reduce-scatter":
            link = (n - 1) * rb
        elif base == "all-reduce":
            link = 2 * (n - 1) / n * rb
        elif base == "all-to-all":
            link = (n - 1) / n * rb
        else:  # collective-permute
            link = rb
        stats.counts[base] += 1
        stats.result_bytes[base] += rb
        stats.link_bytes[base] += link
    return stats


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
