"""Assigned input shapes and ShapeDtypeStruct stand-ins.

Four shapes per architecture (train_4k / prefill_32k / decode_32k /
long_500k); `input_specs` returns allocation-free ShapeDtypeStructs for
dry-run lowering, `make_batch` returns real (small) arrays for smoke tests
and examples.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..models import init_cache
from ..models.config import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason).  long_500k needs sub-quadratic context handling:
    only SSM/hybrid archs run it (DESIGN.md §Arch-applicability)."""
    if shape_name == "long_500k" and not cfg.has_ssm:
        return False, ("pure full-attention arch: a 524k dense KV cache is "
                       "the quadratic blowup long_500k excludes; skipped "
                       "per brief")
    return True, ""


# ---------------------------------------------------------------------------
# spec builders (ShapeDtypeStruct — no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    B, S = spec.global_batch, spec.seq_len
    dt = jnp.dtype(cfg.dtype)
    if cfg.modality == "vlm":
        P = cfg.num_patches
        return {"tokens": _sds((B, S - P), jnp.int32),
                "patches": _sds((B, P, cfg.d_model), dt),
                "labels": _sds((B, S), jnp.int32)}
    if cfg.modality == "audio" and cfg.frame_embed:
        return {"frames": _sds((B, S, cfg.d_model), dt),
                "labels": _sds((B, S), jnp.int32)}
    return {"tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, spec: ShapeSpec) -> dict:
    B = spec.global_batch
    dt = jnp.dtype(cfg.dtype)
    if cfg.modality == "audio" and cfg.frame_embed:
        tok = _sds((B, 1, cfg.d_model), dt)
    else:
        tok = _sds((B, 1), jnp.int32)
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, spec.seq_len))
    return {"tokens": tok, "cache": cache}


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    spec = SHAPES[shape_name]
    if spec.kind in ("train", "prefill"):
        return train_input_specs(cfg, spec)
    return decode_input_specs(cfg, spec)


# ---------------------------------------------------------------------------
# real batches (smoke tests, examples)
# ---------------------------------------------------------------------------


def make_batch(cfg: ModelConfig, rng: np.random.Generator, batch: int,
               seq: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    if cfg.modality == "vlm":
        P = cfg.num_patches
        return {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq - P)), jnp.int32),
            "patches": jnp.asarray(
                rng.standard_normal((batch, P, cfg.d_model)), dt),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        }
    if cfg.modality == "audio" and cfg.frame_embed:
        return {
            "frames": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)) * 0.02, dt),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
    }


def make_decode_tokens(cfg: ModelConfig, rng: np.random.Generator,
                       batch: int):
    if cfg.modality == "audio" and cfg.frame_embed:
        return jnp.asarray(rng.standard_normal((batch, 1, cfg.d_model)) * 0.02,
                           jnp.dtype(cfg.dtype))
    return jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, 1)), jnp.int32)
