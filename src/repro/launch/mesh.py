"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init,
while smoke tests and benches see the real single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one v5e pod's worth of chips) or 2×16×16 (two pods).

    Axes: 'pod' is the DCN-connected outer data axis; 'data' hosts
    FSDP/EP/DP; 'model' hosts tensor parallelism over ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for_devices(n_devices: int, model_parallel: int = 1,
                          axes: tuple[str, str] = ("data", "model")):
    """Largest (data, model) grid for an elastic restart (repro.ft)."""
    model = min(model_parallel, n_devices)
    while n_devices % model:
        model -= 1
    return jax.make_mesh((n_devices // model, model), axes)
