# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.


def tpu_compiler_params(dimension_semantics: tuple):
    """Pallas-TPU CompilerParams across jax renames (TPUCompilerParams
    pre-0.6, CompilerParams after).  Raises if pallas.tpu is unavailable;
    callers that must run on CPU wrap this in try/except."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(dimension_semantics=dimension_semantics)
