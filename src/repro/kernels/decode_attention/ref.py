"""Pure-jnp oracle for single-query decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, length, *,
                         window: int = 0,
                         scale: float | None = None) -> jax.Array:
    """q: (B, H, hd); caches: (B, Hkv, T, hd); length: int — number of
    valid cache positions.  Returns (B, H, hd)."""
    B, H, hd = q.shape
    _, Hkv, T, _ = k_cache.shape
    group = H // Hkv
    if scale is None:
        scale = hd ** -0.5
    kk = jnp.repeat(k_cache, group, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v_cache, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32), kk) * scale
    pos = jnp.arange(T)
    mask = pos < length
    if window:
        mask &= pos >= length - window
    s = jnp.where(mask[None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bht,bhtd->bhd", p, vv).astype(q.dtype)
