"""Pallas TPU single-query decode attention.

The decode hot loop is memory-bound: one query row per (batch, head)
streams the KV cache from HBM exactly once.  Grid = (batch, q_heads,
k_blocks) with the k dimension sequential; online-softmax state (m, l,
acc) sits in VMEM scratch.  The `length` operand masks cache positions
beyond the current decode index so one compiled kernel serves every step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, window: int, block_k: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    length = len_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    lo = ik * block_k
    needed = lo < length
    if window:
        needed &= (lo + block_k) > length - window

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (1, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = lo + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
        mask = pos < length
        if window:
            mask &= pos >= length - window
        s = jnp.where(mask, s, NEG_INF)                 # (1, bk)
        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_bhd(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, length: jax.Array, *,
                         window: int = 0, block_k: int = 512,
                         scale: float | None = None,
                         interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); caches: (B, Hkv, T, hd); length: () int32.
    Returns (B, H, hd)."""
    B, H, hd = q.shape
    _, Hkv, T, _ = k_cache.shape
    group = H // Hkv
    if scale is None:
        scale = hd ** -0.5
    block_k = min(block_k, max(8, T))
    pad = (-T) % block_k
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        k_cache = jnp.pad(k_cache, widths)
        v_cache = jnp.pad(v_cache, widths)
    nk = k_cache.shape[2] // block_k
    qr = q[:, :, None, :]                               # (B, H, 1, hd)
    length = jnp.asarray(length, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (0,)),   # length scalar
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, ik, group=group: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, ik, group=group: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), q.dtype),
        scratch_shapes=[_scratch((1, 1)), _scratch((1, 1)), _scratch((1, hd))],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(length, qr, k_cache, v_cache)
    return out[:, :, 0, :]


def _scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


def _compiler_params():
    from .. import tpu_compiler_params
    return tpu_compiler_params(("parallel", "parallel", "arbitrary"))
