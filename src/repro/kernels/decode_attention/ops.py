"""jit'd wrapper for decode attention with XLA fallback."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import decode_attention_bhd
from .ref import decode_attention_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("window", "block_k"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length, *, window: int = 0,
                     block_k: int = 512) -> jax.Array:
    return decode_attention_bhd(q, k_cache, v_cache, length, window=window,
                                block_k=block_k, interpret=_use_interpret())


@partial(jax.jit, static_argnames=("window",))
def decode_attention_xla(q, k_cache, v_cache, length, *, window: int = 0):
    return decode_attention_ref(q, k_cache, v_cache, length, window=window)
