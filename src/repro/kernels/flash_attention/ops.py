"""jit'd public wrapper: (B,S,H,hd) layout, XLA fallback + interpret mode.

On CPU (this container) the kernel executes in interpret mode; on TPU it
compiles via Mosaic.  `flash_attention` is the entry the model layer uses
when cfg.attn_impl == "pallas".
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd
from .ref import attention_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, S, Hkv, hd) -> (B, S, H*hd-compatible)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             interpret=_use_interpret())
    return o.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention_xla(q, k, v, *, causal: bool = True, window: int = 0):
    """XLA fallback with identical semantics (used by dry-run lowering)."""
    o = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=causal, window=window)
    return o.transpose(0, 2, 1, 3)
