"""Pallas TPU flash attention (causal, GQA, optional sliding window).

Design (TPU-native, not a CUDA port):
- grid = (batch, q_heads, q_blocks, k_blocks); the k dimension is the
  innermost, sequential ("arbitrary") axis so the online-softmax state
  lives in VMEM scratch across k steps;
- BlockSpec tiles: q/o (1,1,block_q,hd), k/v (1,1,block_k,hd) — MXU-aligned
  (block_q=block_k=128 default, hd up to 256), working set
  ≈ (2·block_q + 2·block_k)·hd·4B ≪ VMEM;
- GQA is folded into the k/v index_map (q head h reads kv head
  h // (H/Hkv)) — no repeated KV materialisation in HBM;
- causal + window masking via block-level iota compare; fully-masked
  blocks still iterate but skip the FLOPs via pl.when on the block's
  reachability (cheap static bound).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, seq_q: int, seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # static reachability: causal ⇒ k-block start ≤ q-block end
    q_lo = iq * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k
    reachable = jnp.asarray(True)
    if causal:
        reachable &= k_lo <= q_hi
    if window:
        reachable &= (ik + 1) * block_k - 1 > q_lo - window

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
        k_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        mask = (k_pos < seq_k) & (q_pos < seq_q)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)                # (bq, 1)
        p = jnp.exp(s - m_cur)                         # (bq, bk)
        l_cur = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur
        l_scr[...] = l_cur

    @pl.when(ik == nk - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                # fully-masked rows
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         scale: float | None = None,
                         interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, hd); k/v: (B, Hkv, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    assert H % Hkv == 0
    group = H // Hkv
    if scale is None:
        scale = hd ** -0.5
    block_q = min(block_q, max(8, Sq))
    block_k = min(block_k, max(8, Sk))

    def pad_to(x, axis, mult):
        pad = (-x.shape[axis]) % mult
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths)

    qp = pad_to(q, 2, block_q)
    kp = pad_to(k, 2, block_k)
    vp = pad_to(v, 2, block_k)
    nq = qp.shape[2] // block_q
    nk = kp.shape[2] // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_q=Sq, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, group=group: (b, h // group,
                                                            ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, group=group: (b, h // group,
                                                            ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pl_scratch((block_q, 1)),       # running max m
            pl_scratch((block_q, 1)),       # running denom l
            pl_scratch((block_q, hd)),      # accumulator
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq, :]


def pl_scratch(shape):
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # pragma: no cover - CPU-only environments
        return pl.MemorySpace.ANY(shape, jnp.float32)


def _compiler_params():
    try:
        from .. import tpu_compiler_params
        return tpu_compiler_params(("parallel", "parallel", "parallel",
                                    "arbitrary"))
    except Exception:  # pragma: no cover
        return None
