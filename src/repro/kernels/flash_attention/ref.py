"""Pure-jnp oracle for flash attention (GQA, causal, sliding window)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  scale: float | None = None) -> jax.Array:
    """q: (B, H, Sq, hd); k/v: (B, Hkv, Sk, hd) -> (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    group = H // Hkv
    if scale is None:
        scale = hd ** -0.5
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kj <= qi
    if window:
        mask &= kj > qi - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)
