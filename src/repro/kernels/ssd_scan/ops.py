"""jit'd wrapper for the SSD scan with XLA (chunked-jnp) fallback."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssd_scan
from .ref import ssd_chunked_jnp


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("chunk", "head_block"))
def ssd(x, dt, A, B, C, *, chunk: int = 128, head_block: int = 8):
    """x: (b,s,h,p); dt: (b,s,h); A: (h,); B/C: (b,s,g,n) with g==1.
    Returns (y, None) — decode keeps its own state path."""
    assert B.shape[2] == 1, "kernel path assumes single-group SSD"
    y = ssd_scan(x, dt, A, B[:, :, 0, :], C[:, :, 0, :], chunk=chunk,
                 head_block=min(head_block, x.shape[2]),
                 interpret=_use_interpret())
    return y, None


@partial(jax.jit, static_argnames=("chunk",))
def ssd_xla(x, dt, A, B, C, *, chunk: int = 128):
    return ssd_chunked_jnp(x, dt, A, B, C, chunk=chunk)
