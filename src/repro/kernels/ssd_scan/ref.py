"""Oracles for the SSD scan.

`ssd_sequential` is the ground truth (direct recurrence, one step per
token); `ssd_chunked_jnp` re-exports the vectorised chunked formulation
from the model layer.  Tests check kernel == chunked == sequential.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...models.mamba2 import ssd_chunked as ssd_chunked_jnp  # noqa: F401


def ssd_sequential(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                   C: jax.Array):
    """Direct SSD recurrence.  x: (b,s,h,p); dt: (b,s,h); A: (h,);
    B/C: (b,s,g,n).  Returns (y, final_state)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)   # (b,s,h,n)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)

    def step(state, inp):
        xt, dtt, Bt, Ct = inp                              # (b,h,p) (b,h) ...
        decay = jnp.exp(dtt * A[None, :])                  # (b,h)
        state = state * decay[..., None, None] \
            + (dtt[..., None] * xt.astype(jnp.float32))[..., :, None] \
            * Bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2).astype(jnp.float32),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
