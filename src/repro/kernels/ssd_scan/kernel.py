"""Pallas TPU Mamba2 SSD chunked scan.

TPU-native adaptation of the SSD algorithm [arXiv:2405.21060]: the chunk
dimension is the sequential grid axis; the inter-chunk recurrent state
(h, p, n) persists in VMEM scratch across chunk steps, so the HBM traffic
is exactly one read of (x, dt, B, C) and one write of y — the arithmetic
intensity the SSD formulation is designed to expose maps directly onto
MXU matmuls (chunk×chunk intra term, chunk×state outer products).

Grid = (batch, head_blocks, chunks); B/C are shared across heads
(single-group Mamba2, as in both assigned SSM archs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int, nheads_blk: int, headdim: int, dstate: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)        # (q, hb, p)
    dt = dt_ref[0].astype(jnp.float32)      # (q, hb)
    A = a_ref[0].astype(jnp.float32)        # (hb,)
    Bm = b_ref[0].astype(jnp.float32)       # (q, n)
    Cm = c_ref[0].astype(jnp.float32)       # (q, n)

    dA = dt * A[None, :]                    # (q, hb), negative
    cum = jnp.cumsum(dA, axis=0)            # (q, hb)

    # ---- intra-chunk quadratic term --------------------------------------
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (q, q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = ii >= jj
    # decay[h, i, j] = exp(cum_i - cum_j); weight by dt_j
    seg = cum.T[:, :, None] - cum.T[:, None, :]          # (hb, q, q)
    M = cb[None] * jnp.where(causal[None], jnp.exp(seg), 0.0) \
        * dt.T[:, None, :]                                # (hb, q, q)
    xt = x.transpose(1, 0, 2)                             # (hb, q, p)
    y_intra = jax.lax.dot_general(
        M, xt, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)               # (hb, q, p)

    # ---- inter-chunk contribution from carried state ----------------------
    state = state_scr[...]                                # (hb, p, n)
    inter_w = jnp.exp(cum).T                              # (hb, q)
    cs = jax.lax.dot_general(
        jnp.broadcast_to(Cm[None], (nheads_blk, chunk, dstate)), state,
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)               # (hb, q, p)
    y = y_intra + cs * inter_w[:, :, None]
    y_ref[0] = y.transpose(1, 0, 2).astype(y_ref.dtype)   # (q, hb, p)

    # ---- state update -------------------------------------------------------
    last = cum[-1, :]                                     # (hb,)
    w = jnp.exp(last[None, :] - cum) * dt                 # (q, hb)
    xw = xt * w.T[:, :, None]                             # (hb, q, p)
    new_contrib = jax.lax.dot_general(
        xw, jnp.broadcast_to(Bm[None], (nheads_blk, chunk, dstate)),
        (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)               # (hb, p, n)
    state_scr[...] = state * jnp.exp(last)[:, None, None] + new_contrib


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 128, head_block: int = 8,
             interpret: bool = False):
    """x: (b, s, h, p); dt: (b, s, h); A: (h,); B/C: (b, s, n) (group=1).
    Returns y: (b, s, h, p)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, "sequence must be chunk-aligned"
    hb = min(head_block, h)
    assert h % hb == 0
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, nheads_blk=hb,
                               headdim=p, dstate=n)
    y = pl.pallas_call(
        kernel,
        grid=(b, h // hb, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hb, p),
                         lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, hb), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1, hb), lambda ib, ih, ic: (0, ih)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hb, p),
                               lambda ib, ih, ic: (ib, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[_scratch((hb, p, n))],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(x, dt, A[None, :], B, C)
    return y


def _scratch(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)


def _compiler_params():
    from .. import tpu_compiler_params
    return tpu_compiler_params(("parallel", "parallel", "arbitrary"))
