"""Model assembly: embedding → scanned layer stack → norm → logits, for
all four families, with prefill/decode variants.

Layers are scanned (`jax.lax.scan`) over stacked parameters so the HLO is
O(1) in depth — essential for compile-time at 88 layers and for remat
policy control.  Hybrid models scan Mamba2 blocks and apply one *shared*
attention block on a precomputed layer mask (Zamba2-style) via lax.cond.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (attention, attention_decode, embed_init, init_attention,
                     init_mlp, init_rmsnorm, linear, mlp, pshard, rms_norm)
from .mamba2 import (init_mamba2, init_ssm_cache, mamba2_block, mamba2_decode)
from .moe import init_moe, moe_ffn


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(rng: jax.Array, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(rng, 8)
    params: dict[str, Any] = {
        "embed": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dt),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(keys[1],
                                       (cfg.d_model, cfg.vocab_size), dt)

    def stacked(init_fn, rng, n):
        return jax.vmap(init_fn)(jax.random.split(rng, n))

    if cfg.family in ("dense", "moe"):
        def layer_init(k):
            ks = jax.random.split(k, 4)
            p = {
                "attn_norm": init_rmsnorm(cfg.d_model),
                "attn": init_attention(ks[0], cfg, dt),
                "mlp_norm": init_rmsnorm(cfg.d_model),
            }
            if cfg.family == "moe":
                p["moe"] = init_moe(ks[1], cfg, dt)
            else:
                p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt)
            return p
        params["layers"] = stacked(layer_init, keys[2], cfg.num_layers)
    elif cfg.family == "ssm":
        def layer_init(k):
            return {"norm": init_rmsnorm(cfg.d_model),
                    "mamba": init_mamba2(k, cfg, dt)}
        params["layers"] = stacked(layer_init, keys[2], cfg.num_layers)
    elif cfg.family == "hybrid":
        def layer_init(k):
            return {"norm": init_rmsnorm(cfg.d_model),
                    "mamba": init_mamba2(k, cfg, dt)}
        params["layers"] = stacked(layer_init, keys[2], cfg.num_layers)
        # one shared attention + MLP block (weights reused at each slot)
        params["shared_attn"] = {
            "attn_norm": init_rmsnorm(cfg.d_model),
            "attn": init_attention(keys[3], cfg, dt),
            "mlp_norm": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(keys[4], cfg.d_model, cfg.d_ff, dt),
        }
    else:
        raise ValueError(cfg.family)
    return params


def hybrid_attn_mask(cfg: ModelConfig) -> jax.Array:
    """True at layers after which the shared attention block runs."""
    idx = jnp.arange(cfg.num_layers)
    if not cfg.attn_every:
        return jnp.zeros((cfg.num_layers,), bool)
    return (idx % cfg.attn_every) == (cfg.attn_every - 1)


# ---------------------------------------------------------------------------
# forward (train / prefill trunk)
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch: dict, cfg: ModelConfig):
    """Returns (h (B,S,D), positions (B,S), loss_mask (B,S))."""
    dt = _dtype(cfg)
    if cfg.modality == "vlm":
        tokens = batch["tokens"]                      # (B, S - P)
        patches = batch["patches"].astype(dt)         # (B, P, D)
        te = params["embed"][tokens].astype(dt)
        h = jnp.concatenate([patches, te], axis=1)
        B, S, _ = h.shape
        mask = jnp.concatenate(
            [jnp.zeros(patches.shape[:2], bool),
             jnp.ones(tokens.shape, bool)], axis=1)
    elif cfg.modality == "audio" and cfg.frame_embed:
        h = batch["frames"].astype(dt)                # (B, S, D)
        B, S, _ = h.shape
        mask = jnp.ones((B, S), bool)
    else:
        tokens = batch["tokens"]
        h = params["embed"][tokens].astype(dt)
        B, S, _ = h.shape
        mask = jnp.ones((B, S), bool)
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    return h, positions, mask


def _transformer_layer(cfg: ModelConfig, h, lp, positions):
    a = attention(lp["attn"], rms_norm(lp["attn_norm"], h, cfg.norm_eps),
                  cfg, positions)
    h = pshard(h + a, "act_btd")
    hin = rms_norm(lp["mlp_norm"], h, cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = moe_ffn(lp["moe"], hin, cfg)
    else:
        m, aux = mlp(lp["mlp"], hin, cfg.activation), 0.0
    h = pshard(h + m, "act_btd")
    return h, aux


def _shared_attn_block(cfg: ModelConfig, h, sp, positions):
    a = attention(sp["attn"], rms_norm(sp["attn_norm"], h, cfg.norm_eps),
                  cfg, positions, window=cfg.attn_window)
    h = h + a
    m = mlp(sp["mlp"], rms_norm(sp["mlp_norm"], h, cfg.norm_eps),
            cfg.activation)
    return h + m


def _layer_slice(stacked, i: int):
    return jax.tree.map(lambda x: x[i], stacked)


def _remat(cfg: ModelConfig, body):
    if not cfg.remat:
        return body
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def forward(params: dict, batch: dict, cfg: ModelConfig):
    """Full-sequence forward.  Returns (logits (B,S,V), aux_loss, loss_mask)."""
    h, positions, mask = _embed_inputs(params, batch, cfg)

    if cfg.family in ("dense", "moe"):
        def body(carry, lp):
            h = carry
            h, aux = _transformer_layer(cfg, h, lp, positions)
            return h, aux
        body = _remat(cfg, body)
        if cfg.scan_layers:
            h, auxs = jax.lax.scan(body, h, params["layers"])
            aux = jnp.sum(auxs) if cfg.family == "moe" else 0.0
        else:
            aux = 0.0
            for i in range(cfg.num_layers):
                h, a = body(h, _layer_slice(params["layers"], i))
                aux = aux + a if cfg.family == "moe" else 0.0
    elif cfg.family == "ssm":
        def body(h, lp):
            h = h + mamba2_block(lp["mamba"],
                                 rms_norm(lp["norm"], h, cfg.norm_eps), cfg)
            return pshard(h, "act_btd"), 0.0
        body = _remat(cfg, body)
        if cfg.scan_layers:
            h, _ = jax.lax.scan(body, h, params["layers"])
        else:
            for i in range(cfg.num_layers):
                h, _ = body(h, _layer_slice(params["layers"], i))
        aux = 0.0
    elif cfg.family == "hybrid":
        attn_mask = hybrid_attn_mask(cfg)
        sp = params["shared_attn"]

        def body(h, xs):
            lp, use_attn = xs
            h = h + mamba2_block(lp["mamba"],
                                 rms_norm(lp["norm"], h, cfg.norm_eps), cfg)
            h = jax.lax.cond(use_attn,
                             lambda v: _shared_attn_block(cfg, v, sp,
                                                          positions),
                             lambda v: v, h)
            return pshard(h, "act_btd"), 0.0
        body = _remat(cfg, body)
        if cfg.scan_layers:
            h, _ = jax.lax.scan(body, h, (params["layers"], attn_mask))
        else:
            for i in range(cfg.num_layers):
                h, _ = body(h, (_layer_slice(params["layers"], i),
                                attn_mask[i]))
        aux = 0.0
    else:
        raise ValueError(cfg.family)

    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", h,
                        unembed.astype(h.dtype)).astype(cfg.logit_dtype)
    logits = pshard(logits, "act_btv")
    return logits, aux, mask


def loss_fn(params: dict, batch: dict, cfg: ModelConfig):
    """Next-token cross entropy (+ MoE aux).  Returns (loss, metrics)."""
    logits, aux, mask = forward(params, batch, cfg)
    labels = batch["labels"]
    V = logits.shape[-1]
    lw = mask & (labels >= 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(lw), 1)
    ce = -jnp.sum(ll * lw) / denom
    loss = ce + aux
    return loss, {"ce": ce, "aux": jnp.asarray(aux, jnp.float32),
                  "tokens": denom}


# ---------------------------------------------------------------------------
# caches + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> dict:
    dt = dtype or _dtype(cfg)
    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    L = cfg.num_layers
    if cfg.family in ("dense", "moe"):
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cache["k"] = jnp.zeros((L, batch, hkv, max_seq, hd), dt)
        cache["v"] = jnp.zeros((L, batch, hkv, max_seq, hd), dt)
    elif cfg.family == "ssm":
        per = init_ssm_cache(cfg, batch, dt)
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (L,) + x.shape).copy(), per)
    elif cfg.family == "hybrid":
        per = init_ssm_cache(cfg, batch, dt)
        cache["ssm"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (L,) + x.shape).copy(), per)
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        n_attn = int(cfg.num_layers // max(cfg.attn_every, 1))
        w = cfg.attn_window or max_seq
        w = min(w, max_seq)
        cache["k"] = jnp.zeros((n_attn, batch, hkv, w, hd), dt)
        cache["v"] = jnp.zeros((n_attn, batch, hkv, w, hd), dt)
    return cache


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                cfg: ModelConfig):
    """One-token decode.  tokens: (B, 1) int32 (or (B,1,D) frames for
    audio).  Returns (logits (B, V), new_cache)."""
    dt = _dtype(cfg)
    pos = cache["pos"]
    if cfg.modality == "audio" and cfg.frame_embed:
        h = tokens.astype(dt)                         # (B,1,D) frame embed
    else:
        h = params["embed"][tokens].astype(dt)        # (B,1,D)

    if cfg.family in ("dense", "moe"):
        def body(h, xs):
            lp, kc, vc = xs
            x = rms_norm(lp["attn_norm"], h, cfg.norm_eps)
            a, kc, vc = attention_decode(lp["attn"], x, cfg, kc, vc, pos)
            h = h + a
            hin = rms_norm(lp["mlp_norm"], h, cfg.norm_eps)
            if cfg.family == "moe":
                m, _ = moe_ffn(lp["moe"], hin, cfg)
            else:
                m = mlp(lp["mlp"], hin, cfg.activation)
            return h + m, (kc, vc)
        if cfg.scan_layers:
            h, (k, v) = jax.lax.scan(
                body, h, (params["layers"], cache["k"], cache["v"]))
        else:
            ks, vs = [], []
            for i in range(cfg.num_layers):
                h, (kc, vc) = body(h, (_layer_slice(params["layers"], i),
                                       cache["k"][i], cache["v"][i]))
                ks.append(kc)
                vs.append(vc)
            k, v = jnp.stack(ks), jnp.stack(vs)
        new_cache = dict(cache, k=k, v=v, pos=pos + 1)
    elif cfg.family == "ssm":
        def body(h, xs):
            lp, c = xs
            out, c2 = mamba2_decode(lp["mamba"],
                                    rms_norm(lp["norm"], h, cfg.norm_eps),
                                    c, cfg)
            return h + out, c2
        if cfg.scan_layers:
            h, ssm = jax.lax.scan(body, h, (params["layers"], cache["ssm"]))
        else:
            outs = []
            for i in range(cfg.num_layers):
                h, c2 = body(h, (_layer_slice(params["layers"], i),
                                 _layer_slice(cache["ssm"], i)))
                outs.append(c2)
            ssm = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache = dict(cache, ssm=ssm, pos=pos + 1)
    elif cfg.family == "hybrid":
        attn_mask = hybrid_attn_mask(cfg)
        # slot index for each layer's (possible) attention cache
        slot_idx = jnp.cumsum(attn_mask.astype(jnp.int32)) - 1
        sp = params["shared_attn"]
        w = cache["k"].shape[3]
        # windowed position within the rolling attention cache
        wpos = jnp.minimum(pos, w - 1)

        def body(carry, xs):
            h, k_all, v_all = carry
            lp, c, use_attn, slot = xs
            out, c2 = mamba2_decode(lp["mamba"],
                                    rms_norm(lp["norm"], h, cfg.norm_eps),
                                    c, cfg)
            h = h + out

            def with_attn(args):
                h, k_all, v_all = args
                kc = k_all[slot]
                vc = v_all[slot]
                # rolling window: shift left when full
                def shift(c):
                    return jnp.where(pos >= w,
                                     jnp.roll(c, -1, axis=2), c)
                kc, vc = shift(kc), shift(vc)
                x = rms_norm(sp["attn_norm"], h, cfg.norm_eps)
                a, kc, vc = attention_decode(sp["attn"], x, cfg, kc, vc,
                                             wpos)
                h2 = h + a
                m = mlp(sp["mlp"], rms_norm(sp["mlp_norm"], h2,
                                            cfg.norm_eps), cfg.activation)
                return (h2 + m, k_all.at[slot].set(kc),
                        v_all.at[slot].set(vc))

            h, k_all, v_all = jax.lax.cond(
                use_attn, with_attn, lambda args: args, (h, k_all, v_all))
            return (h, k_all, v_all), c2

        if cfg.scan_layers:
            (h, k, v), ssm = jax.lax.scan(
                body, (h, cache["k"], cache["v"]),
                (params["layers"], cache["ssm"], attn_mask, slot_idx))
        else:
            carry = (h, cache["k"], cache["v"])
            outs = []
            for i in range(cfg.num_layers):
                carry, c2 = body(carry, (_layer_slice(params["layers"], i),
                                         _layer_slice(cache["ssm"], i),
                                         attn_mask[i], slot_idx[i]))
                outs.append(c2)
            h, k, v = carry
            ssm = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        new_cache = dict(cache, k=k, v=v, ssm=ssm, pos=pos + 1)
    else:
        raise ValueError(cfg.family)

    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", h, unembed.astype(h.dtype))
    return logits[:, 0].astype(jnp.float32), new_cache


def prefill(params: dict, batch: dict, cfg: ModelConfig, max_seq: int):
    """Process a full prompt, producing last-token logits + a filled cache.

    For the dry-run's `prefill_step` we compute the forward trunk and fill
    the KV cache in one pass (transformers); SSM caches get the final
    recurrent state.
    """
    logits, _aux, _mask = forward(params, batch, cfg)
    # Cache filling for transformers: recompute K/V per layer from the
    # embedding trunk would double compute; in this reference path we return
    # logits only and let the serving engine run decode from a fresh cache
    # warmed by teacher-forcing.  The benchmark path measures the forward
    # trunk, which dominates prefill cost.
    return logits[:, -1]
