"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic
term + inter-chunk state recurrence via lax.scan); decode is the O(1)
recurrent update.  The chunked scan is also provided as a Pallas kernel
(repro.kernels.ssd_scan); this module's jnp implementation is the oracle
and the XLA fallback.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, init_rmsnorm, linear, pshard, rms_norm


def init_mamba2(rng, cfg: ModelConfig, dtype):
    D, Din = cfg.d_model, cfg.d_inner
    N, H, G = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups
    conv_dim = Din + 2 * G * N
    ks = jax.random.split(rng, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                    math.log(1e-3), math.log(1e-1)))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * Din + 2 * G * N + H),
                              dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv_width, conv_dim),
                             dtype=dtype) * 0.5,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)),          # softplus^-1
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D": jnp.ones((H,), jnp.float32),
        "norm": init_rmsnorm(Din),
        "out_proj": dense_init(ks[3], (Din, D), dtype=dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    Din, N, G, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    z, xBC, dt = jnp.split(zxbcdt, [Din, 2 * Din + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d, width K.  xBC: (B,S,C); w: (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b.astype(out.dtype))


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD forward (oracle).  Shapes:
      x: (b, s, h, p)   dt: (b, s, h)   A: (h,) (negative)
      B, C: (b, s, g, n) with heads grouped g | h.
    Returns y: (b, s, h, p) and final state (b, h, p, n).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, "sequence must be chunk-aligned"
    nc = s // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    dA = dtc * A[None, None, None, :]                 # (b,nc,q,h), negative
    cum = jnp.cumsum(dA, axis=2)                      # within-chunk cumsum

    # intra-chunk quadratic term: M[i,j] = (C_i·B_j) exp(cum_i - cum_j) dt_j
    Bh = jnp.repeat(Bc, rep, axis=3)                  # (b,nc,q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)
    cb = jnp.einsum("bcihn,bcjhn->bchij", Ch, Bh)     # (b,nc,h,q,q)
    # seg[b,c,h,i,j] = cum_i - cum_j
    seg = cum.transpose(0, 1, 3, 2)[..., :, None] \
        - cum.transpose(0, 1, 3, 2)[..., None, :]     # (b,nc,h,q,q)
    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]
    decay = jnp.where(causal, jnp.exp(seg), 0.0)
    M = cb * decay * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :]  # dt_j
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M.astype(x.dtype), xc)

    # chunk-level states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j x_j^T
    last = cum[:, :, -1:, :]                          # (b,nc,1,h)
    w = jnp.exp(last - cum) * dtc                     # (b,nc,q,h)
    states = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn",
                        w.astype(x.dtype), Bh.astype(x.dtype), xc)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(last[:, :, 0, :])           # (b,nc,h)

    def step(carry, inp):
        s_prev = carry
        s_c, dec = inp
        s_new = s_prev * dec[..., None, None].astype(s_prev.dtype) + s_c
        return s_new, s_prev

    init = jnp.zeros((b, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)

    # inter-chunk output: y_i += C_i · (exp(cum_i) * S_prev)
    inter_w = jnp.exp(cum)                            # (b,nc,q,h)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", Ch.astype(x.dtype),
                         prev_states) * inter_w[..., None].astype(x.dtype)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final


def mamba2_block(params, x: jax.Array, cfg: ModelConfig):
    """Full Mamba2 block (train/prefill).  x: (B,S,D) -> (B,S,D)."""
    B, S, D = x.shape
    Din, N, G, H, P = (cfg.d_inner, cfg.ssm_state, cfg.ssm_groups,
                       cfg.ssm_heads, cfg.ssm_head_dim)
    zxbcdt = linear(params["in_proj"], x)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC, params["conv_w"].astype(x.dtype),
                       params["conv_b"])
    xs, Bs, Cs = jnp.split(xBC, [Din, Din + G * N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    Bs = Bs.reshape(B, S, G, N)
    Cs = Cs.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])          # (B,S,H)
    A = -jnp.exp(params["A_log"])                      # (H,) negative

    if cfg.attn_impl == "pallas":
        from ..kernels.ssd_scan import ops as ssd_ops
        y, _ = ssd_ops.ssd(xs, dt, A, Bs, Cs, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(xs, dt, A, Bs, Cs, chunk=cfg.ssm_chunk)
    y = y + xs * params["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, Din)
    y = rms_norm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    y = pshard(y, "act_btf")
    return linear(params["out_proj"], y)


# ---------------------------------------------------------------------------
# O(1) recurrent decode
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, H, P, N), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


def mamba2_decode(params, x: jax.Array, cache: dict, cfg: ModelConfig):
    """One-token step.  x: (B,1,D); cache: {'state','conv'}."""
    B = x.shape[0]
    Din, N, G, H, P = (cfg.d_inner, cfg.ssm_state, cfg.ssm_groups,
                       cfg.ssm_heads, cfg.ssm_head_dim)
    zxbcdt = linear(params["in_proj"], x)[:, 0]        # (B, ...)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # rolling conv window
    hist = jnp.concatenate([cache["conv"], xBC[:, None, :].astype(
        cache["conv"].dtype)], axis=1)                 # (B, K, C)
    w = params["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(x.dtype), w)
    xBC = jax.nn.silu(conv_out + params["conv_b"].astype(x.dtype))
    new_conv = hist[:, 1:, :]

    xs, Bs, Cs = jnp.split(xBC, [Din, Din + G * N], axis=-1)
    xs = xs.reshape(B, H, P)
    Bs = jnp.repeat(Bs.reshape(B, G, N), H // G, axis=1)
    Cs = jnp.repeat(Cs.reshape(B, G, N), H // G, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)                            # (B,H)
    state = cache["state"].astype(jnp.float32)
    state = state * decay[..., None, None] \
        + (dt[..., None] * xs.astype(jnp.float32))[..., :, None] \
        * Bs[:, :, None, :].astype(jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", state, Cs.astype(jnp.float32))
    y = y.astype(x.dtype) + xs * params["D"][None, :, None].astype(x.dtype)
    y = y.reshape(B, 1, Din)
    y = rms_norm(params["norm"], y * jax.nn.silu(z)[:, None, :], cfg.norm_eps)
    out = linear(params["out_proj"], y)
    return out, {"state": state.astype(cache["state"].dtype),
                 "conv": new_conv}
