"""Model configuration: one dataclass covers all ten assigned families
(dense / MoE / SSM / hybrid / VLM / audio backbones)."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: Optional[int] = None  # explicit (Gemma: 256); default D/H
    modality: str = "text"          # text | vlm | audio
    activation: str = "swiglu"      # swiglu | geglu
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False

    # -- MoE ------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0               # per-expert hidden size
    shared_expert_d_ff: int = 0     # DeepSeek/Kimi-style always-on expert
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # -- SSM (Mamba2 / SSD) ------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv_width: int = 4
    ssm_groups: int = 1

    # -- hybrid (Zamba2): one shared attention block every k SSM blocks ---------
    attn_every: int = 0
    # hybrid long-context: shared-attention KV is windowed to this many
    # positions (the Mamba2 backbone carries the full context)
    attn_window: int = 0

    # -- modality stubs -----------------------------------------------------------
    num_patches: int = 0            # VLM: prepended patch-embedding positions
    frame_embed: bool = False       # audio: inputs are precomputed frame embeds

    # -- numerics / execution ------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"      # full | dots (save non-batch matmuls)
    attn_impl: str = "xla"          # xla | pallas | xla_chunked
    moe_impl: str = "gspmd"         # gspmd | shard_map (explicit all-to-all)
    decode_attn_impl: str = "xla"   # xla | shard_map (hd-sharded psum)
    logit_dtype: str = "float32"
    scan_layers: bool = True        # False: unrolled (cost-analysis mode)

    # ---------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(1, self.num_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_attention(self) -> bool:
        return self.family in ("dense", "moe", "hybrid")

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced copy for smoke tests."""
        return replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (drives roofline MODEL_FLOPS)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim
        n = V * D                                   # embeddings
        if not self.tie_embeddings:
            n += V * D                               # unembed
        per_layer = 0
        if self.family in ("dense", "moe"):
            qkv = D * (self.num_heads * hd) + 2 * D * (self.num_kv_heads * hd)
            attn = qkv + (self.num_heads * hd) * D
            per_layer += attn + 2 * D               # norms
            if self.is_moe:
                expert = 3 * D * self.moe_d_ff
                per_layer += self.num_experts * expert + D * self.num_experts
                if self.shared_expert_d_ff:
                    per_layer += 3 * D * self.shared_expert_d_ff
            else:
                per_layer += 3 * D * F
        elif self.family == "ssm":
            per_layer += self._ssm_block_params()
        elif self.family == "hybrid":
            per_layer += self._ssm_block_params()
        n += L * per_layer
        if self.family == "hybrid" and self.attn_every:
            # one shared attention+MLP block (weights shared across slots)
            qkv = D * (self.num_heads * hd) + 2 * D * (self.num_kv_heads * hd)
            n += qkv + (self.num_heads * hd) * D + 3 * D * F + 2 * D
        return n

    def _ssm_block_params(self) -> int:
        D, Din = self.d_model, self.d_inner
        N, H = self.ssm_state, self.ssm_heads
        G = self.ssm_groups
        in_proj = D * (2 * Din + 2 * G * N + H)
        conv = (Din + 2 * G * N) * self.ssm_conv_width
        out = Din * D
        return in_proj + conv + out + Din + 2 * H + 2 * D  # norms, A, D, dt_bias

    def active_param_count(self) -> int:
        """MoE: parameters touched per token (for 6·N_active·D flops)."""
        if not self.is_moe:
            return self.param_count()
        D, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        n = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        qkv = D * (self.num_heads * hd) + 2 * D * (self.num_kv_heads * hd)
        per_layer = qkv + (self.num_heads * hd) * D + 2 * D
        per_layer += self.experts_per_token * 3 * D * self.moe_d_ff
        per_layer += D * self.num_experts  # router
        if self.shared_expert_d_ff:
            per_layer += 3 * D * self.shared_expert_d_ff
        return n + L * per_layer
