"""Weight-only int8 quantization (W8A16) for serving.

Decode is launched once per token: FSDP weight all-gathers per step are
the collective bottleneck (dry-run: 2.9 GB/layer/chip/token on
mistral-large).  The production fix is weight-STATIONARY serving — every
chip keeps its full TP shard resident — which only fits HBM with 8-bit
weights.  Per-output-channel absmax scales keep matmul error ~0.4%
relative; embeddings and norms stay in bf16.

A quantized weight is the pytree {"q": int8 (in, out), "s": f32 (out,)};
`wcast` transparently dequantizes at use so every matmul site supports
both representations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_weight(w: jax.Array) -> dict:
    """Per-output-channel absmax int8: the scale reduces only the
    contraction axis (-2), so stacked (L, D, F) / expert (E, D, F)
    weights keep per-layer/per-expert scales — scan-compatible."""
    w32 = w.astype(jnp.float32)
    scale = jnp.max(jnp.abs(w32), axis=-2) / 127.0      # (..., out)
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(w32 / scale[..., None, :]), -127,
                 127).astype(jnp.int8)
    return {"q": q, "s": scale}


def is_quantized(w) -> bool:
    return isinstance(w, dict) and "q" in w and "s" in w


def wcast(w, dtype):
    """Weight fetch: dequantize int8 weights or cast dense ones."""
    if is_quantized(w):
        return w["q"].astype(dtype) * w["s"][..., None, :].astype(dtype)
    return w.astype(dtype)


_QUANT_SUFFIXES = ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down",
                   "in_proj", "out_proj")


def quantize_tree(params: dict) -> dict:
    """Quantize every matmul weight in a model param tree (embeddings,
    norms, SSM scalars, conv stay dense)."""
    def rec(node, name=""):
        if isinstance(node, dict):
            return {k: rec(v, k) for k, v in node.items()}
        if name in _QUANT_SUFFIXES and getattr(node, "ndim", 0) >= 2:
            return quantize_weight(node)
        return node
    return rec(params)


def dequantize_tree(params: dict, dtype=jnp.bfloat16) -> dict:
    def rec(node):
        if is_quantized(node):
            return wcast(node, dtype)
        if isinstance(node, dict):
            return {k: rec(v) for k, v in node.items()}
        return node
    return rec(params)
