"""Building-block layers (pure JAX, no flax).

Parameters are plain dict pytrees.  Every layer is a pair of functions:
`init_*(rng, ...) -> params` and the apply function.  Sharding is applied
from outside via repro.dist; `pshard` is a pluggable activation-sharding
hook that becomes a no-op when no mesh context is installed.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .quant import wcast

# ---------------------------------------------------------------------------
# activation sharding hook (installed by repro.dist.context)
# ---------------------------------------------------------------------------

_SHARD_HOOK = None


def install_shard_hook(fn) -> None:
    global _SHARD_HOOK
    _SHARD_HOOK = fn


def pshard(x: jax.Array, kind: str) -> jax.Array:
    """Constrain activation sharding; `kind` names a logical layout
    ('act_btd', 'act_btf', 'moe_ecd', ...) resolved by the dist context."""
    if _SHARD_HOOK is None:
        return x
    return _SHARD_HOOK(x, kind)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(rng, shape, dtype=jnp.float32, std: float = 0.02):
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms / projections
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dt)


def linear(w, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,df->...f", x, wcast(w, x.dtype))


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, causal, optional sliding window) — XLA reference path
# ---------------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig, dtype):
    D = cfg.d_model
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], (D, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (D, Hkv * hd), dtype=dtype),
        "wv": dense_init(ks[2], (D, Hkv * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, D), dtype=dtype),
    }


def _gqa_scores(q, k, scale):
    """q: (B,S,Hkv,rep,hd) k: (B,T,Hkv,hd) -> (B,Hkv,rep,S,T)"""
    return jnp.einsum("bshrd,bthd->bhrst", q, k) * scale


def attention(params, x: jax.Array, cfg: ModelConfig,
              positions: jax.Array, window: int = 0) -> jax.Array:
    """Causal self-attention over the full sequence (train / prefill)."""
    B, S, D = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    rep = H // Hkv
    q = linear(params["wq"], x).reshape(B, S, Hkv, rep, hd)
    k = linear(params["wk"], x).reshape(B, S, Hkv, hd)
    v = linear(params["wv"], x).reshape(B, S, Hkv, hd)
    q = apply_rope(q.reshape(B, S, Hkv * rep, hd), positions,
                   cfg.rope_theta).reshape(B, S, Hkv, rep, hd)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = pshard(q, "act_bshrd")
    k = pshard(k, "act_bthd")

    if cfg.attn_impl == "pallas":
        from ..kernels.flash_attention import ops as fa_ops
        o = fa_ops.flash_attention(
            q.reshape(B, S, H, hd), k, v, causal=True, window=window)
        o = o.reshape(B, S, H * hd)
    elif cfg.attn_impl == "xla_chunked":
        o = _attention_chunked(q, k, v, positions, window=window,
                               unroll=not cfg.scan_layers)
        o = o.reshape(B, S, H * hd)
    elif cfg.attn_impl == "xla_bhsd":
        # head-major layout: materialise GQA-repeated K/V so every tensor
        # (incl. the quadratic scores) carries a shardable q-head axis —
        # the memory-roofline fix for H % tp == 0 archs
        qh = pshard(q.reshape(B, S, H, hd), "act_q_bshd")
        kr = pshard(jnp.repeat(k, rep, axis=2), "act_q_bshd")
        vr = pshard(jnp.repeat(v, rep, axis=2), "act_q_bshd")
        scale = 1.0 / math.sqrt(hd)
        s = jnp.einsum("bshd,bthd->bhst", qh, kr) * scale
        ii = positions[:, :, None]
        jj = positions[:, None, :]
        mask = jj <= ii
        if window:
            mask &= jj > ii - window
        s = jnp.where(mask[:, None, :, :], s, -jnp.inf)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
        o = jnp.einsum("bhst,bthd->bshd", p, vr).reshape(B, S, H * hd)
    else:
        scale = 1.0 / math.sqrt(hd)
        scores = _gqa_scores(q, k, scale)                  # (B,Hkv,rep,S,T)
        ii = positions[:, :, None]                          # (B,S,1)
        jj = positions[:, None, :]                          # (B,1,T)
        mask = jj <= ii
        if window:
            mask &= jj > ii - window
        scores = jnp.where(mask[:, None, None, :, :], scores, -jnp.inf)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
        probs = probs.astype(x.dtype)
        o = jnp.einsum("bhrst,bthd->bshrd", probs, v).reshape(B, S, H * hd)
    o = pshard(o, "act_bshd_flat")
    return linear(params["wo"], o)


def attention_decode(params, x: jax.Array, cfg: ModelConfig,
                     k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, window: int = 0):
    """One-token decode against a KV cache.

    x: (B,1,D); caches: (B,Hkv,T,hd); pos: () current index (same for all
    batch rows — the serving engine aligns slots).
    Returns (out (B,1,D), k_cache, v_cache).
    """
    B, _, D = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    rep = H // Hkv
    T = k_cache.shape[2]
    q = linear(params["wq"], x).reshape(B, 1, Hkv, rep, hd)
    k = linear(params["wk"], x).reshape(B, 1, Hkv, hd)
    v = linear(params["wv"], x).reshape(B, 1, Hkv, hd)
    posb = jnp.broadcast_to(pos[None], (B, 1))
    q = apply_rope(q.reshape(B, 1, H, hd), posb, cfg.rope_theta
                   ).reshape(B, 1, Hkv, rep, hd)
    k = apply_rope(k, posb, cfg.rope_theta)

    if cfg.decode_attn_impl == "shard_map":
        from ..dist.context import current_ctx
        ctx = current_ctx()
        dp_size = 1
        tp_size = 0
        if ctx is not None:
            tp_size = ctx.mesh.shape[ctx.pol.tp_axis]
            for a in ctx.pol.dp_axes:
                dp_size *= ctx.mesh.shape[a]
        # only when KV heads CANNOT shard the model axis (the GSPMD
        # cache-gather pathology); head-shardable archs already decode
        # collective-free and the hd reshard would regress them ~8×
        # (EXPERIMENTS.md §Perf optimized-decode table)
        if ctx is not None and tp_size and Hkv % tp_size != 0 \
                and hd % tp_size == 0 and B % dp_size == 0:
            o, k_cache, v_cache = _decode_attention_shard_map(
                q, k, v, k_cache, v_cache, pos, ctx, window=window)
            return linear(params["wo"], o), k_cache, v_cache

    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.transpose(0, 2, 1, 3).astype(k_cache.dtype),
        (0, 0, pos, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.transpose(0, 2, 1, 3).astype(v_cache.dtype),
        (0, 0, pos, 0))
    if cfg.attn_impl == "pallas":
        from ..kernels.decode_attention import ops as da_ops
        o = da_ops.decode_attention(
            q.reshape(B, H, hd), k_cache, v_cache, pos + 1, window=window)
        o = o.reshape(B, 1, H * hd)
    else:
        scale = 1.0 / math.sqrt(hd)
        scores = jnp.einsum("bshrd,bhtd->bhrst", q,
                            k_cache.astype(q.dtype)) * scale  # (B,Hkv,rep,1,T)
        jj = jnp.arange(T)
        mask = jj <= pos
        if window:
            mask &= jj > pos - window
        scores = jnp.where(mask[None, None, None, None, :], scores, -jnp.inf)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1
                               ).astype(x.dtype)
        o = jnp.einsum("bhrst,bhtd->bshrd", probs,
                       v_cache.astype(x.dtype)).reshape(B, 1, H * hd)
    return linear(params["wo"], o), k_cache, v_cache


def _decode_attention_shard_map(q, k_new, v_new, k_cache, v_cache, pos, ctx,
                                *, window: int = 0):
    """Decode attention with explicit head_dim-sharded collectives.

    GSPMD all-gathers an hd-sharded KV cache per layer (2.9 GB/layer/token
    on mistral-large — the dominant decode collective).  Written by hand,
    the hd contraction becomes a psum of the (B,Hkv,rep,1,T) partial
    scores (67 MB) while cache stays put:  ~45× fewer link bytes.

    q: (B,1,Hkv,rep,hd); k_new/v_new: (B,1,Hkv,hd);
    caches: (B,Hkv,T,hd).  Returns (o (B,1,H*hd), k_cache, v_cache).
    """
    import math as _math

    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    dp = ctx.pol.dp_axes
    tp = ctx.pol.tp_axis
    B, _, Hkv, rep, hd = q.shape
    T = k_cache.shape[2]
    scale = 1.0 / _math.sqrt(hd)

    qspec = P(dp, None, None, None, tp)
    kvspec = P(dp, None, None, tp)
    cspec = P(dp, None, None, tp)

    def body(ql, knl, vnl, kc, vc, posl):
        kc = jax.lax.dynamic_update_slice(
            kc, knl.transpose(0, 2, 1, 3).astype(kc.dtype), (0, 0, posl, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, vnl.transpose(0, 2, 1, 3).astype(vc.dtype), (0, 0, posl, 0))
        s_part = jnp.einsum("bshrd,bhtd->bhrst", ql,
                            kc.astype(ql.dtype)) * scale
        s = jax.lax.psum(s_part, tp)               # (B_l,Hkv,rep,1,T)
        jj = jnp.arange(T)
        mask = jj <= posl
        if window:
            mask &= jj > posl - window
        s = jnp.where(mask[None, None, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(ql.dtype)
        o = jnp.einsum("bhrst,bhtd->bshrd", p, vc.astype(ql.dtype))
        return o, kc, vc                            # o hd-sharded

    o, k_cache, v_cache = shard_map(
        body, mesh=mesh,
        in_specs=(qspec, kvspec, kvspec, cspec, cspec, P()),
        out_specs=(qspec, cspec, cspec),
        check_vma=False,
    )(q, k_new, v_new, k_cache, v_cache, pos)
    H = Hkv * rep
    return o.reshape(B, 1, H * hd), k_cache, v_cache


def _attention_chunked(q, k, v, positions, *, window: int = 0,
                       chunk: int = 512, unroll: bool = False):
    """Online-softmax attention, blocked over the KV axis — the pure-XLA
    flash formulation.  Bounds the live score buffer to (B,H,S,chunk)
    instead of (B,H,S,T); this is the memory-roofline optimization the
    Pallas kernel implements natively on TPU.

    q: (B,S,Hkv,rep,hd); k/v: (B,T,Hkv,hd) -> (B,S,Hkv,rep,hd)
    """
    B, S, Hkv, rep, hd = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = k.shape[1] // chunk
    kc = k.reshape(B, nc, chunk, Hkv, hd)
    vc = v.reshape(B, nc, chunk, Hkv, hd)
    qpos = positions[:, :, None]                       # (B,S,1)

    m0 = jnp.full((B, Hkv, rep, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, S), jnp.float32)
    a0 = jnp.zeros((B, S, Hkv, rep, hd), jnp.float32)

    def body(carry, ic):
        m, l, acc = carry
        kb = jax.lax.dynamic_index_in_dim(kc, ic, 1, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vc, ic, 1, keepdims=False)
        s = jnp.einsum("bshrd,bthd->bhrst", q, kb) * scale
        kpos = ic * chunk + jnp.arange(chunk)[None, None, :]  # (1,1,chunk)
        mask = kpos <= qpos                                   # (B,S,chunk)
        mask &= kpos < T
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask[:, None, None, :, :], s.astype(jnp.float32),
                      -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (exp(-inf - -inf))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[:, None, None, :, :], p, 0.0)
        alpha = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhrst,bthd->bshrd", p.astype(q.dtype), vb)
        acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] \
            + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    if unroll:
        carry = (m0, l0, a0)
        for ic in range(nc):
            carry, _ = body(carry, ic)
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nc))
    denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return (acc / denom).astype(q.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def init_mlp(rng, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
    }


def mlp(params, x: jax.Array, activation: str) -> jax.Array:
    g = linear(params["w_gate"], x)
    u = linear(params["w_up"], x)
    act = jax.nn.gelu(g) if activation == "geglu" else jax.nn.silu(g)
    h = pshard(act * u, "act_btf")
    return linear(params["w_down"], h)
