"""Token-choice top-k MoE with capacity-bounded, sort-based dispatch.

The dispatch avoids the GShard one-hot einsum (whose dispatch matmul FLOPs
would dwarf expert FLOPs at E=384): tokens are argsorted by expert id,
positioned within their expert's capacity, gathered into an (E, C, D)
buffer (pure data movement, zero matmul FLOPs), run through batched
per-expert GEMMs, and scatter-added back weighted by the router gate.
Overflow tokens are dropped (capacity_factor bounds the buffer), which is
the standard load-shedding behaviour at scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, linear, mlp, init_mlp, pshard
from .quant import is_quantized, wcast


def init_moe(rng, cfg: ModelConfig, dtype):
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(rng, 5)
    params = {
        "router": dense_init(ks[0], (D, E), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, D, F), in_axis=1, dtype=dtype),
        "w_up": dense_init(ks[2], (E, D, F), in_axis=1, dtype=dtype),
        "w_down": dense_init(ks[3], (E, F, D), in_axis=1, dtype=dtype),
    }
    if cfg.shared_expert_d_ff:
        params["shared"] = init_mlp(ks[4], D, cfg.shared_expert_d_ff, dtype)
    return params


def _route(params, xf: jax.Array, cfg: ModelConfig):
    """Router top-k + Switch-style load-balancing aux.  xf: (T, D)."""
    E, K = cfg.num_experts, cfg.experts_per_token
    T = xf.shape[0]
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)          # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (T * K))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)
    return gate_vals, expert_idx, aux


def _dispatch_tables(expert_idx, gate_vals, T: int, E: int, K: int, C: int):
    """Sort-based capacity dispatch: (E, C) token-id + gate buffers."""
    flat_e = expert_idx.reshape(-1)                          # (T*K,)
    order = jnp.argsort(flat_e, stable=True)                 # slots by expert
    sorted_e = flat_e[order]
    sorted_tok = order // K
    sorted_gate = gate_vals.reshape(-1)[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    pos_in_e = jnp.arange(T * K) - group_start[sorted_e]
    keep = pos_in_e < C

    buf = jnp.full((E, C), T, dtype=jnp.int32)               # T = pad id
    buf = buf.at[jnp.where(keep, sorted_e, E - 1),
                 jnp.where(keep, pos_in_e, C - 1)].set(
        jnp.where(keep, sorted_tok, T).astype(jnp.int32), mode="drop")
    gbuf = jnp.zeros((E, C), jnp.float32)
    gbuf = gbuf.at[jnp.where(keep, sorted_e, E - 1),
                   jnp.where(keep, pos_in_e, C - 1)].set(
        jnp.where(keep, sorted_gate, 0.0), mode="drop")
    return buf, gbuf


def moe_ffn(params, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, D) -> (y, aux_loss).  Dispatch impl per cfg.moe_impl."""
    if cfg.moe_impl == "shard_map":
        from ..dist.context import current_ctx
        ctx = current_ctx()
        if ctx is not None:
            return _moe_shard_map(params, x, cfg, ctx)
    return _moe_gspmd(params, x, cfg)


def _moe_gspmd(params, x: jax.Array, cfg: ModelConfig):
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    xf = x.reshape(T, D)
    gate_vals, expert_idx, aux = _route(params, xf, cfg)
    C = max(1, int(cfg.capacity_factor * T * K / E))
    buf, gbuf = _dispatch_tables(expert_idx, gate_vals, T, E, K, C)

    # gather -> (E, C, D); padded row reads zeros
    xpad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    xe = xpad[buf]                                            # (E, C, D)
    xe = pshard(xe, "moe_ecd")

    # --- batched per-expert GEMMs ------------------------------------------------
    wg = wcast(params["w_gate"], xe.dtype)
    wu = wcast(params["w_up"], xe.dtype)
    wd = wcast(params["w_down"], xe.dtype)
    g = jnp.einsum("ecd,edf->ecf", xe, wg)
    u = jnp.einsum("ecd,edf->ecf", xe, wu)
    act = jax.nn.gelu(g) if cfg.activation == "geglu" else jax.nn.silu(g)
    h = pshard(act * u, "moe_ecf")
    ye = jnp.einsum("ecf,efd->ecd", h, wd)  # (E, C, D)
    ye = ye * gbuf[..., None].astype(ye.dtype)

    # --- combine: scatter-add back to tokens ---------------------------------------
    yf = jnp.zeros((T + 1, D), ye.dtype).at[buf.reshape(-1)].add(
        ye.reshape(E * C, D))[:T]
    y = yf.reshape(B, S, D)

    if "shared" in params:
        y = y + mlp(params["shared"], x, cfg.activation)
    return y, aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism: explicit all-to-all dispatch
# ---------------------------------------------------------------------------
#
# The GSPMD path above routes with a token gather, which the partitioner
# lowers to an all-gather of ALL tokens onto every expert shard (the
# "Involuntary full rematerialization" warnings in the dry-run logs).
# Here we write the EP collectives by hand: each data shard routes its
# local tokens, all-to-all exchanges capacity-bounded expert blocks, local
# experts compute, a second all-to-all returns outputs, and the source
# shard combines.  Per-chip link bytes drop from O(T·D) all-gather to
# O(T_local·K·cf·D) all-to-all.


def _moe_shard_map(params, x: jax.Array, cfg: ModelConfig, ctx):
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    mesh = ctx.mesh
    pol = ctx.pol
    ep = pol.ep_axes
    tp = pol.tp_axis
    dp = pol.dp_axes
    E, K, D = cfg.num_experts, cfg.experts_per_token, cfg.d_model
    n_ep = 1
    for a in ep:
        n_ep *= mesh.shape[a]
    if E % n_ep or (mesh.shape[tp] > 1 and cfg.moe_d_ff % mesh.shape[tp]) \
            or is_quantized(params["w_gate"]):
        return _moe_gspmd(params, x, cfg)   # shapes don't tile; fall back

    B, S, _ = x.shape
    ep_name = ep if len(ep) > 1 else ep[0]

    def body(router, wg, wu, wd, xl):
        # xl: (B_local, S, D); experts local: (E_local, D, F_local)
        Bl = xl.shape[0]
        Tl = Bl * S
        xf = xl.reshape(Tl, D)
        gate_vals, expert_idx, aux = _route({"router": router}, xf, cfg)
        C = max(1, int(cfg.capacity_factor * Tl * K / E))
        buf, gbuf = _dispatch_tables(expert_idx, gate_vals, Tl, E, K, C)
        xpad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
        xe = xpad[buf]                                    # (E, C, D)
        # exchange: every shard sends each expert-block home
        xe = jax.lax.all_to_all(xe, ep_name, split_axis=0, concat_axis=1,
                                tiled=True)               # (E_l, C·n_ep, D)
        g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, wu.astype(xe.dtype))
        act = jax.nn.gelu(g) if cfg.activation == "geglu" else jax.nn.silu(g)
        ye = jnp.einsum("ecf,efd->ecd", act * u, wd.astype(xe.dtype))
        # return trip; outputs are partial over the tp axis (F was sharded)
        ye = jax.lax.all_to_all(ye, ep_name, split_axis=1, concat_axis=0,
                                tiled=True)               # (E, C, D) partial
        ye = ye * gbuf[..., None].astype(ye.dtype)
        yf = jnp.zeros((Tl + 1, D), ye.dtype).at[buf.reshape(-1)].add(
            ye.reshape(-1, D))[:Tl]
        if mesh.shape[tp] > 1:
            yf = jax.lax.psum(yf, tp)
        aux = jax.lax.pmean(aux, ep_name)
        return yf.reshape(Bl, S, D), aux

    # batch axes not in ep stay as extra DP; specs mention them so the body
    # sees per-shard blocks
    extra_dp = tuple(a for a in dp if a not in ep)
    xspec = P(tuple(extra_dp) + tuple(ep) if extra_dp else ep, None, None)
    yspec = xspec
    specs = dict(
        in_specs=(P(), P(ep, None, tp), P(ep, None, tp), P(ep, tp, None),
                  xspec),
        out_specs=(yspec, P()))
    try:
        mapped = shard_map(body, mesh=mesh, check_vma=False, **specs)
    except TypeError:  # pre-0.6 jax spells the kwarg check_rep
        mapped = shard_map(body, mesh=mesh, check_rep=False, **specs)
    out = mapped(params["router"], params["w_gate"], params["w_up"],
                 params["w_down"], x)
    y, aux = out
    if "shared" in params:
        y = y + mlp(params["shared"], x, cfg.activation)
    return y, aux
