"""Kimi K2 — trillion-param MoE, 384 experts top-8 + 1 shared expert.
[arXiv:2501.kimi2; unverified, paper-table]

Adaptation note (DESIGN.md SS4): the public table lists GQA kv=8 with 64
heads at d_model=7168; we use an explicit head_dim=128 (MXU-aligned)
rather than 7168/64=112.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    activation="swiglu",
    num_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    shared_expert_d_ff=2048,
    capacity_factor=1.25,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                      head_dim=32, d_ff=64, vocab_size=512, num_experts=8,
                      experts_per_token=2, moe_d_ff=64, shared_expert_d_ff=64)
