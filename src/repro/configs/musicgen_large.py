"""MusicGen-large: decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

The EnCodec frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, S, d_model); the head predicts the next codebook token
(vocab 2048).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="dense",
    modality="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    activation="swiglu",
    frame_embed=True,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
                      head_dim=32, d_ff=256, vocab_size=128)
