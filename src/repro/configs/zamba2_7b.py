"""Zamba2-7B: Mamba2 backbone + shared attention block every 6 layers.
[arXiv:2411.15242; unverified]

Adaptation notes (DESIGN.md SS4/SS6): the real model interleaves two
alternating shared blocks with per-slot LoRA deltas; we implement one
shared attention+MLP block (weights reused at every slot).  For
long_500k the shared-attention KV is windowed to 32768 positions — the
Mamba2 backbone carries the long-range state.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    activation="swiglu",
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    attn_every=6,
    attn_window=32768,
)

SMOKE = CONFIG.scaled(num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
                      head_dim=32, d_ff=256, vocab_size=256, ssm_state=16,
                      ssm_head_dim=32, ssm_chunk=16, attn_every=2,
                      attn_window=64)
