"""Assigned-architecture registry: one module per arch, exact public
configs; `get_config(name)` / `smoke_config(name)` for full/reduced."""

from __future__ import annotations

import importlib

from ..models.config import ModelConfig

ARCHS = [
    "mistral-large-123b",
    "smollm-360m",
    "gemma-7b",
    "deepseek-coder-33b",
    "phi-3-vision-4.2b",
    "kimi-k2-1t-a32b",
    "phi3.5-moe-42b-a6.6b",
    "zamba2-7b",
    "musicgen-large",
    "mamba2-2.7b",
]

_MODULES = {name: name.replace("-", "_").replace(".", "_") for name in ARCHS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {ARCHS}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.SMOKE


def list_archs() -> list[str]:
    return list(ARCHS)
