"""Gemma-7B: GeGLU, head_dim=256, MHA kv=16. [arXiv:2403.08295; hf]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    activation="geglu",
    tie_embeddings=True,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
                      head_dim=32, d_ff=256, vocab_size=512)
