"""Mistral-Large-Instruct-2407 (123B dense).
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    activation="swiglu",
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                      head_dim=32, d_ff=256, vocab_size=256)
