"""SmolLM-360M (llama-arch small). [hf:HuggingFaceTB/SmolLM-135M; hf]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    activation="swiglu",
    tie_embeddings=True,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=96, num_heads=3, num_kv_heads=1,
                      head_dim=32, d_ff=192, vocab_size=256)
