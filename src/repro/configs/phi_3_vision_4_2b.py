"""Phi-3-Vision-128k (phi3-mini text backbone + CLIP stub frontend).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The vision tower is a STUB: input_specs() provides precomputed patch
embeddings (num_patches x d_model) prepended to the token stream.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="dense",
    modality="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    activation="swiglu",
    num_patches=256,
)

SMOKE = CONFIG.scaled(num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
                      head_dim=32, d_ff=256, vocab_size=256, num_patches=8)
