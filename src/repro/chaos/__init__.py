"""Chaos harness: safety and liveness auditors plus seeded fault-schedule
generation for the gray-failure DSL (`workload/scenario.py`).

- `linearizability`: per-cell checker over client operation histories —
  Spinnaker cells are versioned registers, so commit versions give a total
  write order and the check reduces to interval sweeps (WGL specialized).
- `availability`: replays the *applied* fault timeline into per-cohort
  majority-healthy windows and demands writes succeed within a recovery
  bound inside each one (red-flags a minority-partitioned leader stalling
  a range the majority could serve).
- `schedule`: seeded random generator composing crash/partition/gray-
  failure episodes into DSL text, for reproducible chaos sweeps.
"""

from .availability import (CohortHealthTimeline, audit_availability,
                           majority_healthy_windows)
from .linearizability import HistOp, HistoryRecorder, check_linearizability
from .schedule import generate_chaos_schedule

__all__ = [
    "HistOp",
    "HistoryRecorder",
    "check_linearizability",
    "CohortHealthTimeline",
    "majority_healthy_windows",
    "audit_availability",
    "generate_chaos_schedule",
]
