"""Availability auditing: majority-healthy windows must serve writes.

The auditor replays the *applied* fault timeline (the structured
`FaultEvent`s a `FaultSchedule` actually fired, with crash targets
resolved) into a piecewise-constant health model, derives per-cohort
**majority-healthy windows** — intervals where some majority subset of
the cohort is up, un-degraded, and mutually connected — and then demands
that inside every such window longer than the recovery bound, the
cohort's probe writes succeed within that bound of the window opening.

This is the liveness half of the chaos harness, and it is exactly the
check a minority-partitioned leader fails at lease-off: the majority
side of the cohort is healthy (the window is open), but the stale leader
still holds the leadership znode via its direct ZooKeeper session, no
re-election happens, and no probe write completes until the partition
heals.  Time-bounded leases turn that stall into a bounded failover, and
this auditor is what proves it.

Health model (deliberately conservative — a window is only *required* to
be available, never forbidden):

- crashed nodes are unhealthy until restarted;
- a node is *degraded* while its disk or CPU gray multiplier is at or
  above `degraded_factor`, and for `flap_grace` seconds after a session
  flap begins;
- two nodes are connected iff no symmetric partition separates them, no
  one-way cut covers either direction, and no link fault with a positive
  drop probability (or a delay factor at or above `degraded_factor`)
  touches either direction between them;
- `heal` clears every network fault and gray multiplier (matching
  `SpinnakerCluster.heal`), `restart` only revives its node.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from itertools import combinations
from typing import Iterable, Optional

from ..workload.scenario import FaultEvent


@dataclass
class _State:
    down: set = field(default_factory=set)
    degraded: dict = field(default_factory=dict)   # node -> until (inf ok)
    groups: dict = field(default_factory=dict)     # node -> group idx
    oneway: list = field(default_factory=list)     # (src set, dst set)
    links: dict = field(default_factory=dict)      # (s,d) -> (drop,dup,delay)


class CohortHealthTimeline:
    """Replays applied fault events into per-cohort healthy intervals."""

    def __init__(self, n_nodes: int, degraded_factor: float = 4.0,
                 flap_grace: float = 1.5):
        self.n_nodes = n_nodes
        self.degraded_factor = degraded_factor
        self.flap_grace = flap_grace

    # -- pairwise / subset health on a state snapshot -------------------------
    def _connected(self, st: _State, a: int, b: int) -> bool:
        ga, gb = st.groups.get(a), st.groups.get(b)
        if ga is not None and gb is not None and ga != gb:
            return False
        for src, dst in st.oneway:
            if (a in src and b in dst) or (b in src and a in dst):
                return False
        for s, d in ((a, b), (b, a)):
            drop, _dup, delay = st.links.get((s, d), (0.0, 0.0, 1.0))
            if drop > 0.0 or delay >= self.degraded_factor:
                return False
        return True

    def _node_ok(self, st: _State, n: int, t: float) -> bool:
        return n not in st.down and t >= st.degraded.get(n, 0.0)

    def _majority_healthy(self, st: _State, t: float,
                          members: tuple) -> bool:
        need = len(members) // 2 + 1
        healthy = [m for m in members if self._node_ok(st, m, t)]
        if len(healthy) < need:
            return False
        for subset in combinations(healthy, need):
            if all(self._connected(st, a, b)
                   for a, b in combinations(subset, 2)):
                return True
        return False

    # -- event replay ---------------------------------------------------------
    def _apply(self, st: _State, ev: FaultEvent) -> None:
        if ev.action == "crash":
            st.down.add(ev.node)
        elif ev.action == "restart" and ev.node is not None:
            st.down.discard(ev.node)
        elif ev.action == "partition":
            st.groups = {n: gi for gi, g in enumerate(ev.groups) for n in g}
        elif ev.action == "partition_oneway":
            st.oneway.append((set(ev.groups[0]), set(ev.groups[1])))
        elif ev.action == "link":
            cur = st.links.get((ev.src, ev.dst), (0.0, 0.0, 1.0))
            st.links[(ev.src, ev.dst)] = (
                cur[0] if ev.drop_p is None else ev.drop_p,
                cur[1] if ev.dup_p is None else ev.dup_p,
                cur[2] if ev.factor is None else ev.factor)
        elif ev.action in ("slow_disk", "slow_cpu"):
            if ev.factor is not None and ev.factor >= self.degraded_factor:
                st.degraded[ev.node] = float("inf")
            else:
                st.degraded.pop(ev.node, None)
        elif ev.action == "flap":
            st.degraded[ev.node] = max(
                st.degraded.get(ev.node, 0.0),
                ev.t + ev.outage + self.flap_grace)
        elif ev.action == "heal":
            st.groups = {}
            st.oneway = []
            st.links = {}
            st.degraded = {n: u for n, u in st.degraded.items()
                           if u != float("inf")}

    def windows(self, events: Iterable[FaultEvent], members: tuple,
                t_end: float, t_start: float = 0.0
                ) -> list[tuple[float, float]]:
        """Maximal [a, b) intervals in [t_start, t_end] where `members`
        has a healthy majority.  Event times are schedule-relative; pass
        probe times in the same frame."""
        evs = sorted((e for e in events if e.t <= t_end),
                     key=lambda e: e.t)
        # flap expiries add state-change instants between events
        change_ts = sorted({t_start, t_end, *(e.t for e in evs),
                            *(e.t + e.outage + self.flap_grace
                              for e in evs if e.action == "flap")})
        st = _State()
        out: list[list[float]] = []
        open_at: Optional[float] = None
        i = 0
        for t in change_ts:
            while i < len(evs) and evs[i].t <= t:
                self._apply(st, evs[i])
                i += 1
            healthy = self._majority_healthy(st, t, members)
            if healthy and open_at is None:
                open_at = max(t, t_start)
            elif not healthy and open_at is not None:
                if t > open_at:
                    out.append([open_at, t])
                open_at = None
        if open_at is not None and t_end > open_at:
            out.append([open_at, t_end])
        return out


def majority_healthy_windows(events: Iterable[FaultEvent], members: tuple,
                             t_end: float, n_nodes: int = 5,
                             **kw) -> list[tuple[float, float]]:
    return CohortHealthTimeline(n_nodes, **kw).windows(
        list(events), members, t_end)


def audit_availability(events: Iterable[FaultEvent],
                       cohorts: dict, probe_acks: dict,
                       t_end: float, recovery_bound: float = 4.0,
                       n_nodes: int = 5,
                       degraded_factor: float = 4.0,
                       flap_grace: float = 1.5) -> dict:
    """Audit liveness: for each cohort `rid -> members`, every majority-
    healthy window longer than `recovery_bound` must contain a successful
    probe write acked within `recovery_bound` of the window opening AND
    keep seeing acks at least every `recovery_bound` until it closes.

    `probe_acks` maps rid -> sorted ack times (schedule-relative) of that
    cohort's probe writer.  Returns {"ok", "violations", "windows"}."""
    tl = CohortHealthTimeline(n_nodes, degraded_factor=degraded_factor,
                              flap_grace=flap_grace)
    events = list(events)
    violations = []
    windows_out = {}
    for rid, members in sorted(cohorts.items()):
        wins = tl.windows(events, tuple(members), t_end)
        windows_out[rid] = [[round(a, 6), round(b, 6)] for a, b in wins]
        acks = sorted(probe_acks.get(rid, ()))
        for a, b in wins:
            if b - a <= recovery_bound:
                continue   # too short to demand recovery inside it
            # acks inside the window, scanned for gaps > recovery_bound
            t_prev = a
            for t in acks:
                if t < a:
                    continue
                if t > b:
                    break
                if t - t_prev > recovery_bound:
                    break
                t_prev = t
            # the window's write obligation runs to its close (minus the
            # bound, so a fault landing right at the end can't fail it)
            if t_prev < b - recovery_bound:
                violations.append({
                    "rid": rid, "window": [round(a, 6), round(b, 6)],
                    "last_ack": None if t_prev == a else round(t_prev, 6),
                    "detail": "majority-healthy window served no probe "
                              f"write for > {recovery_bound}s"})
    return {"ok": not violations, "violations": violations,
            "windows": windows_out, "recovery_bound": recovery_bound}
