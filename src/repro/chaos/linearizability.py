"""Per-cell linearizability checking over client operation histories.

Spinnaker's data model makes the general Wing&Gong / P-compositionality
search unnecessary: every committed write to a cell `(key, colname)` is
assigned a dense commit version by the cohort's single Paxos log, so the
*versions themselves* are the linearization order of the writes.  The
checker therefore only has to verify that this order is consistent with
real time and that reads respect it:

W1. **Version uniqueness** — two acknowledged writes to one cell can never
    report the same version (a duplicate would mean a double-commit or a
    split-brain leader pair).
W2. **Real-time write order** — if write A completed before write B was
    invoked, then version(A) < version(B).
R1. **No stale reads** — a strong read that returns version `v` must have
    `v >= ` the highest version of any write to the cell that *completed
    before the read was invoked* (the read-your-quorum guarantee the
    leader lease / read-index protects).
R2. **No reads from the future** — `v` cannot exceed the highest version
    that could exist when the read completed.  Every client *attempt* can
    commit at most once (a retry after a lost ack legitimately commits a
    second time), so the ceiling is the max acked version among writes
    invoked before the response plus the extra attempts of every write
    invoked by then — exact (one slot per write) in retry-free runs.
R3. **Value match** — if `v` equals an acked write's version, the read
    must return that write's value (history writers use unique values).

Timed-out / retry-exhausted writes are *unresolved*: they are allowed to
have taken effect (they widen R2's ceiling) but never constrain R1's
floor.  Histories are recorded with `HistoryRecorder`, which wraps a
`core.cluster.Client` and stamps invoke/response sim-times around every
op it issues.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Any, Optional


@dataclass
class HistOp:
    client: str
    kind: str                 # "write" | "read"
    key: str
    col: str
    invoke: float
    response: float
    ok: bool
    version: Optional[int]    # acked write version / read version
    value: Any = None
    resolved: bool = True     # False: outcome unknown (timeout)
    attempts: int = 1         # client attempts spent (each may commit)


class HistoryRecorder:
    """Issues strong ops through a `Client` and records the invocation /
    response history the checker consumes.  Write values are unique per
    recorder (`<client_id>#<n>`) so R3's value check has teeth."""

    def __init__(self, client, sim, base_versions: Optional[dict] = None):
        self.client = client
        self.sim = sim
        self.history: list[HistOp] = []
        self.base_versions = dict(base_versions or {})
        self._n = 0

    def put(self, key: str, col: str, done=None) -> None:
        self._n += 1
        value = f"{self.client.id}#{self._n}".encode()
        t0 = self.sim.now

        def cb(res):
            self.history.append(HistOp(
                self.client.id, "write", key, col, t0, self.sim.now,
                ok=bool(res.ok), version=res.version, value=value,
                resolved=res.ok, attempts=getattr(res, "attempts", 1)))
            if done is not None:
                done(res)

        self.client.put(key, col, value, cb)

    def get(self, key: str, col: str, done=None) -> None:
        t0 = self.sim.now

        def cb(res):
            self.history.append(HistOp(
                self.client.id, "read", key, col, t0, self.sim.now,
                ok=bool(res.ok), version=res.version, value=res.value,
                resolved=res.ok))
            if done is not None:
                done(res)

        self.client.get(key, col, True, cb)


def _cell_violations(cell: tuple, ops: list[HistOp], base: int) -> list[dict]:
    bad: list[dict] = []

    def flag(rule: str, detail: str, op: Optional[HistOp] = None) -> None:
        bad.append({"cell": list(cell), "rule": rule, "detail": detail,
                    "client": op.client if op else None,
                    "t": op.response if op else None})

    acked = [o for o in ops if o.kind == "write" and o.ok
             and o.version is not None]
    unresolved = [o for o in ops if o.kind == "write" and not o.resolved]
    reads = [o for o in ops if o.kind == "read" and o.ok
             and o.version is not None]

    # W1: version uniqueness
    by_version: dict[int, HistOp] = {}
    for w in acked:
        if w.version in by_version:
            flag("W1", f"duplicate acked version {w.version} "
                 f"(clients {by_version[w.version].client}, {w.client})", w)
        else:
            by_version[w.version] = w
        if w.version <= base:
            flag("W1", f"acked version {w.version} <= preload base {base}", w)

    # W2 + R1 share a sweep: walk completions in time order, maintaining
    # the highest version known to be committed by each instant; any write
    # or read *invoked* after that instant must see at least that version.
    completions = sorted(((w.response, w.version) for w in acked))
    comp_times = [t for t, _v in completions]
    comp_pmax = []
    for _t, v in completions:
        comp_pmax.append(max(comp_pmax[-1], v) if comp_pmax else v)

    def floor_at(t: float) -> int:
        i = bisect.bisect_left(comp_times, t)
        return comp_pmax[i - 1] if i else base

    for w in acked:
        f = floor_at(w.invoke)
        if w.version <= f and f > base:
            flag("W2", f"write acked version {w.version} but version {f} "
                 "had already completed before it was invoked", w)

    # R2 ceiling: max acked version invoked by then, plus commit slots for
    # extra attempts (acked writes: attempts-1 beyond the acked commit;
    # unresolved writes: every attempt may have committed)
    acked_by_invoke = sorted((w.invoke, w.version) for w in acked)
    inv_times = [t for t, _v in acked_by_invoke]
    inv_pmax = []
    for _t, v in acked_by_invoke:
        inv_pmax.append(max(inv_pmax[-1], v) if inv_pmax else v)
    extra_slots = sorted([(w.invoke, max(0, w.attempts - 1)) for w in acked]
                         + [(w.invoke, max(1, w.attempts))
                            for w in unresolved])
    slot_times = [t for t, _n in extra_slots]
    slot_psum = []
    for _t, n in extra_slots:
        slot_psum.append((slot_psum[-1] if slot_psum else 0) + n)

    def ceiling_at(t: float) -> int:
        i = bisect.bisect_left(inv_times, t)
        vmax = inv_pmax[i - 1] if i else base
        j = bisect.bisect_left(slot_times, t)
        return vmax + (slot_psum[j - 1] if j else 0)

    for r in reads:
        f = floor_at(r.invoke)
        if r.version < f:
            flag("R1", f"stale read: returned version {r.version} but "
                 f"version {f} completed before the read was invoked", r)
        c = ceiling_at(r.response)
        if r.version > c:
            flag("R2", f"read from the future: returned version "
                 f"{r.version} > ceiling {c}", r)
        w = by_version.get(r.version)
        if w is not None and r.value != w.value:
            flag("R3", f"value mismatch at version {r.version}: read "
                 f"{r.value!r}, write was {w.value!r}", r)
    return bad


def check_linearizability(history: list[HistOp],
                          base_versions: Optional[dict] = None
                          ) -> list[dict]:
    """Check a history; returns a list of violation dicts (empty = clean).

    `base_versions` maps `(key, col)` to the version preloaded before the
    history started (defaults to 0 = cell created by the history)."""
    base_versions = base_versions or {}
    cells: dict[tuple, list[HistOp]] = {}
    for op in history:
        cells.setdefault((op.key, op.col), []).append(op)
    violations: list[dict] = []
    for cell, ops in sorted(cells.items()):
        violations.extend(
            _cell_violations(cell, ops, int(base_versions.get(cell, 0))))
    return violations
