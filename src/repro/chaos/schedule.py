"""Seeded random chaos-schedule generation.

Composes the fault DSL's verbs (`workload/scenario.py`) into a bounded
sequence of *episodes*: each episode injects one fault class, holds it,
then clears it (restart for crashes, `heal` for network/gray faults)
before the next begins, and the whole schedule ends fully healed with a
quiet tail.  Episodes are sequential on purpose — the availability
auditor then sees crisp majority-healthy windows between faults, so the
liveness check has teeth on every schedule instead of only on lucky
overlaps.

Generation uses its own `random.Random(seed)` — never the simulator's
stream — so the same seed yields the same schedule text regardless of
what the simulation itself consumes.
"""

from __future__ import annotations

import random

# every fault class the harness can inject; seeds rotate through these so
# any handful of seeds covers crashes, asymmetric cuts, symmetric
# partitions, lossy/duplicating/slow links, gray disk/CPU, and ZK flaps
EPISODES = ("crash", "crash_leader", "partition", "oneway", "drop_link",
            "dup_link", "slow_link", "slow_disk", "slow_cpu", "flap")


def generate_chaos_schedule(seed: int, n_nodes: int = 5,
                            duration: float = 18.0,
                            episodes: int = 5,
                            quiet_tail: float = 4.0,
                            n_ranges: int = 5) -> str:
    """Deterministic DSL text for one chaos run of `duration` seconds.

    The first `episodes` fault classes come from a seed-rotated walk over
    EPISODES (guaranteeing class diversity across consecutive seeds), the
    hold times and targets from `random.Random(seed)`."""
    rng = random.Random(seed)
    nodes = list(range(n_nodes))
    budget = duration - quiet_tail
    slot = budget / max(1, episodes)
    lines = [f"# chaos schedule seed={seed} nodes={n_nodes}"]
    classes = [EPISODES[(seed + i) % len(EPISODES)] for i in range(episodes)]
    rng.shuffle(classes)
    t = 0.4
    for kind in classes:
        hold = min(slot * 0.6, 0.8 + rng.random() * (slot * 0.5))
        t_inj = round(t, 2)
        t_clear = round(min(t + hold, budget - 0.1), 2)
        if t_clear <= t_inj:
            break
        if kind == "crash":
            n = rng.choice(nodes)
            lose = " lose_disk" if rng.random() < 0.25 else ""
            lines.append(f"at {t_inj}s crash node {n}{lose}")
            lines.append(f"at {t_clear}s restart node {n}")
        elif kind == "crash_leader":
            rid = rng.randrange(n_ranges)
            lines.append(f"at {t_inj}s crash leader of {rid}")
            lines.append(f"at {t_clear}s restart crashed")
        elif kind == "partition":
            k = rng.randrange(1, (n_nodes - 1) // 2 + 1)
            minority = rng.sample(nodes, k)
            majority = [n for n in nodes if n not in minority]
            lines.append(
                "at %ss partition {%s} | {%s}"
                % (t_inj, ",".join(map(str, sorted(minority))),
                   ",".join(map(str, sorted(majority)))))
            lines.append(f"at {t_clear}s heal")
        elif kind == "oneway":
            k = rng.randrange(1, (n_nodes - 1) // 2 + 1)
            src = rng.sample(nodes, k)
            dst = [n for n in nodes if n not in src]
            lines.append(
                "at %ss partition oneway {%s} -> {%s}"
                % (t_inj, ",".join(map(str, sorted(src))),
                   ",".join(map(str, sorted(dst)))))
            lines.append(f"at {t_clear}s heal")
        elif kind in ("drop_link", "dup_link", "slow_link"):
            a, b = rng.sample(nodes, 2)
            if kind == "drop_link":
                p = round(0.1 + rng.random() * 0.4, 2)
                lines.append(f"at {t_inj}s drop link {a} {b} p={p}")
            elif kind == "dup_link":
                p = round(0.1 + rng.random() * 0.4, 2)
                lines.append(f"at {t_inj}s dup link {a} {b} p={p}")
            else:
                f = round(4 + rng.random() * 12, 1)
                lines.append(f"at {t_inj}s slow link {a} {b} x{f}")
            lines.append(f"at {t_clear}s heal")
        elif kind in ("slow_disk", "slow_cpu"):
            n = rng.choice(nodes)
            f = round(5 + rng.random() * 20, 1)
            what = "disk" if kind == "slow_disk" else "cpu"
            lines.append(f"at {t_inj}s slow {what} on {n} x{f}")
            lines.append(f"at {t_clear}s heal")
        else:   # flap
            n = rng.choice(nodes)
            outage = round(0.5 + rng.random() * 1.0, 2)
            lines.append(f"at {t_inj}s flap session of {n} for {outage}s")
        t = t_clear + 0.3
    lines.append(f"at {round(budget, 2)}s heal")
    return "\n".join(lines) + "\n"
