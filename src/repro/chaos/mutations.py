"""Mutation corpus: known-fixed protocol bugs behind test-only switches.

Each mutation re-introduces a real bug from this repo's history
(`ReplicaConfig.bug_*` switches) and drives a choreography that makes it
bite; the invariant watchdog must pinpoint the bug **at the violating
transition** (the journal entry kind named below), and the same
choreography with the fix in place must run watchdog-silent:

``catchup_starvation`` (PR 6)
    Catch-up retries were paced off the leader-heartbeat clock, which
    lease beats keep fresh — a CATCHUP replica whose data was lost never
    re-requested it.  Violates ``catchup_progress`` at a ``lease_heard``
    beat.
``takeover_wedge`` (PR 6)
    Takeover skipped reloading durable records of the unresolved window
    from the WAL when the in-memory queue had dropped them (an aborted
    CATCHUP join), so the new regime advertised an LST it could never
    re-commit.  Violates ``takeover_completeness`` at the ``takeover``
    transition (``missing`` > 0).
``ack_before_force``
    A follower acked a proposal on receipt instead of after its WAL
    force — the commit rule then counts an ack that a crash can revoke.
    Violates ``acked_durable`` at the first ``ack``.

`run_mutation(name, mutated=...)` runs one choreography; `run_corpus`
runs every mutation both ways and reports per-bug detection plus the
zero-false-positive control results.
"""

from __future__ import annotations

from typing import Callable, Optional


def _run_until(sim, cond: Callable[[], bool], timeout: float,
               step: float = 0.05) -> bool:
    deadline = sim.now + timeout
    while sim.now < deadline:
        if cond():
            return True
        sim.run(until=min(sim.now + step, deadline))
    return cond()


def _build(seed: int, n_nodes: int = 5):
    from ..workload.experiment import ExperimentConfig, build_spinnaker
    cfg = ExperimentConfig(seed=seed, n_nodes=n_nodes, disk="ssd")
    sim, cluster = build_spinnaker(cfg, num_keys=40)
    return sim, cluster


def _range_keys(cluster, rid: int, n: int) -> list[str]:
    from ..core.cluster import key_of
    keys = []
    i = 0
    while len(keys) < n and i < 4000:
        if cluster.range_of(key_of(i)) == rid:
            keys.append(key_of(i))
        i += 1
    return keys


def _seed_writes(cluster, keys, tag: str = "base") -> None:
    c = cluster.make_client(f"mut-{tag}")
    for k in keys:
        c.sync_put(k, "c", b"v-" + tag.encode())


# -- choreographies ---------------------------------------------------------

def _scenario_catchup_starvation(sim, cluster) -> None:
    """Crash+restart a follower so it rejoins through catch-up; the
    `drop_first_catchup` fault hook swallows the first catch-up payload.
    Fixed protocol: the 0.6s retry clock re-requests and the replica
    joins.  Mutated: lease beats keep the (mispaced) retry clock fresh
    and the replica starves in CATCHUP."""
    rid = 0
    keys = _range_keys(cluster, rid, 6)
    _seed_writes(cluster, keys)
    leader = cluster.leader_replica(rid)
    follower = next(m for m in cluster.members[rid]
                    if m != leader.node.node_id)
    cluster.crash_node(follower)
    sim.run_for(1.0)
    _seed_writes(cluster, keys, tag="gap")   # the restarted node is behind
    cluster.restart_node(follower)
    sim.run_for(6.0)                         # beats arrive every 0.25s


def _scenario_takeover_wedge(sim, cluster) -> None:
    """One-way-partition the leader (its sends vanish, it still hears the
    world) with writes in flight.  The followers never saw those commits,
    so when the ex-leader briefly re-wins (max LST), their CATCHUP joins
    drop the volatile tail; its takeover times out without acks, and a
    tail-dropped follower wins the next election.  Fixed protocol: that
    takeover reloads the window from its WAL and re-commits it.  Mutated:
    the reload is skipped and the takeover advertises an LST it can never
    re-send (`missing` > 0) — the range wedges.

    Runs on 3 nodes so every cohort spans the whole cluster: the cut
    silences the ex-leader's lease renewals on every range it leads.
    (On a wider cluster a range sharing only ONE peer with the cut keeps
    acking the old leader's lease through its third member while the cut
    peer deposes it — a genuine gray-failure lease overlap, but a
    different shape than the one this mutation targets.)"""
    from ..core.types import OpType, WriteOp
    rid = 0
    keys = _range_keys(cluster, rid, 8)
    _seed_writes(cluster, keys[:4])
    rep = cluster.leader_replica(rid)
    lnode = rep.node.node_id
    for p in cluster.members[rid]:
        if p != lnode:
            cluster.set_link_fault(lnode, p, drop_p=1.0)
    sim.run_for(0.05)
    for k in keys[4:]:
        # direct submission (not via a Client): retries must not reroute
        # to a successor and mint higher LSNs there
        rep.client_write(WriteOp(OpType.PUT, k, "c", b"stranded"),
                         lambda r: None)
    sim.run_for(0.05)
    assert rep.lst > rep.cmt, "no stranded tail; choreography broken"
    # lease lapse -> deposal -> ex-leader re-wins and stalls -> abdicates
    # suppressed -> a CATCHUP-dropped follower takes over (needs reload)
    sim.run_for(8.0)
    cluster.heal()
    sim.run_for(2.0)


def _scenario_ack_before_force(sim, cluster) -> None:
    """Plain committed write load: with the mutation every follower acks
    at receive time, ahead of its WAL force."""
    keys = _range_keys(cluster, 0, 6) + _range_keys(cluster, 1, 6)
    _seed_writes(cluster, keys)
    sim.run_for(0.5)


MUTATIONS: dict[str, dict] = {
    "catchup_starvation": {
        "switch": "bug_catchup_starvation",
        "hooks": {"drop_first_catchup": True},
        "invariant": "catchup_progress",
        "at_kind": "lease_heard",
        "scenario": _scenario_catchup_starvation,
        "description": "catch-up retries paced off the lease-beat clock "
                       "never fire; CATCHUP starves under a live leader",
    },
    "takeover_wedge": {
        "switch": "bug_takeover_wedge",
        "hooks": {},
        "n_nodes": 3,
        "invariant": "takeover_completeness",
        "at_kind": "takeover",
        "scenario": _scenario_takeover_wedge,
        "description": "takeover skips the WAL reload of the unresolved "
                       "window and advertises records it cannot re-send",
    },
    "ack_before_force": {
        "switch": "bug_ack_before_force",
        "hooks": {},
        "invariant": "acked_durable",
        "at_kind": "ack",
        "scenario": _scenario_ack_before_force,
        "description": "followers ack proposals at receive time, before "
                       "the WAL force that makes the ack true",
    },
}


def run_mutation(name: str, mutated: bool = True, seed: int = 0,
                 export_journal: bool = False) -> dict:
    """Run one mutation choreography and report what the watchdog saw.

    `mutated=False` is the control arm: same choreography, same fault
    hooks, fixed protocol — the watchdog must stay silent."""
    spec = MUTATIONS[name]
    sim, cluster = _build(seed, n_nodes=spec.get("n_nodes", 5))
    rcfg = cluster.cfg.node.replica      # shared by every replica
    for hook, val in spec["hooks"].items():
        setattr(rcfg, hook, val)
    if mutated:
        setattr(rcfg, spec["switch"], True)
    spec["scenario"](sim, cluster)
    wd = cluster.obs.watchdog
    hits = [v for v in wd.violations
            if v["invariant"] == spec["invariant"]
            and v["kind"] == spec["at_kind"]]
    detected = bool(hits)
    first: Optional[dict] = None
    if hits:
        first = {k: hits[0][k] for k in
                 ("t", "invariant", "rid", "node", "kind", "detail")}
    extra = {}
    if export_journal:
        extra["journal_jsonl"] = cluster.obs.journal.to_jsonl()
    return {
        **extra,
        "name": name,
        "mutated": mutated,
        "expected_invariant": spec["invariant"],
        "expected_at_kind": spec["at_kind"],
        "detected": detected,
        "first_violation": first,
        "watchdog": wd.summary(),
        "ok": detected if mutated else wd.ok,
    }


def run_corpus(seed: int = 0) -> dict:
    """Both arms for every mutation: the mutated run must be detected at
    the expected transition, the control run must be watchdog-silent."""
    out: dict = {"mutations": {}, "ok": True}
    for name in MUTATIONS:
        bug = run_mutation(name, mutated=True, seed=seed)
        fix = run_mutation(name, mutated=False, seed=seed)
        out["mutations"][name] = {
            "description": MUTATIONS[name]["description"],
            "detected": bug["detected"],
            "detected_at": bug["first_violation"],
            "control_silent": fix["watchdog"]["ok"],
            "mutated_by_invariant": bug["watchdog"]["by_invariant"],
            "control_by_invariant": fix["watchdog"]["by_invariant"],
        }
        out["ok"] = out["ok"] and bug["detected"] and fix["watchdog"]["ok"]
    return out
