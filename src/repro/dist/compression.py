"""Gradient compression: symmetric int8 quantization with optional error
feedback (residual carried to the next step so quantization error does not
accumulate into bias).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def _quantize_leaf(g: jax.Array, residual: Optional[jax.Array] = None):
    """Round-trip one tensor through int8; returns (dequantized, new residual)."""
    corrected = g if residual is None else g + residual
    scale = jnp.max(jnp.abs(corrected)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(corrected / safe), -127, 127).astype(jnp.int8)
    deq = (q.astype(corrected.dtype) * safe).astype(g.dtype)
    return deq, corrected - deq


def compress_decompress(grads: Any) -> Any:
    """Simulate the all-reduce compression round-trip (no feedback)."""
    return jax.tree.map(lambda g: _quantize_leaf(g)[0], grads)


def compress_with_feedback(grads: Any, residuals: Optional[Any] = None):
    """Quantize with error feedback.

    Returns `(compressed_grads, new_residuals)`; pass the residuals back in
    on the next call (None on the first step).  The residual bounds the
    *accumulated* error by a single step's quantization error.
    """
    if residuals is None:
        pairs = jax.tree.map(_quantize_leaf, grads)
    else:
        pairs = jax.tree.map(_quantize_leaf, grads, residuals)
    out = jax.tree.map(lambda p: p[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return out, res
