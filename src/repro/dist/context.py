"""Active mesh context.

A tiny indirection layer so model code (`repro.models`) can ask "what mesh
am I running under?" without importing the sharding machinery; the hook is
installed by `repro.dist.sharding.MeshContext`.
"""

from __future__ import annotations

from typing import Optional

_CURRENT = None


def set_ctx(ctx) -> None:
    global _CURRENT
    _CURRENT = ctx


def current_ctx() -> Optional["object"]:
    """The innermost active MeshContext, or None outside any context."""
    return _CURRENT
