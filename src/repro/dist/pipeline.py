"""Pipeline parallelism: GPipe schedule over a mesh axis, plus napkin math
for choosing pipeline- vs data-parallelism across a slow interconnect.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    """Fraction of device time idle in a GPipe schedule.

    A pipeline of S stages fed M microbatches runs M + S - 1 ticks, of
    which S - 1 per device are fill/drain bubble.
    """
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pp_vs_dp_napkin(grad_bytes: float, dcn_bw: float, step_compute_s: float,
                    n_micro: int, n_stages: int) -> dict:
    """Back-of-envelope: pipeline across a slow link vs data-parallel
    all-reduce over it.

    DP pays a ~2x grad-bytes all-reduce on the link every step; PP pays the
    fill/drain bubble instead (cross-stage activations are ignored — they
    are tiny next to full gradients at napkin precision).
    """
    dp_allreduce_s = 2.0 * grad_bytes / dcn_bw
    bubble_s = step_compute_s * bubble_fraction(n_micro, n_stages)
    return {
        "dp_allreduce_s": dp_allreduce_s,
        "bubble_s": bubble_s,
        "pp_wins": bool(bubble_s < dp_allreduce_s),
        "advantage_s": dp_allreduce_s - bubble_s,
    }


def gpipe(stage_fn: Callable, mesh, axis: str = "pipe") -> Callable:
    """Build a GPipe runner over `axis` of `mesh`.

    `stage_fn(W_stage, x)` applies one pipeline stage.  The returned
    `run(Ws, x)` takes stage-stacked params `Ws: (n_stages, ...)` and
    microbatched inputs `x: (n_micro, mb, ...)`, and equals applying the
    stages sequentially to every microbatch.  Stages are laid out one per
    device along `axis`; activations move between stages with ppermute
    (lowers to collective-permute).
    """
    n_devices = mesh.shape[axis]

    def run(Ws, x):
        n_stages = Ws.shape[0]
        if n_stages != n_devices:
            raise ValueError(
                f"gpipe: {n_stages} stages but mesh axis {axis!r} has "
                f"{n_devices} devices (need exactly one stage per device)")
        n_micro = x.shape[0]
        ticks = n_micro + n_stages - 1
        ring = [(i, (i + 1) % n_devices) for i in range(n_devices)]

        def device_body(W_local, x_all):
            W = W_local[0]                      # this device's stage params
            stage = jax.lax.axis_index(axis)
            state0 = jnp.zeros(x_all.shape[1:], x_all.dtype)
            out0 = jnp.zeros_like(x_all)

            def tick(carry, t):
                state, out = carry
                # stage 0 injects microbatch t; others consume the permuted
                # activation from the previous tick
                x_in = jnp.where(stage == 0,
                                 x_all[jnp.clip(t, 0, n_micro - 1)], state)
                y = stage_fn(W, x_in)
                # the last stage finishes microbatch t - (S - 1) at tick t
                mb_done = t - (n_stages - 1)
                write = (stage == n_stages - 1) & (mb_done >= 0)
                out = jnp.where(
                    write,
                    jax.lax.dynamic_update_index_in_dim(
                        out, y, jnp.clip(mb_done, 0, n_micro - 1), 0),
                    out)
                state = jax.lax.ppermute(y, axis, ring)
                return (state, out), None

            (_, out), _ = jax.lax.scan(tick, (state0, out0),
                                       jnp.arange(ticks))
            return out

        mapped = shard_map(device_body, mesh=mesh,
                           in_specs=(P(axis), P()), out_specs=P(axis),
                           check_rep=False)
        stacked = mapped(Ws, x)       # (n_devices * n_micro, mb, ...)
        return stacked[-n_micro:]     # only the last stage's buffer is real

    return run
