"""Sharding policy: map parameter/batch/cache trees to NamedShardings.

`ShardingPolicy` decides which mesh axes carry tensor parallelism (TP),
data parallelism (DP/FSDP), and expert parallelism (EP).  `param_spec`
assigns a PartitionSpec per parameter from its tree path; indivisible
assignments are dropped (`_drop_indivisible`) rather than erroring, so one
policy covers every architecture in `repro.configs`.

`MeshContext` is the activation half: entering it publishes the context to
`repro.dist.context` and installs the `pshard` activation-sharding hook in
`repro.models.layers`.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.tree_util as jtu
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import context as _context

# parameter names whose LAST dim is the TP (output-feature) dim
_TP_LAST = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "unembed"}
# parameter names whose SECOND-TO-LAST dim is the TP (input-feature) dim
_TP_SECOND = {"wo", "w_down", "out_proj"}


def path_str(path) -> str:
    """'/'-joined tree path; accepts DictKey/SequenceKey/objects with .key."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


@dataclass
class ShardingPolicy:
    """Which mesh axes carry which kind of parallelism."""
    tp_axis: str = "model"
    dp_axes: tuple = ("data",)          # batch/activation axes
    fsdp_axes: tuple = ("data",)        # parameter-sharding axes
    ep_axes: tuple = ("data",)          # expert-parallel axes
    seq_parallel: bool = False

    @classmethod
    def for_mesh(cls, mesh: Mesh, seq_parallel: bool = False,
                 shard_params_on_pod: bool = False) -> "ShardingPolicy":
        axes = tuple(mesh.axis_names)
        tp = "model" if "model" in axes else axes[-1]
        dp = tuple(a for a in axes if a != tp)
        fsdp = tuple(a for a in dp if a != "pod" or shard_params_on_pod)
        ep = tuple(a for a in dp if a != "pod") or dp
        return cls(tp_axis=tp, dp_axes=dp, fsdp_axes=fsdp, ep_axes=ep,
                   seq_parallel=seq_parallel)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def _drop_indivisible(spec: P, leaf, mesh: Mesh) -> P:
    """Replace spec entries whose axis product doesn't divide the dim."""
    shape = getattr(leaf, "shape", leaf)
    out = []
    for d, entry in enumerate(tuple(spec)):
        if entry is not None and d < len(shape) \
                and shape[d] % _axis_size(mesh, entry) == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def param_spec(path, leaf, pol: ShardingPolicy, cfg=None) -> P:
    """PartitionSpec for one parameter, from its name and rank.

    Weights are (..., in, out), usually stacked over layers at dim 0.  TP
    shards the feature dim named by `_TP_LAST`/`_TP_SECOND`; FSDP shards the
    opposite matrix dim.  Vectors and norms replicate.
    """
    if leaf.ndim <= 1:
        return P(*([None] * leaf.ndim))
    name = path_str(path).rsplit("/", 1)[-1]
    spec: list = [None] * leaf.ndim
    fsdp = tuple(pol.fsdp_axes) or None
    if name in _TP_LAST:
        spec[-1] = pol.tp_axis
        if fsdp:
            spec[-2] = fsdp
    elif name in _TP_SECOND:
        spec[-2] = pol.tp_axis
        if fsdp:
            spec[-1] = fsdp
    elif name == "embed":
        if fsdp:
            spec[0] = fsdp
    else:
        # unknown >=2D weight: FSDP on its largest dim
        if fsdp:
            spec[max(range(leaf.ndim), key=lambda d: leaf.shape[d])] = fsdp
    return P(*spec)


class MeshContext:
    """Activate a (mesh, config, policy) triple.

    Inside the `with` block, `repro.dist.context.current_ctx()` returns
    this object and the model's `pshard` hook constrains activation batch
    dims onto the DP axes.  Provides the sharding constructors the dry-run
    driver and trainers need.
    """

    def __init__(self, mesh: Mesh, cfg: Any, pol: ShardingPolicy):
        self.mesh = mesh
        self.cfg = cfg
        self.pol = pol
        self._prev_ctx = None

    # -- constructors ---------------------------------------------------------
    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    def _named(self, spec: P, leaf) -> NamedSharding:
        return NamedSharding(self.mesh,
                             _drop_indivisible(spec, leaf, self.mesh))

    def param_shardings(self, tree_shape):
        def one(path, leaf):
            return self._named(param_spec(path, leaf, self.pol, self.cfg),
                               leaf)
        return jtu.tree_map_with_path(one, tree_shape)

    def batch_sharding(self, batch):
        """Shard the leading (batch) dim of every input leaf over DP."""
        dp = tuple(self.pol.dp_axes)

        def one(leaf):
            if getattr(leaf, "ndim", 0) == 0 or not dp:
                return self.replicated()
            spec = P(*([dp] + [None] * (leaf.ndim - 1)))
            return self._named(spec, leaf)
        return jax.tree.map(one, batch)

    def cache_sharding(self, cache_shape):
        """KV/SSM cache: (L, B, heads, ...) — batch on DP, heads on TP."""
        dp = tuple(self.pol.dp_axes)
        tp = self.pol.tp_axis

        def one(leaf):
            nd = getattr(leaf, "ndim", 0)
            if nd <= 1:
                spec = P(*([dp] if nd == 1 and dp else [None] * nd))
            else:
                entries: list = [None] * nd
                if dp:
                    entries[1] = dp
                if nd >= 4:
                    entries[2] = tp
                spec = P(*entries)
            return self._named(spec, leaf)
        return jax.tree.map(one, cache_shape)

    # -- activation hook -------------------------------------------------------
    def _shard_activation(self, x, kind: str):
        dp = tuple(self.pol.dp_axes)
        if not dp or getattr(x, "ndim", 0) == 0:
            return x
        spec = _drop_indivisible(
            P(*([dp] + [None] * (x.ndim - 1))), x, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    # -- context protocol ------------------------------------------------------
    def __enter__(self) -> "MeshContext":
        from ..models.layers import install_shard_hook
        self._prev_ctx = _context.current_ctx()
        _context.set_ctx(self)
        install_shard_hook(self._shard_activation)
        return self

    def __exit__(self, *exc) -> None:
        from ..models.layers import install_shard_hook
        _context.set_ctx(self._prev_ctx)
        install_shard_hook(None)
