"""Distributed-training helpers: pipeline-parallel schedules and gradient
compression.  Split out of `train/` so substrate tests and napkin math can
import them without pulling in the full model stack.
"""

from .compression import compress_decompress, compress_with_feedback
from .pipeline import bubble_fraction, gpipe, pp_vs_dp_napkin

__all__ = [
    "bubble_fraction",
    "compress_decompress",
    "compress_with_feedback",
    "gpipe",
    "pp_vs_dp_napkin",
]
