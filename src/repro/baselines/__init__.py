"""Baseline systems the paper compares against."""

from .cassandra import CassandraCluster, CassandraConfig

__all__ = ["CassandraCluster", "CassandraConfig"]
