"""Cassandra-style eventually consistent datastore (§9 baseline).

The paper benchmarks Spinnaker against Cassandra (from whose codebase it
was derived), so the comparison system is reproduced on the same simulator
with the same storage/log/network models:

- no leaders: any cohort replica coordinates a request;
- writes go to all 3 replicas; *weak* writes ack after 1 durable copy,
  *quorum* writes after 2 (same durability as Spinnaker, §9.2);
- *weak* reads touch 1 replica; *quorum* reads touch 2, resolve conflicts
  by timestamp (last-writer-wins) and fire async read repair;
- no quorum-based recovery: a restarted replica serves stale data until
  read repair catches it (the consistency gap §9 highlights).

Timestamps come from the coordinator's clock — concurrent writes to
different coordinators can conflict and LWW-resolve, which is exactly the
anomaly Spinnaker's leader serialization removes.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from ..core.cluster import key_of
from ..core.sim import (Disk, DiskParams, FifoServer, LatencyStats, NetParams,
                        Network, Simulator)
from ..core.types import ErrorCode, Result
from ..obs import Observability, ObsConfig


@dataclass
class CassandraConfig:
    n_nodes: int = 5
    num_keys: int = 100_000
    disk: DiskParams = field(default_factory=DiskParams.hdd)
    net: NetParams = field(default_factory=NetParams)
    # coordinator-side mutation batching, mirroring the Spinnaker leader's
    # adaptive proposal batching so the §9 comparison stays fair: real
    # Cassandra coordinators batch mutations per destination replica too
    batch: str = "adaptive"             # "adaptive" | "off"
    batch_max_records: int = 32
    batch_deadline: float = 0.5e-3
    # server-side ingress batching, mirroring core/node.py (same codebase,
    # §9): messages arriving while the CPU is busy drain as one batch job —
    # per-message overhead once per message class, marginal per record
    ingress_batch: bool = True
    obs: ObsConfig = field(default_factory=ObsConfig)


@dataclass
class _TCell:
    value: Any
    ts: float


# CPU costs mirror the Spinnaker node's (same codebase, §9): a
# (per-message overhead, per-mutation marginal) split, so batched
# replica_write messages amortise the overhead exactly like proposes do
CPU_READ = (96e-6, 14e-6)
CPU_WRITE = (30e-6, 25e-6)
CPU_FWD = (16e-6, 12e-6)
CPU_ACK = (8e-6, 0.0)

# kinds that carry client requests; everything else (forwarded replica
# reads/writes, acks) is protocol traffic the two-class ingress drain
# runs ahead of client request processing
_CLIENT_KINDS = ("coord_read", "coord_write")

# message kind -> profiler component label (mirrors core/node.py so the
# Spinnaker-vs-Cassandra utilization shares compare like for like)
COMPONENT_OF = {
    "coord_read": "client.read",
    "coord_write": "client.write",
    "replica_write": "replica.fwd",
    "replica_read": "replica.fwd",
    "ack": "replica.ack",
    "read_resp": "replica.ack",
}


class CassandraNode:
    def __init__(self, cluster: "CassandraCluster", node_id: int,
                 cfg: CassandraConfig):
        self.cluster = cluster
        self.node_id = node_id
        self.cfg = cfg
        self.sim = cluster.sim
        self.cpu = FifoServer(self.sim, name=f"ccpu{node_id}")
        self.disk = Disk(self.sim, cfg.disk, name=f"clog{node_id}")
        self.data: dict[tuple[str, str], _TCell] = {}
        self.up = True
        # coordinator-side per-destination mutation accumulators
        self._mut_batch: dict[int, list[tuple]] = {}
        self._mut_timer: dict[int, Any] = {}
        self.batches_sent = 0
        self.muts_batched = 0
        # server-side ingress batching (mirrors SpinnakerNode; same
        # codebase, §9): staged messages drained as one amortised CPU job
        self._ingress: list[tuple] = []
        self._ingress_ev = None
        self.ingress_draining = False
        self.ingress_batches = 0
        self.ingress_msgs = 0

    # -- local replica ops -------------------------------------------------------
    def local_write(self, key: str, colname: str, value: Any, ts: float,
                    done: Callable) -> None:
        """Log force (group commit) then memtable apply."""
        def after_force():
            if not self.up:
                return
            cur = self.data.get((key, colname))
            if cur is None or ts >= cur.ts:
                self.data[(key, colname)] = _TCell(value, ts)
            done()
        self.disk.force(4200, after_force, component="wal.force")

    def _apply_local(self, key: str, colname: str, value: Any,
                     ts: float) -> None:
        cur = self.data.get((key, colname))
        if cur is None or ts >= cur.ts:
            self.data[(key, colname)] = _TCell(value, ts)

    def local_read(self, key: str, colname: str) -> Optional[_TCell]:
        return self.data.get((key, colname))

    def crash(self, lose_disk: bool = False) -> None:
        self.up = False
        self.cluster.net.set_down(self.node_id, True)
        self.cpu.close()
        self.cpu.bump_generation()
        self.disk.crash()
        self._ingress.clear()
        if self._ingress_ev is not None:
            self._ingress_ev.cancel()
            self._ingress_ev = None
        for timer in self._mut_timer.values():
            timer.cancel()
        self._mut_timer.clear()
        self._mut_batch.clear()
        if lose_disk:
            self.data.clear()

    def restart(self) -> None:
        # commit log replay restores the pre-crash memtable (all writes were
        # forced before ack); no catch-up — the replica is simply stale.
        self.up = True
        self.cluster.net.set_down(self.node_id, False)
        self.cpu.open()

    # -- message entry points ------------------------------------------------------
    def handle(self, kind: str, kw: dict) -> None:
        if not self.up:
            return
        # trace context rides the request; coord_write carries it onward
        # (it stamps durable-commit), reads only need the receive mark
        tr = kw.pop("trace", None)
        if tr is not None:
            tr.mark_recv(self.sim.now, self.node_id)
            if kind == "coord_write":
                kw["trace"] = tr
        base, per_rec = {"coord_read": CPU_READ, "coord_write": CPU_WRITE,
                         "replica_write": CPU_FWD, "replica_read": CPU_FWD,
                         "ack": CPU_ACK}.get(kind, CPU_ACK)
        n = len(kw["muts"]) if "muts" in kw else \
            len(kw["tags"]) if "tags" in kw else 1
        thunk = lambda: getattr(self, kind)(**kw)   # noqa: E731
        if not self.cfg.ingress_batch or (
                not self._ingress and self.cpu.queue_delay() <= 1e-12):
            self._profile_cpu(kind, base + per_rec * n)
            self.cpu.submit(base + per_rec * n, thunk)
            return
        self._ingress.append((kind, base, per_rec * n, thunk))
        if self._ingress_ev is None:
            self._ingress_ev = self.sim.schedule(
                self.cpu.queue_delay(), self._drain_ingress)

    def _profile_cpu(self, kind: str, cost: float) -> None:
        prof = self.cluster.obs.profiler
        if prof.enabled:
            wait = self.cpu.queue_delay()
            prof.cpu_work(self.node_id, COMPONENT_OF.get(kind, "other"),
                          cost * self.cpu.slow_factor, queue_wait_s=wait)
            self.cluster.obs.metrics.observe(
                self.node_id, "cpu_queue_wait_s", wait)

    def _drain_ingress(self) -> None:
        self._ingress_ev = None
        if not self.up:
            self._ingress.clear()
            return
        if self.cpu.queue_delay() > 1e-12:
            self._ingress_ev = self.sim.schedule(
                self.cpu.queue_delay(), self._drain_ingress)
            return
        batch, self._ingress = self._ingress, []
        if not batch:
            return
        self.ingress_batches += 1
        self.ingress_msgs += len(batch)
        # Two-class drain, mirroring the Spinnaker node: replica-side
        # protocol traffic (forwarded writes/reads, acks) runs as its own
        # CPU job ahead of coordinator-side client requests, the way real
        # stores give replication handling its own stage.
        proto = [it for it in batch if it[0] not in _CLIENT_KINDS]
        client = [it for it in batch if it[0] in _CLIENT_KINDS]
        for job in (proto, client):
            if not job:
                continue
            total = 0.0
            seen: set[str] = set()
            for kind, base, marginal, _thunk in job:
                share = marginal + (base if kind not in seen else 0.0)
                seen.add(kind)
                total += share
                self._profile_cpu(kind, share)

            def run_batch(job=job):
                self.ingress_draining = True
                try:
                    for _k, _b, _m, thunk in job:
                        thunk()
                finally:
                    self.ingress_draining = False
                for dst in list(self._mut_batch):
                    self._maybe_flush_muts(dst)

            self.cpu.submit(total, run_batch)

    # -- coordinator-side mutation batching ----------------------------------------
    def _enqueue_mut(self, dst: int, key: str, colname: str, value: Any,
                     ts: float) -> None:
        """Stage a mutation for `dst`; flush policy mirrors the Spinnaker
        leader's adaptive batching (immediate while the CPU queue is empty,
        else accumulate until count/deadline)."""
        self._mut_batch.setdefault(dst, []).append((key, colname, value, ts))
        self._maybe_flush_muts(dst)

    def _maybe_flush_muts(self, dst: int) -> None:
        cfg = self.cfg
        if not self._mut_batch.get(dst):
            return
        if cfg.batch != "adaptive" \
                or len(self._mut_batch[dst]) >= cfg.batch_max_records:
            self._flush_muts(dst)
            return
        if self.ingress_draining:
            # mid ingress-drain: coord_writes still to run in this CPU
            # batch may stage more mutations for dst; run_batch flushes
            # once at the end (mirrors the Spinnaker leader's accumulator)
            return
        if self.cpu.busy_until <= self.sim.now + 1e-12:
            self._flush_muts(dst)
        elif dst not in self._mut_timer:
            self._mut_timer[dst] = self.sim.schedule(
                cfg.batch_deadline, self._flush_muts, dst)

    def _flush_muts(self, dst: int) -> None:
        timer = self._mut_timer.pop(dst, None)
        if timer is not None:
            timer.cancel()
        muts = self._mut_batch.pop(dst, [])
        if not muts or not self.up:
            return
        self.batches_sent += 1
        self.muts_batched += len(muts)
        node = self.cluster.nodes[dst]
        nbytes = 100 + sum(200 + (len(v) if isinstance(v, (bytes, str))
                                  else 16) for _, _, v, _ in muts)
        self.cluster.net.send(self.node_id, dst, node.handle, "replica_write",
                              dict(muts=muts, origin=self.node_id),
                              nbytes=nbytes, component="replica.fwd")

    # -- coordinator logic -----------------------------------------------------------
    def coord_write(self, key: str, colname: str, value: Any, w: int,
                    reply: Callable, trace=None) -> None:
        """Send to all 3 replicas, ack client after `w` durable copies."""
        ts = self.sim.now  # coordinator clock = LWW timestamp
        if trace is not None:
            trace.t_cpu = ts
        members = self.cluster.cohort(self.cluster.range_of(key))
        acks = [0]
        replied = [False]

        def one_ack():
            acks[0] += 1
            if acks[0] >= w and not replied[0]:
                replied[0] = True
                if trace is not None:
                    trace.t_commit = self.sim.now
                reply(Result(ErrorCode.OK, version=0))

        # ack collection from remote replicas (registered before the sends
        # so a same-tick ack cannot race it)
        self._pending_acks.setdefault((key, colname, ts), one_ack)
        for m in members:
            if m == self.node_id:
                self.local_write(key, colname, value, ts, one_ack)
            else:
                self._enqueue_mut(m, key, colname, value, ts)

    _pending_acks: dict = None  # set in __init__ of cluster wiring

    def replica_write(self, muts: list, origin: int) -> None:
        """Apply a coordinator's mutation batch: ONE log force covers every
        mutation (group commit), then one cumulative ack message carrying
        every tag rides back."""
        def done():
            if not self.up:
                return
            tags = []
            for key, colname, value, ts in muts:
                self._apply_local(key, colname, value, ts)
                tags.append((key, colname, ts))
            node = self.cluster.nodes.get(origin)
            if node is None:
                return
            self.cluster.net.send(self.node_id, origin, node.handle, "ack",
                                  dict(tags=tags),
                                  nbytes=64 + 96 * len(tags),
                                  component="replica.ack")
        self.disk.force(4200 * len(muts), done, component="wal.force")

    def ack(self, tags: list) -> None:
        for tag in tags:
            cb = self._pending_acks.get(tag)
            if cb is not None:
                cb()

    def coord_read(self, key: str, colname: str, r: int,
                   reply: Callable) -> None:
        """Read `r` replicas, LWW-resolve, async read repair on conflict."""
        members = list(self.cluster.cohort(self.cluster.range_of(key)))
        # prefer self if replica, then others round-robin
        if self.node_id in members:
            members.remove(self.node_id)
            targets = [self.node_id] + members
        else:
            targets = members
        targets = targets[:r]
        results: list[tuple[int, Optional[_TCell]]] = []

        def collect(nid: int, cell: Optional[_TCell]):
            results.append((nid, cell))
            if len(results) == len(targets):
                cells = [c for _, c in results if c is not None]
                if not cells:
                    reply(Result(ErrorCode.NOT_FOUND))
                    return
                best = max(cells, key=lambda c: c.ts)
                # read repair: push the winning cell to stale replicas
                for nid2, c in results:
                    if c is None or c.ts < best.ts:
                        if nid2 == self.node_id:
                            self.cluster.nodes[nid2].local_write(
                                key, colname, best.value, best.ts,
                                lambda: None)
                        else:
                            self._enqueue_mut(nid2, key, colname,
                                              best.value, best.ts)
                reply(Result(ErrorCode.OK, value=best.value, version=0))

        for t in targets:
            if t == self.node_id:
                collect(t, self.local_read(key, colname))
            else:
                node = self.cluster.nodes[t]

                def remote(t=t, node=node):
                    self.cluster.net.send(
                        self.node_id, t, node.handle, "replica_read",
                        dict(key=key, colname=colname, origin=self.node_id,
                             tag=(key, colname, self.sim.now)), nbytes=300,
                        component="replica.fwd")
                remote()
        self._read_collect[(key, colname)] = collect

    _read_collect: dict = None

    def replica_read(self, key: str, colname: str, origin: int,
                     tag) -> None:
        cell = self.local_read(key, colname)
        node = self.cluster.nodes.get(origin)
        if node is None:
            return
        nbytes = 4300 if cell is not None else 200
        self.cluster.net.send(self.node_id, origin, node.handle, "read_resp",
                              dict(key=key, colname=colname, cell=cell,
                                   frm=self.node_id), nbytes=nbytes,
                              component="replica.ack")

    def read_resp(self, key: str, colname: str, cell: Optional[_TCell],
                  frm: int) -> None:
        cb = self._read_collect.get((key, colname))
        if cb is not None:
            cb(frm, cell)


class CassandraCluster:
    def __init__(self, sim: Simulator, cfg: CassandraConfig | None = None):
        self.sim = sim
        self.cfg = cfg or CassandraConfig()
        self.net = Network(sim, self.cfg.net)
        self.obs = Observability(sim, "cassandra", self.cfg.obs)
        self.nodes: dict[int, CassandraNode] = {}
        self.obs.profiler.attach_network(self.net)
        n = self.cfg.n_nodes
        self.boundaries = [key_of(i * self.cfg.num_keys // n) for i in range(n)]
        for i in range(n):
            node = CassandraNode(self, i, self.cfg)
            node._pending_acks = {}
            node._read_collect = {}
            self.nodes[i] = node
            self.obs.profiler.attach_node(i, node.cpu, node.disk)
            m = self.obs.metrics
            m.add_gauge(i, "cpu_queue_s", node.cpu.queue_delay)
            m.add_gauge(i, "disk_queue", node.disk.queue_depth)
            m.add_gauge(i, "wal_forces", lambda node=node: node.disk.forces)
            m.add_gauge(i, "wal_bytes_forced",
                        lambda node=node: node.disk.bytes_forced)
        self.obs.start()

    def cohort(self, rid: int) -> tuple[int, int, int]:
        n = self.cfg.n_nodes
        return (rid, (rid + 1) % n, (rid + 2) % n)

    def range_of(self, key: str) -> int:
        import bisect
        return max(0, bisect.bisect_right(self.boundaries, key) - 1)

    def crash_node(self, nid: int, lose_disk: bool = False) -> None:
        self.nodes[nid].crash(lose_disk)

    def restart_node(self, nid: int) -> None:
        self.nodes[nid].restart()

    def partition(self, *groups) -> None:
        self.net.set_partition(groups)

    def heal(self) -> None:
        self.net.clear_partition()

    def make_client(self, client_id: str = "cc0") -> "CassandraClient":
        return CassandraClient(self, client_id)


class CassandraClient:
    """Weak/quorum reads and writes; coordinator = a cohort replica."""

    ATTEMPT_TIMEOUT = 1.0
    MAX_RETRIES = 30
    RETRY_DELAY = 0.05

    def __init__(self, cluster: CassandraCluster, client_id: str):
        self.cluster = cluster
        self.sim = cluster.sim
        self.id = client_id
        self.stats = LatencyStats()
        self.stats_by_kind: dict[str, LatencyStats] = {}
        self.op_hook: Optional[Callable[[str, Result], None]] = None
        self._rr = 0
        # workload adapters set this right before issuing an op so traces
        # carry the workload's label instead of the wire kind
        self.next_trace_kind: Optional[str] = None

    def _coordinator(self, key: str) -> int:
        members = self.cluster.cohort(self.cluster.range_of(key))
        self._rr += 1
        return members[self._rr % len(members)]

    def write(self, key: str, colname: str, value: Any, quorum: bool,
              cb: Callable) -> None:
        self._op("coord_write", key,
                 dict(key=key, colname=colname, value=value,
                      w=2 if quorum else 1), cb, t0=self.sim.now, tries=0,
                 nbytes=4300)

    def read(self, key: str, colname: str, quorum: bool,
             cb: Callable) -> None:
        self._op("coord_read", key,
                 dict(key=key, colname=colname, r=2 if quorum else 1), cb,
                 t0=self.sim.now, tries=0, nbytes=300)

    def _op(self, kind: str, key: str, kw: dict, cb: Callable, t0: float,
            tries: int, nbytes: int) -> None:
        path = kind.removeprefix("coord_")
        if tries == 0:
            hint = self.next_trace_kind
            self.next_trace_kind = None
            tr0 = self.cluster.obs.tracer.maybe_start(hint or path, path, key)
            if tr0 is not None:
                kw["_trace"] = tr0      # kw persists across retries
        if tries > self.MAX_RETRIES:
            res = Result(ErrorCode.TIMEOUT, latency=self.sim.now - t0)
            tr = kw.pop("_trace", None)
            if tr is not None:
                self.cluster.obs.tracer.finish(tr, False, "timeout")
            if self.op_hook is not None:
                self.op_hook(path, res)
            cb(res)
            return
        target = self._coordinator(key)
        settled = [False]

        def on_reply(res: Result):
            if settled[0]:
                return
            settled[0] = True
            timeout_ev.cancel()
            res.latency = self.sim.now - t0
            self.stats.add(res.latency)
            self.stats_by_kind.setdefault(path, LatencyStats()).add(
                res.latency)
            tr = kw.pop("_trace", None)
            if tr is not None:
                self.cluster.obs.tracer.finish(
                    tr, res.ok, getattr(res.code, "name", str(res.code)))
            if self.op_hook is not None:
                self.op_hook(path, res)
            cb(res)

        def on_timeout():
            if settled[0]:
                return
            settled[0] = True
            self.sim.schedule(self.RETRY_DELAY, self._op, kind, key, kw, cb,
                              t0, tries + 1, nbytes)

        timeout_ev = self.sim.schedule(self.ATTEMPT_TIMEOUT, on_timeout)

        def reply_via_net(res: Result):
            self.cluster.net.send(target, self.id, on_reply, res,
                                  nbytes=4300, cross_switch=True,
                                  component="client.reply")

        payload = dict(kw)
        payload.pop("_trace", None)
        tr = kw.get("_trace")
        if tr is not None:
            tr.attempts += 1
            tr.t_send = self.sim.now
            payload["trace"] = tr
        payload["reply"] = reply_via_net
        node = self.cluster.nodes[target]
        comp = "client.write" if kind == "coord_write" else "client.read"
        self.cluster.net.send(self.id, target, node.handle, kind, payload,
                              nbytes=nbytes, cross_switch=True,
                              component=comp)

    # sync helpers for tests
    def sync_write(self, key: str, colname: str, value: Any,
                   quorum: bool = True) -> Result:
        box = []
        self.write(key, colname, value, quorum, lambda r: box.append(r))
        guard = 0
        while not box and guard < 1_000_000:
            if not self.sim.step():
                break
            guard += 1
        return box[0]

    def sync_read(self, key: str, colname: str, quorum: bool = True) -> Result:
        box = []
        self.read(key, colname, quorum, lambda r: box.append(r))
        guard = 0
        while not box and guard < 1_000_000:
            if not self.sim.step():
                break
            guard += 1
        return box[0]
