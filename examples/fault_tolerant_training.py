"""Fault-tolerant training end-to-end: the paper's protocol as the
training fleet's state plane.

    PYTHONPATH=src python examples/fault_tolerant_training.py

Storyline:
  1. train with checkpoints committed to the 3-way Paxos-replicated store;
  2. a STORAGE node dies mid-run — commits keep flowing (majority alive);
  3. the TRAINER dies; a replacement restores with a STRONG read and
     resumes bit-exactly (deterministic pipeline + pure step);
  4. a zombie of the old trainer wakes up and tries to commit — the
     conditionalPut manifest fence kills it (split-brain protection);
  5. a host is lost from the training fleet — the controller fences the
     generation and re-plans the mesh (elastic scaling).
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (SpinnakerCheckpointStore,
                                    StaleTrainerError, StoreConfig)
from repro.core.coordination import Coordination
from repro.core.sim import Simulator
from repro.data.pipeline import DataConfig, TokenStream
from repro.ft.manager import (FTConfig, HostAgent, TrainingController,
                              plan_mesh)
from repro.models.config import ModelConfig
from repro.train.optim import OptimizerConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step


def main():
    cfg = ModelConfig(name="ft-demo", family="dense", num_layers=4,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=512,
                      vocab_size=2048, dtype="float32", remat=False)
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8,
                      seed=0)
    stream = TokenStream(dcfg, 0)
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    def run(state, start, n):
        losses = []
        for s in range(start, start + n):
            batch = {k: jnp.asarray(v)
                     for k, v in stream.batch_at(s).items()}
            state, m = step_fn(state, batch)
            losses.append(float(m["loss"]))
        return state, losses

    store = SpinnakerCheckpointStore(StoreConfig())
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)

    # 1. train + commit
    state, l1 = run(state, 0, 10)
    store.save(10, jax.tree.map(np.asarray, state))
    print(f"[1] 10 steps, loss {l1[0]:.3f} -> {l1[-1]:.3f}; checkpoint "
          f"committed (quorum)")

    # 2. storage node dies; commits keep flowing
    store.crash_storage_node(2)
    store.sim.run_for(3.0)
    state, l2 = run(state, 10, 5)
    store.save(15, jax.tree.map(np.asarray, state))
    print(f"[2] storage node 2 down — checkpoint @15 still committed "
          f"(majority quorum alive)")

    # 3. trainer dies; replacement restores with a STRONG read
    reference_state, lref = run(state, 15, 5)   # what the run should produce
    del state
    fresh = init_train_state(jax.random.PRNGKey(99), cfg, tcfg)
    step0, restored = store.restore_tree(fresh)
    restored = jax.tree.map(jnp.asarray, restored)
    resumed, l3 = run(restored, step0, 5)
    same = all(abs(a - b) < 1e-6 for a, b in zip(l3, lref))
    print(f"[3] trainer replaced: restored step {step0} via strong read; "
          f"5 resumed steps bit-match reference: {same}")
    assert same

    # 4. zombie trainer is fenced by the conditionalPut
    zombie = SpinnakerCheckpointStore.__new__(SpinnakerCheckpointStore)
    zombie.__dict__.update(store.__dict__)
    zombie._manifest_version = 1                  # stale view of the run
    try:
        zombie.save(11, jax.tree.map(np.asarray, resumed))
        print("[4] ZOMBIE COMMITTED — fence failed!")
    except StaleTrainerError as e:
        print(f"[4] zombie trainer fenced out by conditionalPut: {e}")

    # 5. elastic re-mesh on host loss
    sim = Simulator(seed=1)
    zk = Coordination(sim, session_timeout=1.0)
    ftc = FTConfig(session_timeout=1.0, heartbeat_interval=0.25)
    plans = []
    ctrl = TrainingController(sim, zk, "run0", ftc,
                              on_replan=lambda h, g: plans.append((h, g)))
    agents = [HostAgent(sim, zk, "run0", i, ftc) for i in range(64)]
    sim.run_for(0.5)
    ctrl.bootstrap()
    d, m = plan_mesh(len(plans[-1][0]), chips_per_host=4)
    print(f"[5] fleet up: {len(plans[-1][0])} hosts -> mesh (data={d}, "
          f"model={m}), generation {plans[-1][1]}")
    agents[13].crash()
    sim.run_for(3.0)
    d, m = plan_mesh(len(plans[-1][0]), chips_per_host=4)
    print(f"    host 13 lost -> generation {plans[-1][1]}, re-planned mesh "
          f"(data={d}, model={m}); old generation fenced: "
          f"{agents[0].fenced()}")


if __name__ == "__main__":
    main()
