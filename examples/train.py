"""End-to-end training driver.

    PYTHONPATH=src python examples/train.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train.py --preset 25m --steps 50   # CI

Trains a llama-family model on the deterministic mixture pipeline with
AdamW, periodically committing checkpoints to the Spinnaker-replicated
store (quorum writes + conditionalPut manifest fence).  Loss curve and
throughput are written to results/train_<preset>.json.
"""

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint.store import SpinnakerCheckpointStore, StoreConfig
from repro.data.pipeline import DataConfig, TokenStream
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.train.optim import OptimizerConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step

PRESETS = {
    # ~name: layers, d_model, heads, kv, d_ff, vocab, batch, seq
    "5m": dict(num_layers=4, d_model=128, heads=4, kv=2, d_ff=512,
               vocab=2048, batch=8, seq=128),
    "25m": dict(num_layers=8, d_model=384, heads=6, kv=2, d_ff=1024,
                vocab=8192, batch=4, seq=256),
    "100m": dict(num_layers=12, d_model=768, heads=12, kv=4, d_ff=2048,
                 vocab=16384, batch=4, seq=256),
}


def make_config(p) -> ModelConfig:
    return ModelConfig(
        name="train-example", family="dense", num_layers=p["num_layers"],
        d_model=p["d_model"], num_heads=p["heads"], num_kv_heads=p["kv"],
        d_ff=p["d_ff"], vocab_size=p["vocab"], activation="swiglu",
        dtype="float32", remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="25m", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = make_config(p)
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=args.lr))
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    n_params = sum(x.size for x in jax.tree.leaves(state["params"]))
    print(f"model: {n_params/1e6:.1f}M params ({args.preset})")

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=p["seq"],
                      global_batch=p["batch"], seed=0)
    stream = TokenStream(dcfg, 0)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    store = SpinnakerCheckpointStore(StoreConfig(chunk_bytes=4 << 20))

    losses = []
    t0 = time.time()
    tokens_done = 0
    for s in range(args.steps):
        raw = stream.batch_at(s)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        tokens_done += p["batch"] * p["seq"]
        if s % 10 == 0 or s == args.steps - 1:
            dt = time.time() - t0
            print(f"step {s:4d}  loss {loss:.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}  "
                  f"{tokens_done/max(dt,1e-9):.0f} tok/s", flush=True)
        if args.ckpt_every and (s + 1) % args.ckpt_every == 0:
            import numpy as np
            store.save(s + 1, jax.tree.map(np.asarray, state))
            print(f"  checkpoint @ step {s+1} committed to replicated "
                  f"store (quorum + manifest fence)", flush=True)

    assert losses[-1] < losses[0], "loss did not decrease"
    out = Path("results")
    out.mkdir(exist_ok=True)
    (out / f"train_{args.preset}.json").write_text(json.dumps({
        "preset": args.preset, "params": n_params, "steps": args.steps,
        "loss_first": losses[0], "loss_last": losses[-1],
        "losses_every10": losses[::10],
        "wall_s": time.time() - t0,
        "tok_per_s": tokens_done / (time.time() - t0),
    }, indent=2))
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
