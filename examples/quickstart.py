"""Quickstart: the Spinnaker datastore API end-to-end on the simulator.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's API (§3): put/get with strong vs timeline consistency,
conditionalPut optimistic concurrency, then a leader failure with
sub-second failover (§D.1) and a strong read that proves no committed
write was lost.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (ClusterConfig, ErrorCode, Simulator, SpinnakerCluster,
                        key_of)


def main():
    sim = Simulator(seed=0)
    cluster = SpinnakerCluster(sim, ClusterConfig(n_nodes=5))
    cluster.start()
    cluster.settle()
    print(f"cluster up: 5 nodes, 5 key ranges, 3-way cohorts "
          f"(chained declustering), leaders elected in "
          f"{sim.now * 1e3:.1f} ms sim-time")

    c = cluster.make_client()
    key = key_of(1234)

    # --- basic put/get -----------------------------------------------------
    res = c.sync_put(key, "name", b"spinnaker")
    print(f"put:               ok v{res.version} "
          f"({res.latency * 1e3:.2f} ms)")
    res = c.sync_get(key, "name", consistent=True)
    print(f"strong get:        {res.value!r} v{res.version} "
          f"({res.latency * 1e3:.2f} ms)")
    res = c.sync_get(key, "name", consistent=False)
    print(f"timeline get:      {res.value!r} "
          f"({res.latency * 1e3:.2f} ms — any replica, may be stale)")

    # --- optimistic concurrency (§3's counter idiom) -------------------------
    c.sync_put(key, "count", 0)
    cur = c.sync_get(key, "count")
    res = c.sync_cond_put(key, "count", cur.value + 1, cur.version)
    print(f"conditionalPut:    ok -> count=1 v{res.version}")
    stale = c.sync_cond_put(key, "count", 99, cur.version)
    print(f"stale condPut:     {stale.code.value} (as it should be)")

    # --- leader failure + failover -------------------------------------------
    rid = cluster.range_of(key)
    leader = cluster.leader_replica(rid)
    print(f"\ncrashing leader n{leader.node.node_id} of range {rid} ...")
    t0 = sim.now
    cluster.crash_node(leader.node.node_id, expire_session=True)
    while cluster.leader_replica(rid) is None:
        sim.run(until=sim.now + 0.001)
    print(f"new leader n{cluster.leader_replica(rid).node.node_id} open "
          f"for writes after {(sim.now - t0) * 1e3:.0f} ms")

    res = c.sync_get(key, "count", consistent=True)
    assert res.value == 1, "committed write lost!"
    print(f"strong get after failover: count={res.value} — no committed "
          f"write lost")
    res = c.sync_put(key, "count", 2)
    print(f"writes accepted again: v{res.version}")


if __name__ == "__main__":
    main()
