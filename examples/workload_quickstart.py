"""Workload-engine quickstart: drive a 5-node Spinnaker cluster with a
YCSB-style zipfian mix while a fault schedule kills and revives the
leader, then print the availability timeline.

    PYTHONPATH=src python examples/workload_quickstart.py
"""

from repro.workload import (ExperimentConfig, WorkloadSpec,
                            run_spinnaker_workload)

SCENARIO = """
# one-file failure scenario: the DSL resolves 'leader of 0' at fire time
at 2.0s  crash leader of 0
at 4.0s  partition {0,1} | {2,3,4}
at 5.5s  heal
at 6.0s  restart crashed
"""


def main() -> None:
    spec = WorkloadSpec(num_keys=500, key_dist="zipfian",
                        read_frac=0.6, write_frac=0.4,
                        rmw_frac=0.0, cond_frac=0.0, value_size=1024)
    cfg = ExperimentConfig(n_nodes=5, disk="ssd", n_clients=8,
                           warmup=0.5, duration=9.0, window=0.5,
                           preload_cap=500)
    r = run_spinnaker_workload(spec, cfg, schedule=SCENARIO)

    print("fault events applied:")
    for e in r["fault_events"]:
        print("  ", e)
    print(f"\nreads : p50={r['reads']['p50_ms']:.2f}ms "
          f"p99={r['reads']['p99_ms']:.2f}ms  ({r['reads']['count']} ops)")
    print(f"writes: p50={r['writes']['p50_ms']:.2f}ms "
          f"p99={r['writes']['p99_ms']:.2f}ms  ({r['writes']['count']} ops)")
    print("\nwrite availability timeline (0.5s windows):")
    for w in r["timeline"]["write"]:
        bar = "#" * int(w["throughput"] / 100)
        print(f"  t={w['t_start']:5.1f}s  {w['throughput']:7.0f}/s  {bar}")


if __name__ == "__main__":
    main()
