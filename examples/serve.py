"""Batched serving with timeline-consistent weight refresh.

    PYTHONPATH=src python examples/serve.py

Brings up the continuous-batching engine on a small model, serves a
burst of requests, then demonstrates the paper's consistency menu
applied to serving: a trainer commits new weights to the Spinnaker store
(quorum write + manifest fence) and the engine picks them up with a
*timeline* read — never blocking the training commit path.
"""

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.checkpoint.store import SpinnakerCheckpointStore, StoreConfig
from repro.configs import smoke_config
from repro.models import init_params
from repro.serve.engine import Request, ServeConfig, ServingEngine


def main():
    cfg = smoke_config("smollm-360m").scaled(remat=False, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    store = SpinnakerCheckpointStore(StoreConfig())
    store.save(1, jax.tree.map(np.asarray, params))

    eng = ServingEngine(cfg, params, ServeConfig(slots=4, max_seq=96,
                                                 refresh_every_batches=8),
                        store=store)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(12):
        prompt = rng.integers(2, cfg.vocab_size, rng.integers(3, 9)).tolist()
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=12))
    eng.run_until_drained()
    print(f"served 12 requests on 4 slots in {eng.batches_run} lockstep "
          f"batches ({time.time()-t0:.1f}s wall)")
    for rid in sorted(eng.finished)[:4]:
        print(f"  req {rid}: {eng.finished[rid].output}")

    # --- trainer commits new weights; engine refreshes via timeline read ----
    new_params = init_params(jax.random.PRNGKey(7), cfg)
    store.save(2, jax.tree.map(np.asarray, new_params))
    store.sim.run_for(2.0)   # commit period elapses; followers catch up
    refreshed = eng.maybe_refresh_weights()
    print(f"weight refresh via timeline read: step {eng.weights_step} "
          f"(refreshed={refreshed})")
    eng.submit(Request(rid=99, prompt=[5, 6, 7], max_new_tokens=8))
    eng.run_until_drained()
    print(f"req 99 on refreshed weights: {eng.finished[99].output}")


if __name__ == "__main__":
    main()
