"""Kernel correctness: pallas_call (interpret mode on CPU) vs pure-jnp
oracles, swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention.kernel import decode_attention_bhd
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_chunked_jnp, ssd_sequential

RNG = np.random.default_rng(42)


def randn(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_SHAPES = [
    # (B, H, Hkv, Sq, Sk, hd, bq, bk, causal, window)
    (1, 4, 4, 64, 64, 32, 16, 16, True, 0),       # MHA causal
    (2, 8, 2, 96, 96, 64, 32, 32, True, 0),       # GQA, non-pow2 grid
    (1, 4, 1, 128, 128, 32, 64, 32, True, 0),     # MQA, asymmetric blocks
    (1, 2, 2, 80, 80, 32, 32, 32, True, 0),       # ragged tail (padding)
    (1, 4, 2, 64, 64, 32, 16, 16, True, 24),      # sliding window
    (1, 2, 2, 48, 48, 16, 16, 16, False, 0),      # bidirectional
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FA_SHAPES)
def test_flash_attention_matches_ref(case, dtype):
    B, H, Hkv, Sq, Sk, hd, bq, bk, causal, window = case
    q = randn((B, H, Sq, hd), dtype)
    k = randn((B, Hkv, Sk, hd), dtype)
    v = randn((B, Hkv, Sk, hd), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_flash_attention_grad_path_not_needed_but_vjp_of_xla_matches():
    """The training path uses the XLA branch; sanity-check the oracle is
    differentiable (kernels are forward-only by design)."""
    q = randn((1, 2, 32, 16), jnp.float32)
    k = randn((1, 2, 32, 16), jnp.float32)
    v = randn((1, 2, 32, 16), jnp.float32)
    g = jax.grad(lambda q: attention_ref(q, k, v).sum())(q)
    assert bool(jnp.all(jnp.isfinite(g)))


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DA_SHAPES = [
    # (B, H, Hkv, T, hd, bk, length, window)
    (2, 4, 4, 128, 32, 32, 100, 0),
    (1, 8, 2, 256, 64, 64, 256, 0),
    (2, 4, 1, 64, 32, 16, 1, 0),          # first decode step
    (1, 4, 4, 160, 32, 64, 130, 0),        # padded tail
    (1, 4, 2, 256, 32, 64, 200, 96),       # sliding window
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", DA_SHAPES)
def test_decode_attention_matches_ref(case, dtype):
    B, H, Hkv, T, hd, bk, length, window = case
    q = randn((B, H, hd), dtype)
    k = randn((B, Hkv, T, hd), dtype)
    v = randn((B, Hkv, T, hd), dtype)
    out = decode_attention_bhd(q, k, v, jnp.int32(length), window=window,
                               block_k=bk, interpret=True)
    ref = decode_attention_ref(q, k, v, length, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    # (b, s, h, p, n, chunk, head_block)
    (1, 64, 4, 16, 16, 16, 4),
    (2, 128, 8, 32, 32, 32, 4),
    (1, 96, 2, 16, 64, 32, 2),
    (1, 64, 8, 64, 16, 64, 8),     # single chunk boundary case
]


def _ssd_inputs(b, s, h, p, n, dtype):
    x = randn((b, s, h, p), dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = randn((b, s, 1, n), dtype)
    C = randn((b, s, 1, n), dtype)
    return x, dt, A, B, C


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("case", SSD_SHAPES)
def test_ssd_chunked_jnp_matches_sequential(case, dtype):
    b, s, h, p, n, chunk, hb = case
    x, dt, A, B, C = _ssd_inputs(b, s, h, p, n, dtype)
    y_seq, state_seq = ssd_sequential(x, dt, A, B, C)
    y_chk, state_chk = ssd_chunked_jnp(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_chk, np.float32),
                               np.asarray(state_seq, np.float32),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", SSD_SHAPES)
def test_ssd_kernel_matches_sequential(case, dtype):
    b, s, h, p, n, chunk, hb = case
    x, dt, A, B, C = _ssd_inputs(b, s, h, p, n, dtype)
    y_seq, _ = ssd_sequential(x, dt, A, B, C)
    y_ker = ssd_scan(x, dt, A, B[:, :, 0, :], C[:, :, 0, :], chunk=chunk,
                     head_block=hb, interpret=True)
    tol = dict(rtol=1e-4, atol=1e-4) if dtype == jnp.float32 \
        else dict(rtol=3e-2, atol=3e-2)
    np.testing.assert_allclose(np.asarray(y_ker, np.float32),
                               np.asarray(y_seq, np.float32), **tol)
