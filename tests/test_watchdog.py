"""Protocol flight recorder (PR 9): journal, invariant watchdog, and the
offline explainer.

The load-bearing invariants:

- the journal is pure measurement — a journaled + watchdog-monitored
  run is op-for-op identical to one with the flight recorder off;
- each consensus invariant trips on a hand-built journal fragment that
  violates it and stays silent on the lawful variant;
- the watchdog is silent across seeded gray-failure chaos schedules
  (zero false positives under crashes, partitions, flaps, gray links);
- the mutation corpus — three known-fixed protocol bugs re-introduced
  behind test-only switches — is pinpointed at the violating journal
  transition, with the fixed-protocol control runs silent;
- the offline explainer reconstructs regimes from a JSONL dump and
  matches the named anomaly signatures.
"""

import dataclasses
import sys
from pathlib import Path

import pytest

from repro.chaos.mutations import MUTATIONS, run_corpus, run_mutation
from repro.obs.journal import ProtocolJournal
from repro.obs.watchdog import InvariantWatchdog
from repro.workload import (ExperimentConfig, WorkloadSpec,
                            run_spinnaker_chaos, run_spinnaker_workload)

sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                       / "benchmarks"))
import explain  # noqa: E402


def E(t, kind, node, **kw):
    return {"t": t, "kind": kind, "node": node, **kw}


def replay(entries):
    return InvariantWatchdog.replay(entries)


def invariants(entries):
    return [v["invariant"] for v in replay(entries).violations]


# -- journal substrate -------------------------------------------------------


class _Sim:
    now = 0.0


def test_journal_record_export_window_roundtrip():
    sim = _Sim()
    j = ProtocolJournal(sim)
    for i in range(5):
        sim.now = float(i)
        j.record("flush", node=i % 2, rid=i % 3, epoch=1, lsn=10 + i)
    assert len(j.entries) == 5
    # export shifts times and filters
    ex = j.export(t0=2.0, rid=2)
    assert all(e["rid"] == 2 for e in ex)
    assert ex[0]["t"] == 0.0           # shifted relative to t0
    # window keeps absolute times
    win = j.window(1.0, 3.0)
    assert [e["t"] for e in win] == [1.0, 2.0, 3.0]
    # JSONL round-trips
    back = ProtocolJournal.load_jsonl(j.to_jsonl())
    assert len(back) == 5
    assert back[0]["kind"] == "flush" and back[4]["lsn"] == 14


def test_journal_cap_drops_storage_not_listeners():
    sim = _Sim()
    j = ProtocolJournal(sim, cap=3)
    seen = []
    j.listeners.append(seen.append)
    for i in range(5):
        j.record("flush", node=0, rid=0, lsn=i)
    assert len(j.entries) == 3 and j.dropped == 2
    assert len(seen) == 5              # the watchdog never goes blind


def test_journal_window_summary_counts_and_notables():
    sim = _Sim()
    j = ProtocolJournal(sim)
    sim.now = 1.0
    j.record("ack", node=1, rid=0, lsn=5)
    j.record("takeover", node=2, rid=0, epoch=3)
    s = j.window_summary(0.0, 2.0, rid=0)
    assert s["n_entries"] == 2
    assert s["by_kind"] == {"ack": 1, "takeover": 1}
    assert [e["kind"] for e in s["notable"]] == ["takeover"]


# -- per-invariant unit tests (hand-built fragments) -------------------------


def test_single_leader_per_epoch():
    ok = [E(0.0, "takeover", 1, rid=0, epoch=5, cmt=0, lst=0, missing=0,
            n_cohort=3),
          E(1.0, "takeover", 2, rid=0, epoch=6, cmt=0, lst=0, missing=0,
            n_cohort=3)]
    assert invariants(ok) == []
    bad = ok[:1] + [E(0.1, "takeover", 2, rid=0, epoch=5, cmt=0, lst=0,
                      missing=0, n_cohort=3)]
    assert invariants(bad) == ["single_leader_per_epoch"]


def test_takeover_completeness_flags_missing_records():
    bad = [E(0.0, "takeover", 1, rid=0, epoch=2, cmt=4, lst=9,
             unresolved=3, missing=2, n_cohort=3)]
    wd = replay(bad)
    assert invariants(bad) == ["takeover_completeness"]
    assert "missing 2 durable" in wd.violations[0]["detail"]
    ok = [dict(bad[0], missing=0)]
    assert invariants(ok) == []


def test_lease_disjoint_overlap_and_lawful_renewal():
    base = [E(0.0, "takeover", 1, rid=0, epoch=1, n_cohort=3),
            E(0.0, "lease_acquire", 1, rid=0, epoch=1, until=1.0)]
    # same holder extending its own lease is lawful
    assert invariants(base + [E(0.5, "lease_acquire", 1, rid=0, epoch=1,
                                until=1.5)]) == []
    # another node acquiring inside the live window is the precursor
    bad = base + [E(0.5, "lease_acquire", 2, rid=0, epoch=2, until=1.4)]
    assert invariants(bad) == ["lease_disjoint"]
    # ...unless the old holder's window lapsed first
    ok = base + [E(1.2, "lease_lapse", 1, rid=0, epoch=1),
                 E(1.3, "lease_acquire", 2, rid=0, epoch=2, until=2.3)]
    assert invariants(ok) == []


def test_lease_disjoint_session_fence_exemption():
    # a flapped leader's stale-epoch renewal racing the successor's
    # takeover is handoff noise, not a split-brain claim
    frag = [E(0.0, "takeover", 1, rid=0, epoch=1, n_cohort=3),
            E(0.0, "lease_acquire", 1, rid=0, epoch=1, until=1.0),
            E(0.4, "session_flap", 1, outage=0.5),
            E(0.5, "takeover", 2, rid=0, epoch=2, n_cohort=3),
            E(0.5, "lease_acquire", 2, rid=0, epoch=2, until=1.5,
              grace=True),
            E(0.50003, "lease_acquire", 1, rid=0, epoch=1, until=1.45)]
    assert invariants(frag) == []


def test_quorum_intersection_minority_election_and_short_log_winner():
    minority = [E(0.0, "elect_decide", 1, rid=0, epoch=2, round=1,
                  candidates=[1], winner=1, winner_lst=5, max_lst=5,
                  n_cohort=3)]
    assert invariants(minority) == ["quorum_intersection"]
    short = [E(0.0, "elect_decide", 1, rid=0, epoch=2, round=1,
               candidates=[1, 2], winner=1, winner_lst=3, max_lst=9,
               n_cohort=3)]
    assert invariants(short) == ["quorum_intersection"]
    ok = [E(0.0, "elect_decide", 1, rid=0, epoch=2, round=1,
            candidates=[1, 2], winner=1, winner_lst=9, max_lst=9,
            n_cohort=3)]
    assert invariants(ok) == []


def test_acked_durable_requires_local_evidence():
    ok = [E(0.0, "flush", 2, rid=0, epoch=1, lsn=10),
          E(0.1, "ack", 2, rid=0, epoch=1, lsn=10)]
    assert invariants(ok) == []
    bad = ok + [E(0.2, "ack", 2, rid=0, epoch=1, lsn=20)]
    assert invariants(bad) == ["acked_durable"]
    # an applied commit index is evidence too (dup re-ack after cmt)
    cmt = [E(0.0, "commit_idx", 2, rid=0, epoch=1, lsn=30),
           E(0.1, "ack", 2, rid=0, epoch=1, lsn=30)]
    assert invariants(cmt) == []


def test_acked_committed_majority():
    both = [E(0.0, "flush", 1, rid=0, epoch=1, lsn=10),
            E(0.0, "flush", 2, rid=0, epoch=1, lsn=10),
            E(0.1, "commit", 1, rid=0, epoch=1, lsn=10, n_cohort=3)]
    assert invariants(both) == []
    solo = [E(0.0, "flush", 1, rid=0, epoch=1, lsn=10),
            E(0.1, "commit", 1, rid=0, epoch=1, lsn=10, n_cohort=3)]
    assert invariants(solo) == ["acked_committed_majority"]


def test_commit_monotonic_allows_crash_rewind():
    bad = [E(0.0, "commit_idx", 1, rid=0, epoch=1, lsn=10),
           E(0.1, "commit_idx", 1, rid=0, epoch=1, lsn=5)]
    assert invariants(bad) == ["commit_monotonic"]
    crash = [E(0.0, "commit_idx", 1, rid=0, epoch=1, lsn=10),
             E(0.1, "node_crash", 1),
             E(0.2, "commit_idx", 1, rid=0, epoch=1, lsn=5)]
    assert invariants(crash) == []


def test_log_matching_digest_divergence():
    ok = [E(0.0, "append", 1, rid=0, epoch=1, lsn=7, digest=111),
          E(0.1, "append", 2, rid=0, epoch=1, lsn=7, digest=111)]
    assert invariants(ok) == []
    bad = ok + [E(0.2, "append", 3, rid=0, epoch=1, lsn=7, digest=222)]
    assert invariants(bad) == ["log_matching"]


def test_txn_decision_stable():
    ok = [E(0.0, "txn_decide", 1, rid=0, txid="x1", outcome="commit"),
          E(0.1, "txn_resolve", 2, rid=1, txid="x1", outcome="commit")]
    assert invariants(ok) == []
    bad = ok + [E(0.2, "txn_resolve", 3, rid=2, txid="x1",
                  outcome="abort")]
    assert invariants(bad) == ["txn_decision_stable"]


def test_gc_floor_safe_vs_unresolved_prepares():
    prep = [E(0.0, "txn_prepared", 1, rid=0, epoch=1, lsn=5, txid="x1")]
    assert invariants(prep + [E(0.1, "gc_floor_pin", 1, rid=0,
                                lsn=7)]) == ["gc_floor_safe"]
    assert invariants(prep + [E(0.1, "txn_unpin", 1, rid=0,
                                epoch=1)]) == ["gc_floor_safe"]
    resolved = prep + [E(0.1, "txn_resolve", 1, rid=0, txid="x1",
                         outcome="commit"),
                       E(0.2, "gc_floor_pin", 1, rid=0, lsn=7)]
    assert invariants(resolved) == []


def test_catchup_progress_starvation_vs_active_retry():
    def frag(retry_at=None):
        es = [E(0.0, "catchup_enter", 2, rid=0, epoch=1, leader=1)]
        if retry_at is not None:
            es.append(E(retry_at, "catchup_retry", 2, rid=0, epoch=1))
        es += [E(1.0, "lease_heard", 2, rid=0, epoch=1, role="CATCHUP"),
               E(2.0, "lease_heard", 2, rid=0, epoch=1, role="CATCHUP"),
               E(3.1, "lease_heard", 2, rid=0, epoch=1, role="CATCHUP")]
        return es
    assert invariants(frag()) == ["catchup_progress"]
    assert invariants(frag(retry_at=2.5)) == []
    # a FOLLOWER hearing beats is not in catch-up at all
    follower = [E(1.0, "lease_heard", 2, rid=0, epoch=1,
                  role="FOLLOWER")] * 5
    assert invariants(follower) == []


def test_violation_shape_and_dedup():
    bad = [E(0.0, "flush", 2, rid=0, epoch=1, lsn=10)] + \
        [E(0.1 * i, "ack", 2, rid=0, epoch=1, lsn=20 + i)
         for i in range(1, 5)]
    wd = replay(bad)
    assert len(wd.violations) == 1      # dedup per (rid, node) ack site
    v = wd.violations[0]
    for key in ("t", "invariant", "rid", "node", "kind", "detail",
                "window"):
        assert key in v
    s = wd.summary()
    assert not s["ok"] and s["by_invariant"] == {"acked_durable": 1}


# -- bit-identity: the flight recorder is pure measurement -------------------


@pytest.mark.filterwarnings("ignore::UserWarning")
def test_journaled_run_bit_identical_to_unjournaled():
    spec = WorkloadSpec(num_keys=100, value_size=256, read_frac=0.5,
                        write_frac=0.5, rmw_frac=0, cond_frac=0)
    cfg = ExperimentConfig(n_nodes=5, disk="mem", n_clients=4, warmup=0.5,
                           duration=2.0, preload_cap=100)
    on = run_spinnaker_workload(spec, cfg, consistent_reads=True)
    off = run_spinnaker_workload(spec, dataclasses.replace(cfg,
                                                           journal=False),
                                 consistent_reads=True)
    assert on["total_ops"] == off["total_ops"]
    for kind in ("reads", "writes"):
        assert on[kind]["count"] == off[kind]["count"]
        assert on[kind]["p50_ms"] == off[kind]["p50_ms"]
        assert on[kind]["p99_ms"] == off[kind]["p99_ms"]


# -- chaos silence: zero false positives -------------------------------------


@pytest.mark.filterwarnings("ignore::UserWarning")
def test_watchdog_silent_on_chaos_schedule():
    r = run_spinnaker_chaos(seed=0, duration=6.0)
    wd = r["watchdog"]
    assert wd["ok"], wd["violations"][:3]
    assert wd["entries_checked"] > 10_000
    assert r["ok"]                      # watchdog is part of the chaos gate


@pytest.mark.slow
@pytest.mark.filterwarnings("ignore::UserWarning")
def test_watchdog_silent_on_all_chaos_seeds():
    for seed in range(8):
        r = run_spinnaker_chaos(seed=seed, duration=12.0)
        wd = r["watchdog"]
        assert wd["ok"], (seed, wd["violations"][:3])


# -- mutation corpus: detection at the violating transition ------------------


@pytest.mark.filterwarnings("ignore::UserWarning")
def test_mutation_corpus_detects_all_bugs_with_silent_controls():
    corpus = run_corpus()
    assert corpus["ok"], corpus
    assert set(corpus["mutations"]) == set(MUTATIONS)
    for name, m in corpus["mutations"].items():
        assert m["detected"], name
        at = m["detected_at"]
        assert at["invariant"] == MUTATIONS[name]["invariant"], (name, at)
        assert at["kind"] == MUTATIONS[name]["at_kind"], (name, at)
        assert m["control_silent"], (name, m["control_by_invariant"])


# -- offline explainer -------------------------------------------------------


@pytest.fixture(scope="module")
def wedge_journal():
    r = run_mutation("takeover_wedge", mutated=True, export_journal=True)
    assert r["detected"]
    return ProtocolJournal.load_jsonl(r["journal_jsonl"])


@pytest.fixture(scope="module")
def wedge_control_journal():
    r = run_mutation("takeover_wedge", mutated=False, export_journal=True)
    return ProtocolJournal.load_jsonl(r["journal_jsonl"])


def test_explainer_reconstructs_wedged_regime(wedge_journal):
    regs = explain.regimes(wedge_journal, 0)
    assert len(regs) >= 3
    last = regs[-1]
    assert last["missing"] > 0          # the incomplete takeover
    assert last["t_open"] is None       # ...that never reopened
    # earlier regimes carry election context from elect_decide
    assert any(r["election"] for r in regs)


def test_explainer_signature_takeover_wedge(wedge_journal,
                                            wedge_control_journal):
    sigs = explain.scan_signatures(wedge_journal)
    hits = [f for f in sigs["takeover_wedge"] if f["severity"] == "bug"]
    assert hits and hits[0]["rid"] == 0
    clean = explain.scan_signatures(wedge_control_journal)
    assert not [f for f in clean["takeover_wedge"]
                if f["severity"] == "bug"]


def test_explainer_signature_catchup_starvation():
    r = run_mutation("catchup_starvation", mutated=True,
                     export_journal=True)
    entries = ProtocolJournal.load_jsonl(r["journal_jsonl"])
    hits = explain.sig_catchup_starvation(entries)
    assert hits and all(f["severity"] == "bug" for f in hits)
    fixed = run_mutation("catchup_starvation", mutated=False,
                         export_journal=True)
    assert not explain.sig_catchup_starvation(
        ProtocolJournal.load_jsonl(fixed["journal_jsonl"]))


def test_explainer_signature_split_brain_precursor():
    overlap = [E(0.0, "lease_acquire", 1, rid=0, epoch=3, until=2.0),
               E(0.5, "lease_acquire", 2, rid=0, epoch=3, until=2.5)]
    hits = explain.sig_split_brain_precursor(overlap)
    assert hits and hits[0]["severity"] == "precursor"
    # a strictly newer epoch overlapping the old one is the bounded
    # takeover handoff — classified benign, not a precursor
    handoff = [E(0.0, "lease_acquire", 1, rid=0, epoch=3, until=2.0),
               E(0.5, "lease_acquire", 2, rid=0, epoch=4, until=2.5)]
    hand = explain.sig_split_brain_precursor(handoff)
    assert hand and hand[0]["severity"] == "benign-handoff"
    # no overlap, no finding
    clean = [E(0.0, "lease_acquire", 1, rid=0, epoch=3, until=0.4),
             E(0.5, "lease_acquire", 2, rid=0, epoch=4, until=1.5)]
    assert not explain.sig_split_brain_precursor(clean)


def test_explainer_stall_and_narrative(wedge_journal):
    stall = "\n".join(explain.explain_stall(wedge_journal, 0, 3.0, 9.0))
    assert "NO LEADER OPEN" in stall
    text = explain.narrate(wedge_journal, rid=0)
    assert "TAKEOVER INCOMPLETE" in text
    assert "takeover_wedge" in text
    assert "takeover_completeness" in text   # the watchdog replay section


def test_explainer_watchdog_replay_matches_online(wedge_journal,
                                                  wedge_control_journal):
    rep = explain.analyze(wedge_journal)
    assert not rep["watchdog"]["ok"]
    assert rep["watchdog"]["by_invariant"].get("takeover_completeness")
    clean = explain.analyze(wedge_control_journal)
    assert clean["watchdog"]["ok"], clean["watchdog"]["violations"][:3]
