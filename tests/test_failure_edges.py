"""Failure-edge tests: optimistic concurrency across a leader failover,
and storage-node crash in the middle of a checkpoint commit."""

import jax
import numpy as np
import pytest

from repro.checkpoint.store import (CheckpointError, SpinnakerCheckpointStore,
                                    StoreConfig)
from repro.core import (ClusterConfig, ErrorCode, NodeConfig, ReplicaConfig,
                        Simulator, SpinnakerCluster, key_of)


def make_cluster(n=3, seed=0):
    sim = Simulator(seed=seed)
    cluster = SpinnakerCluster(sim, ClusterConfig(
        n_nodes=n, node=NodeConfig(replica=ReplicaConfig(commit_period=0.5))))
    cluster.start()
    cluster.settle()
    return sim, cluster


def test_conditional_put_counter_exact_across_failover():
    """Concurrent CAS increments with a leader crash in the middle: the
    final counter must equal exactly the number of SUCCESSFUL CAS acks
    (no lost or duplicated increments — §3's transactional counter)."""
    sim, cluster = make_cluster(seed=3)
    c1 = cluster.make_client("c1")
    c2 = cluster.make_client("c2")
    key = key_of(7)
    c1.sync_put(key, "n", 0)

    successes = [0]
    inflight = [0]

    def attempt(client, rounds_left):
        if rounds_left == 0:
            return
        inflight[0] += 1

        def on_get(res):
            if not res.ok:
                inflight[0] -= 1
                return

            def on_cas(r2):
                inflight[0] -= 1
                if r2.ok:
                    successes[0] += 1
                attempt(client, rounds_left - 1)

            client.conditional_put(key, "n", res.value + 1, res.version,
                                   on_cas)

        client.get(key, "n", True, on_get)

    attempt(c1, 6)
    attempt(c2, 6)
    sim.run_for(1.5)
    # kill the leader mid-burst
    rid = cluster.range_of(key)
    leader = cluster.leader_replica(rid)
    if leader is not None:
        cluster.crash_node(leader.node.node_id)
    sim.run_for(10.0)
    cluster.restart_node(leader.node.node_id)
    sim.run_for(60.0)

    final = c1.sync_get(key, "n", consistent=True)
    assert final.ok
    # CAS semantics make double-apply impossible; an acked CAS may at most
    # be counted once. The counter equals the successful CAS count.
    assert final.value == successes[0], \
        f"counter {final.value} != acked CAS {successes[0]}"


def test_checkpoint_commit_with_storage_crash_midway():
    """Crash a storage node while chunks are being written: the save must
    either complete (quorum survives) and restore bit-exactly, and the
    previous manifest must never be corrupted."""
    store = SpinnakerCheckpointStore(StoreConfig(chunk_bytes=256))
    rng = np.random.default_rng(0)
    tree1 = {"w": rng.standard_normal((64, 33)).astype(np.float32)}
    store.save(1, tree1)

    tree2 = {"w": rng.standard_normal((64, 33)).astype(np.float32)}
    # interleave: crash node 1 after some chunks of save(2) are in
    orig_put = store._put
    calls = [0]

    def crashing_put(key, value):
        calls[0] += 1
        if calls[0] == 4:
            store.crash_storage_node(1)
        return orig_put(key, value)

    store._put = crashing_put
    store.save(2, tree2)          # quorum survives -> must succeed
    store._put = orig_put

    step, restored = store.restore_tree(tree2)
    assert step == 2
    assert np.array_equal(restored["w"], tree2["w"])

    # the dead node comes back and catches up; restore still exact
    store.restart_storage_node(1)
    step, restored = store.restore_tree(tree2)
    assert step == 2 and np.array_equal(restored["w"], tree2["w"])


def test_checkpoint_blocked_when_majority_lost_then_recovers():
    store = SpinnakerCheckpointStore(StoreConfig(n_nodes=3, chunk_bytes=512))
    tree = {"w": np.arange(300, dtype=np.float32)}
    store.save(1, tree)
    store.crash_storage_node(0)
    store.crash_storage_node(1)
    store.sim.run_for(3.0)
    with pytest.raises(CheckpointError):
        store.save(2, tree)
    # majority restored -> commits flow again
    store.restart_storage_node(0)
    store.sim.run_for(8.0)
    store.save(3, {"w": tree["w"] * 2})
    step, restored = store.restore_tree(tree)
    assert step == 3 and np.array_equal(restored["w"], tree["w"] * 2)
