"""Property-based protocol tests (hypothesis): random fault schedules must
never violate Spinnaker's guarantees (§8.1):

  P1  durability: an acknowledged write is never lost under any
      crash-restart schedule (disks survive; only volatile state is lost);
  P2  version linearity: committed versions per key are unique and the
      final state corresponds to an actually-issued write;
  P3  leader uniqueness: at most one open leader per cohort, epochs
      strictly monotone;
  P4  timeline monotonicity: a replica's applied version for a key never
      decreases;
  P5  convergence: after healing, all replicas agree on committed state.
"""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import (ClusterConfig, ErrorCode, NodeConfig, ReplicaConfig,
                        Simulator, SpinnakerCluster, key_of)
from repro.core.replica import Role

KEYS = [key_of(1), key_of(2), key_of(3)]   # all land in a small cluster's ranges

action = st.one_of(
    st.tuples(st.just("put"), st.integers(0, len(KEYS) - 1)),
    st.tuples(st.just("crash"), st.integers(0, 2)),
    st.tuples(st.just("crash_noexpire"), st.integers(0, 2)),
    st.tuples(st.just("restart"), st.integers(0, 2)),
    st.tuples(st.just("tick"), st.sampled_from([0.1, 0.5, 1.5, 3.0])),
)


def drive(sim, pred, budget, slice_=0.05):
    """Run sim until pred() or sim-time budget exhausted."""
    deadline = sim.now + budget
    while sim.now < deadline and not pred():
        sim.run(until=min(deadline, sim.now + slice_))
    return pred()


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large])
@given(seed=st.integers(0, 2**16), schedule=st.lists(action, min_size=1,
                                                     max_size=30))
def test_no_acked_write_lost_under_crash_restart(seed, schedule):
    sim = Simulator(seed=seed)
    cfg = ClusterConfig(
        n_nodes=3,
        node=NodeConfig(replica=ReplicaConfig(commit_period=0.25)),
        session_timeout=1.0)
    cluster = SpinnakerCluster(sim, cfg)
    cluster.start()
    drive(sim, lambda: all(cluster.leader_replica(r) is not None
                           for r in range(3)), 30.0)

    client = cluster.make_client()
    up = {0: True, 1: True, 2: True}
    acked: dict[str, tuple[bytes, int]] = {}
    issued: dict[str, set[bytes]] = {k: set() for k in KEYS}
    max_seen_version: dict[tuple[int, str], int] = {}
    wseq = 0

    def check_leader_uniqueness():
        for rid in range(3):
            leaders = [m for m in cluster.cohort(rid)
                       if cluster.nodes[m].replicas[rid].role is Role.LEADER
                       and cluster.nodes[m].has_session()]
            assert len(leaders) <= 1, f"two live leaders for range {rid}"

    def check_timeline_monotonic():
        # P4: per-replica applied versions never decrease
        for nid, node in cluster.nodes.items():
            for rid, rep in node.replicas.items():
                for key in KEYS:
                    cell = rep.store.get(key, "c")
                    if cell is None:
                        continue
                    prev = max_seen_version.get((nid, key), 0)
                    if node.up:
                        assert cell.version >= prev, \
                            f"replica n{nid} went back in time on {key}"
                    max_seen_version[(nid, key)] = max(prev, cell.version)

    for act in schedule:
        kind = act[0]
        if kind == "put":
            key = KEYS[act[1]]
            wseq += 1
            val = f"{key}-w{wseq}".encode()
            issued[key].add(val)
            box = []
            # bind THIS box (late replies from earlier, still-retrying puts
            # must not land in a rebound list)
            client.put(key, "c", val, lambda r, b=box: b.append(r))
            done = drive(sim, lambda b=box: bool(b), 8.0)
            if done and box[0].ok:
                acked[key] = (val, box[0].version)
        elif kind in ("crash", "crash_noexpire") and up[act[1]]:
            cluster.crash_node(act[1],
                               expire_session=(kind == "crash"))
            up[act[1]] = False
        elif kind == "restart" and not up[act[1]]:
            cluster.restart_node(act[1])
            up[act[1]] = True
        elif kind == "tick":
            sim.run_for(act[1])
        check_leader_uniqueness()
        check_timeline_monotonic()

    # heal everything and let the system settle
    for nid, alive in up.items():
        if not alive:
            cluster.restart_node(nid)
    ok = drive(sim, lambda: all(cluster.leader_replica(r) is not None
                                for r in range(3)), 60.0)
    assert ok, "cluster failed to re-elect leaders after full heal"
    sim.run_for(3.0)   # commit messages propagate

    # P1/P2: strong reads see every acknowledged write (or something newer
    # that was actually issued)
    for key, (val, version) in acked.items():
        box = []
        client.get(key, "c", True, lambda r, b=box: b.append(r))
        assert drive(sim, lambda b=box: bool(b), 30.0), "strong read stalled"
        res = box[0]
        assert res.ok, f"committed key {key} unreadable: {res.code}"
        assert res.version >= version, \
            f"lost write {val!r} v{version}; got v{res.version}"
        if res.version == version:
            assert res.value == val
        else:
            assert res.value in issued[key], "fabricated value"

    # P5: replicas converge on committed state
    sim.run_for(2.0)
    for rid in range(3):
        lead = cluster.leader_replica(rid)
        assert lead is not None
        for key in KEYS:
            if cluster.range_of(key) != rid:
                continue
            lcell = lead.store.get(key, "c")
            for m in cluster.cohort(rid):
                rep = cluster.nodes[m].replicas[rid]
                if rep.role is Role.FOLLOWER:
                    fcell = rep.store.get(key, "c")
                    if lcell is None:
                        continue
                    assert fcell is not None and fcell.version == lcell.version, \
                        f"follower n{m} diverged on {key}"


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**16),
       n_writers=st.integers(2, 4),
       n_rounds=st.integers(2, 8))
def test_conditional_put_is_linear_under_contention(seed, n_writers, n_rounds):
    """Optimistic concurrency (§3): concurrent conditional increments — the
    counter must equal exactly the number of successful cond-puts."""
    sim = Simulator(seed=seed)
    cluster = SpinnakerCluster(sim, ClusterConfig(n_nodes=3))
    cluster.start()
    drive(sim, lambda: all(cluster.leader_replica(r) is not None
                           for r in range(3)), 30.0)
    clients = [cluster.make_client(f"c{i}") for i in range(n_writers)]
    key = KEYS[0]
    clients[0].sync_put(key, "n", 0)

    successes = [0]

    def attempt(client, rounds_left):
        if rounds_left == 0:
            return

        def on_get(res):
            if not res.ok:
                return

            def on_cas(r2):
                if r2.ok:
                    successes[0] += 1
                attempt(client, rounds_left - 1)

            client.conditional_put(key, "n", res.value + 1, res.version,
                                   on_cas)

        client.get(key, "n", True, on_get)

    for cl in clients:
        attempt(cl, n_rounds)
    sim.run_for(60.0)

    final = clients[0].sync_get(key, "n", consistent=True)
    assert final.ok
    assert final.value == successes[0], \
        f"counter {final.value} != successful cond-puts {successes[0]}"
    assert final.version == successes[0] + 1  # initial put + each success
