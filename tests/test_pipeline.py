"""Pipeline parallelism: GPipe schedule must be exact vs the sequential
stack (runs on 8 host devices in a subprocess)."""

import subprocess
import sys
import textwrap

from repro.dist.pipeline import bubble_fraction, pp_vs_dp_napkin


def test_bubble_fraction():
    assert bubble_fraction(1, 4) == 0.75
    assert abs(bubble_fraction(15, 2) - 1 / 16) < 1e-9
    assert bubble_fraction(100, 2) < 0.01


def test_pp_vs_dp_napkin_two_pods():
    # mistral-large grads bf16 = 246 GB over 25 GB/s DCN vs a 2-stage
    # pipeline bubble on a ~1 s step: PP wins only with enough microbatches
    r = pp_vs_dp_napkin(grad_bytes=246e9, dcn_bw=25e9 * 256,
                        step_compute_s=1.0, n_micro=16, n_stages=2)
    assert "pp_wins" in r and r["bubble_s"] > 0


PIPE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.pipeline import gpipe

    mesh = jax.make_mesh((4, 2), ("pipe", "model"))
    D = 32
    n_stages, layers_per_stage = 4, 2
    rng = np.random.default_rng(0)
    # stage params: (n_stages, layers_per_stage, D, D)
    Ws = jnp.asarray(rng.standard_normal(
        (n_stages, layers_per_stage, D, D)) * 0.2, jnp.float32)

    def stage_fn(Wstage, x):
        for i in range(layers_per_stage):
            x = jnp.tanh(x @ Wstage[i])
        return x

    n_micro, mb = 6, 3
    x = jnp.asarray(rng.standard_normal((n_micro, mb, D)), jnp.float32)

    run = gpipe(stage_fn, mesh, axis="pipe")
    y_pipe = jax.jit(run)(Ws, x)

    # sequential oracle
    y_ref = x
    for s in range(n_stages):
        y_ref = jax.vmap(lambda xm: stage_fn(Ws[s], xm))(y_ref)

    err = float(jnp.max(jnp.abs(y_pipe - y_ref)))
    assert err < 1e-5, err
    # collective-permute must appear in the lowered module
    txt = jax.jit(run).lower(Ws, x).compile().as_text()
    assert "collective-permute" in txt
    print("PIPE_OK", err)
""")


def test_gpipe_exact_vs_sequential_subprocess():
    r = subprocess.run([sys.executable, "-c", PIPE_SCRIPT],
                       capture_output=True, text=True, timeout=600, cwd=".")
    assert "PIPE_OK" in r.stdout, r.stdout + r.stderr
