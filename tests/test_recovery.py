"""Recovery tests (§6, App. B): follower recovery, leader takeover,
logical truncation, SSTable-sourced catch-up."""

import pytest

from repro.core import (ClusterConfig, ErrorCode, NodeConfig, ReplicaConfig,
                        Simulator, SpinnakerCluster, key_of)
from repro.core.replica import Role


def make_cluster(n=5, seed=0, commit_period=1.0, **kw):
    sim = Simulator(seed=seed)
    cfg = ClusterConfig(
        n_nodes=n,
        node=NodeConfig(replica=ReplicaConfig(commit_period=commit_period)),
        **kw)
    cluster = SpinnakerCluster(sim, cfg)
    cluster.start()
    cluster.settle()
    return sim, cluster


def put_many(cluster, c, keys, prefix="v"):
    done = []
    for i, k in enumerate(keys):
        c.put(k, "c", f"{prefix}{i}".encode(), lambda r: done.append(r))
    cluster.sim.run_for(5.0)
    assert len(done) == len(keys) and all(r.ok for r in done)
    return done


def test_follower_crash_restart_catches_up():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    key = key_of(10)
    rid = cluster.range_of(key)
    leader = cluster.leader_replica(rid)
    follower_id = next(m for m in cluster.cohort(rid)
                       if m != leader.node.node_id)

    c.sync_put(key, "c", b"before")
    cluster.crash_node(follower_id)
    # writes continue with one follower down (majority alive)
    for i in range(20):
        assert c.sync_put(key, "c", f"during{i}".encode()).ok
    cluster.restart_node(follower_id)
    sim.run_for(5.0)
    rep = cluster.nodes[follower_id].replicas[rid]
    assert rep.role is Role.FOLLOWER
    cell = rep.store.get(key, "c")
    assert cell is not None and cell.value == b"during19"
    assert cell.version == 21


def test_leader_crash_fails_over_and_no_committed_write_lost():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    key = key_of(10)
    rid = cluster.range_of(key)
    old_leader = cluster.leader_replica(rid)
    old_epoch = old_leader.epoch

    acked = []
    for i in range(15):
        c.put(key, "c", f"w{i}".encode(), lambda r, i=i: acked.append((i, r)))
    sim.run_for(3.0)
    committed = [i for i, r in acked if r.ok]
    assert committed  # some writes acked

    cluster.crash_node(old_leader.node.node_id)
    sim.run_for(5.0)
    new_leader = cluster.leader_replica(rid)
    assert new_leader is not None
    assert new_leader.node.node_id != old_leader.node.node_id
    assert new_leader.epoch > old_epoch

    # every acked write survives: last acked value visible via strong read
    got = c.sync_get(key, "c", consistent=True)
    last = max(committed)
    assert got.ok and got.value == f"w{last}".encode()
    # cohort accepts new writes with LSNs beyond the old regime
    res = c.sync_put(key, "c", b"after-failover")
    assert res.ok and res.version == len(committed) + 1


def test_old_leader_rejoins_as_follower():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    key = key_of(10)
    rid = cluster.range_of(key)
    old_leader = cluster.leader_replica(rid)
    c.sync_put(key, "c", b"x")
    cluster.crash_node(old_leader.node.node_id)
    sim.run_for(5.0)
    assert c.sync_put(key, "c", b"y").ok
    cluster.restart_node(old_leader.node.node_id)
    sim.run_for(5.0)
    rep = cluster.nodes[old_leader.node.node_id].replicas[rid]
    assert rep.role is Role.FOLLOWER
    cell = rep.store.get(key, "c")
    assert cell is not None and cell.value == b"y"


def test_unavailable_when_majority_down_then_recovers():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    key = key_of(10)
    rid = cluster.range_of(key)
    members = cluster.cohort(rid)
    c.sync_put(key, "c", b"committed")
    sim.run_for(2.0)   # commit message propagates to followers
    # take down 2 of 3 => writes must not commit
    cluster.crash_node(members[0])
    cluster.crash_node(members[1])
    sim.run_for(3.0)
    res = []
    c.put(key, "c", b"should-stall", lambda r: res.append(r))
    sim.run_for(2.0)
    assert not res or not res[0].ok
    # timeline reads still served by the survivor (§8.1)
    tr = []
    c.get(key, "c", False, lambda r: tr.append(r))
    sim.run_for(6.0)
    assert any(r.ok and r.value == b"committed" for r in tr)
    # majority restored => cohort becomes writable again
    cluster.restart_node(members[0])
    sim.run_for(8.0)
    assert c.sync_put(key, "c", b"recovered").ok
    assert c.sync_get(key, "c").value == b"recovered"


def test_figure10_full_cohort_crash_partial_restart():
    """App. B walk-through: all nodes down; two restart; uncommitted tail of
    the crashed minority is logically truncated; epochs advance."""
    sim, cluster = make_cluster(n=3, commit_period=0.5)
    c = cluster.make_client()
    key = key_of(10)
    rid = cluster.range_of(key)
    members = cluster.cohort(rid)

    put_many(cluster, c, [key] * 10)
    sim.run_for(1.0)  # let commit messages flow

    # whole cohort goes down
    for m in members:
        cluster.crash_node(m)
    sim.run_for(3.0)
    # two come back (possibly missing some uncommitted tail)
    cluster.restart_node(members[0])
    cluster.restart_node(members[1])
    sim.run_for(8.0)
    got = c.sync_get(key, "c", consistent=True)
    assert got.ok and got.value == b"v9" and got.version == 10

    res = c.sync_put(key, "c", b"new-epoch-write")
    assert res.ok and res.version == 11
    # third node rejoins and catches up across both regimes
    cluster.restart_node(members[2])
    sim.run_for(8.0)
    rep = cluster.nodes[members[2]].replicas[rid]
    cell = rep.store.get(key, "c")
    assert cell is not None and cell.value == b"new-epoch-write"
    assert cell.version == 11


def test_disk_loss_recovers_via_catchup():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    key = key_of(10)
    rid = cluster.range_of(key)
    leader = cluster.leader_replica(rid)
    follower_id = next(m for m in cluster.cohort(rid)
                       if m != leader.node.node_id)
    put_many(cluster, c, [key] * 8)
    cluster.crash_node(follower_id, lose_disk=True)
    sim.run_for(2.0)
    assert c.sync_put(key, "c", b"while-down").ok
    cluster.restart_node(follower_id)
    sim.run_for(6.0)
    rep = cluster.nodes[follower_id].replicas[rid]
    assert rep.role is Role.FOLLOWER
    cell = rep.store.get(key, "c")
    assert cell is not None and cell.value == b"while-down"


def test_catchup_from_sstables_after_log_rollover():
    """Force memtable flushes + log GC, then catch a follower up (§6.1:
    'the appropriate SSTable is located and sent')."""
    sim = Simulator(seed=3)
    cfg = ClusterConfig(
        n_nodes=3,
        node=NodeConfig(
            replica=ReplicaConfig(commit_period=0.2, flush_threshold=2000),
            wal_segment_bytes=4000))
    cluster = SpinnakerCluster(sim, cfg)
    cluster.start()
    cluster.settle()
    c = cluster.make_client()
    key = key_of(10)
    rid = cluster.range_of(key)
    leader = cluster.leader_replica(rid)
    follower_id = next(m for m in cluster.cohort(rid)
                       if m != leader.node.node_id)
    cluster.crash_node(follower_id)
    keys = [key_of(10 + i % 5) for i in range(120)]
    put_many(cluster, c, keys, prefix="x" * 100)
    sim.run_for(2.0)
    assert leader.store.flushes > 0, "flush threshold should have tripped"
    cluster.restart_node(follower_id)
    sim.run_for(8.0)
    rep = cluster.nodes[follower_id].replicas[rid]
    assert rep.role is Role.FOLLOWER
    # spot-check several keys on the recovered follower
    for i in range(5):
        want_leader = leader.store.get(key_of(10 + i), "c")
        got = rep.store.get(key_of(10 + i), "c")
        assert got is not None and want_leader is not None
        assert got.value == want_leader.value
        assert got.version == want_leader.version


def test_epoch_monotonic_across_failovers():
    sim, cluster = make_cluster(n=3)
    c = cluster.make_client()
    key = key_of(10)
    rid = cluster.range_of(key)
    epochs = [cluster.leader_replica(rid).epoch]
    for round_ in range(3):
        leader = cluster.leader_replica(rid)
        c.sync_put(key, "c", f"r{round_}".encode())
        nid = leader.node.node_id
        cluster.crash_node(nid)
        sim.run_for(6.0)
        cluster.restart_node(nid)
        sim.run_for(6.0)
        new_leader = cluster.leader_replica(rid)
        assert new_leader is not None
        epochs.append(new_leader.epoch)
    assert epochs == sorted(epochs)
    assert len(set(epochs)) == len(epochs)
    got = c.sync_get(key, "c")
    assert got.ok and got.value == b"r2"
