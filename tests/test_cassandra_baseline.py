"""Tests for the eventually consistent baseline (§9's comparison system)."""

from repro.baselines import CassandraCluster, CassandraConfig
from repro.core import ErrorCode, Simulator
from repro.core.cluster import key_of


def make(n=5, seed=0):
    sim = Simulator(seed=seed)
    cluster = CassandraCluster(sim, CassandraConfig(n_nodes=n))
    return sim, cluster


def test_quorum_write_then_quorum_read():
    sim, cluster = make()
    c = cluster.make_client()
    assert c.sync_write(key_of(5), "c", b"v", quorum=True).ok
    got = c.sync_read(key_of(5), "c", quorum=True)
    assert got.ok and got.value == b"v"


def test_weak_write_single_ack_faster_than_quorum():
    sim, cluster = make()
    c = cluster.make_client()
    lat_w, lat_q = [], []
    for i in range(50):
        r = c.sync_write(key_of(5), "c", f"w{i}".encode(), quorum=False)
        lat_w.append(r.latency)
    for i in range(50):
        r = c.sync_write(key_of(5), "c", f"q{i}".encode(), quorum=True)
        lat_q.append(r.latency)
    assert sum(lat_w) / 50 < sum(lat_q) / 50


def test_stale_read_possible_after_restart_without_repair():
    """The consistency gap §9 highlights: no quorum recovery => a restarted
    replica can serve stale weak reads."""
    sim, cluster = make(n=3, seed=7)
    c = cluster.make_client()
    key = key_of(5)
    c.sync_write(key, "c", b"old", quorum=True)
    sim.run_for(1.0)
    victim = cluster.cohort(cluster.range_of(key))[0]
    cluster.crash_node(victim)
    sim.run_for(0.5)
    assert c.sync_write(key, "c", b"new", quorum=True).ok
    cluster.restart_node(victim)
    sim.run_for(0.5)
    # weak reads round-robin; some hit the stale restarted replica
    seen = set()
    for _ in range(12):
        r = c.sync_read(key, "c", quorum=False)
        if r.ok:
            seen.add(r.value)
    assert b"new" in seen
    # (stale b"old" may or may not appear depending on routing; both legal
    # under eventual consistency — the point is no error is raised either way)


def test_quorum_read_repairs_stale_replica():
    sim, cluster = make(n=3, seed=11)
    c = cluster.make_client()
    key = key_of(5)
    c.sync_write(key, "c", b"old", quorum=True)
    victim = cluster.cohort(cluster.range_of(key))[0]
    cluster.crash_node(victim)
    sim.run_for(0.5)
    c.sync_write(key, "c", b"new", quorum=True)
    cluster.restart_node(victim)
    sim.run_for(0.5)
    # quorum reads LWW-resolve and trigger read repair
    for _ in range(8):
        r = c.sync_read(key, "c", quorum=True)
        assert not r.ok or r.value == b"new" or r.value == b"old"
    sim.run_for(1.0)
    for _ in range(8):
        r = c.sync_read(key, "c", quorum=True)
        if r.ok:
            assert r.value == b"new"


def test_write_survives_one_node_down():
    sim, cluster = make(n=3)
    c = cluster.make_client()
    key = key_of(5)
    victim = cluster.cohort(cluster.range_of(key))[1]
    cluster.crash_node(victim)
    sim.run_for(0.2)
    assert c.sync_write(key, "c", b"v", quorum=True).ok
    got = c.sync_read(key, "c", quorum=True)
    assert got.ok and got.value == b"v"
