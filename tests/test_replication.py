"""Steady-state replication protocol tests (§5)."""

import pytest

from repro.core import (ClusterConfig, ErrorCode, Simulator, SpinnakerCluster,
                        key_of)
from repro.core.replica import Role


def make_cluster(n=5, seed=0, **kw):
    sim = Simulator(seed=seed)
    cfg = ClusterConfig(n_nodes=n, **kw)
    cluster = SpinnakerCluster(sim, cfg)
    cluster.start()
    cluster.settle()
    return sim, cluster


def test_cold_start_elects_all_leaders():
    sim, cluster = make_cluster()
    for rid in range(5):
        rep = cluster.leader_replica(rid)
        assert rep is not None
        assert rep.open_for_writes
        # exactly one leader per cohort
        leaders = [m for m in cluster.cohort(rid)
                   if cluster.nodes[m].replicas[rid].role is Role.LEADER]
        assert len(leaders) == 1


def test_put_then_strong_get():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    res = c.sync_put(key_of(7), "col", b"hello")
    assert res.ok and res.version == 1
    got = c.sync_get(key_of(7), "col", consistent=True)
    assert got.ok and got.value == b"hello" and got.version == 1


def test_versions_increment_and_conditional_put():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    assert c.sync_put(key_of(3), "c", b"v1").version == 1
    assert c.sync_put(key_of(3), "c", b"v2").version == 2
    # matching version succeeds
    res = c.sync_cond_put(key_of(3), "c", b"v3", 2)
    assert res.ok and res.version == 3
    # stale version fails
    res = c.sync_cond_put(key_of(3), "c", b"v4", 2)
    assert res.code == ErrorCode.VERSION_MISMATCH
    got = c.sync_get(key_of(3), "c")
    assert got.value == b"v3" and got.version == 3


def test_delete_and_not_found():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    c.sync_put(key_of(11), "c", b"x")
    res = c.sync_delete(key_of(11), "c")
    assert res.ok
    got = c.sync_get(key_of(11), "c")
    assert got.code == ErrorCode.NOT_FOUND


def test_multi_put_single_call():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    res = c.sync(c.multi_put, key_of(20), [("a", b"1"), ("b", b"2")])
    assert res.ok
    assert c.sync_get(key_of(20), "a").value == b"1"
    assert c.sync_get(key_of(20), "b").value == b"2"


def test_write_replicated_to_majority_logs():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    key = key_of(42)
    c.sync_put(key, "c", b"payload")
    rid = cluster.range_of(key)
    sim.run(until=sim.now + 0.2)  # let follower forces finish
    holders = 0
    for m in cluster.cohort(rid):
        recs, _cmt = cluster.nodes[m].wal.recover_range(rid)
        if any(r.key == key for r in recs):
            holders += 1
    assert holders >= 2


def test_timeline_read_converges_after_commit_period():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    key = key_of(55)
    c.sync_put(key, "c", b"fresh")
    rid = cluster.range_of(key)
    # after > commit_period, every replica must serve the new value
    sim.run(until=sim.now + 2.5)
    for m in cluster.cohort(rid):
        rep = cluster.nodes[m].replicas[rid]
        cell = rep.store.get(key, "c")
        assert cell is not None and cell.value == b"fresh"


def test_strong_read_routed_to_leader_only():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    key = key_of(77)
    c.sync_put(key, "c", b"x")
    rid = cluster.range_of(key)
    leader = cluster.leader_replica(rid)
    before = leader.reads_served
    c.sync_get(key, "c", consistent=True)
    assert leader.reads_served == before + 1


def test_pipelined_writes_same_key_serialize():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    key = key_of(90)
    results = []
    for i in range(10):
        c.put(key, "c", f"v{i}".encode(), lambda r: results.append(r))
    sim.run_for(5.0)
    assert len(results) == 10
    assert all(r.ok for r in results)
    versions = sorted(r.version for r in results)
    assert versions == list(range(1, 11))
    got = c.sync_get(key, "c")
    assert got.version == 10
