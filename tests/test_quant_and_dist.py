"""Weight-only quantization + distribution-layer unit tests."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models import decode_step, forward, init_cache, init_params
from repro.models.quant import (dequantize_tree, is_quantized,
                                quantize_tree, quantize_weight, wcast)
from repro.launch.shapes import make_batch, make_decode_tokens


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((256, 128)) * 0.05, jnp.float32)
    q = quantize_weight(w)
    assert q["q"].dtype == jnp.int8
    assert q["s"].shape == (128,)
    back = wcast(q, jnp.float32)
    err = jnp.max(jnp.abs(back - w))
    assert float(err) <= float(jnp.max(jnp.abs(w))) / 127.0 + 1e-7


def test_quantized_forward_close_to_dense():
    cfg = smoke_config("smollm-360m").scaled(remat=False, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    qparams = quantize_tree(params)
    # embeddings stay dense; attention/mlp weights quantized
    assert is_quantized(qparams["layers"]["attn"]["wq"])
    assert not is_quantized(qparams["embed"])
    rng = np.random.default_rng(1)
    batch = make_batch(cfg, rng, batch=2, seq=16)
    ref, _, _ = forward(params, batch, cfg)
    out, _, _ = forward(qparams, batch, cfg)
    # W8A16-style error: small relative to logit scale
    denom = float(jnp.std(ref)) + 1e-9
    rel = float(jnp.max(jnp.abs(out - ref))) / denom
    assert rel < 0.25, f"quantized logits too far off ({rel})"


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-2.7b"])
def test_quantized_decode_runs(arch):
    cfg = smoke_config(arch).scaled(remat=False, dtype="float32")
    params = quantize_tree(init_params(jax.random.PRNGKey(0), cfg))
    cache = init_cache(cfg, 2, 32)
    rng = np.random.default_rng(2)
    tok = make_decode_tokens(cfg, rng, 2)
    logits, cache = decode_step(params, cache, tok, cfg)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_remat_policy_dots_matches_full():
    cfg = smoke_config("gemma-7b").scaled(dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    batch = make_batch(cfg, rng, batch=2, seq=16)
    from repro.models import loss_fn

    g_full = jax.grad(lambda p: loss_fn(p, batch, cfg)[0])(params)
    cfg2 = cfg.scaled(remat_policy="dots")
    g_dots = jax.grad(lambda p: loss_fn(p, batch, cfg2)[0])(params)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_dots)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


SHARD_MAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke_config
    from repro.dist.sharding import MeshContext, ShardingPolicy
    from repro.models.moe import init_moe, moe_ffn

    cfg = smoke_config("kimi-k2-1t-a32b").scaled(
        dtype="float32", num_experts=8, moe_d_ff=64, capacity_factor=8.0,
        shared_expert_d_ff=0)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    pol = ShardingPolicy.for_mesh(mesh)
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 16, cfg.d_model)), jnp.float32)
    with MeshContext(mesh, cfg, pol):
        y1, _ = jax.jit(lambda p, x: moe_ffn(
            p, x, cfg.scaled(moe_impl="gspmd")))(params, x)
        y2, _ = jax.jit(lambda p, x: moe_ffn(
            p, x, cfg.scaled(moe_impl="shard_map")))(params, x)
        # gradients flow through the explicit all-to-alls
        g = jax.jit(jax.grad(lambda p: moe_ffn(
            p, x, cfg.scaled(moe_impl="shard_map"))[0].sum()))(params)
    err = float(jnp.max(jnp.abs(y1 - y2)))
    assert err < 1e-5, err
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    print("SHARD_MAP_OK", err)
""")


def test_shard_map_moe_matches_gspmd_on_8_devices():
    """Runs in a subprocess: needs 8 host devices while the main test
    process is locked to 1."""
    r = subprocess.run([sys.executable, "-c", SHARD_MAP_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       cwd=".")
    assert "SHARD_MAP_OK" in r.stdout, r.stdout + r.stderr
