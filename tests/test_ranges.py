"""Elastic range management: live splits, replica migration, hotspot
rebalancing, and dynamic client routing (core/ranges.py)."""

import warnings

import pytest

from repro.core import (ClusterConfig, ErrorCode, Simulator,
                        SpinnakerCluster, key_of)
from repro.core import ranges as ranges_mod
from repro.core.ranges import BalancerConfig
from repro.core.replica import Role
from repro.workload import parse_schedule


def make_cluster(n=5, seed=0, num_keys=100, **kw):
    sim = Simulator(seed=seed)
    cfg = ClusterConfig(n_nodes=n, num_keys=num_keys, **kw)
    cluster = SpinnakerCluster(sim, cfg)
    cluster.start()
    cluster.settle()
    return sim, cluster


def preload(cluster, n, prefix=b"v"):
    c = cluster.make_client("pre")
    acked = {}
    for i in range(n):
        r = c.sync_put(key_of(i), "c", prefix + str(i).encode())
        assert r.ok
        acked[i] = r.version
    return acked


# ---------------------------------------------------------------------- split

def test_live_split_routes_all_keys():
    sim, cluster = make_cluster()
    preload(cluster, 100)
    n_before = len(cluster.ranges)
    assert cluster.admin_split(0)
    sim.run_for(2.0)
    cluster.settle()
    assert len(cluster.ranges) == n_before + 1
    child_rid = max(cluster.ranges)
    # child metadata registered with the parent cohort's members
    meta = ranges_mod.get_range_meta(cluster.zk, child_rid)
    assert meta is not None
    lo, hi, members = meta
    assert members == cluster.members[0]
    assert cluster.ranges[0].hi == lo      # contiguous boundary
    # every key readable and writable after the move
    c = cluster.make_client()
    for i in range(100):
        r = c.sync_get(key_of(i), "c")
        assert r.ok and r.value == b"v" + str(i).encode(), (i, r)
    # writes land on both sides of the boundary
    assert c.sync_put(cluster.ranges[0].lo, "c", b"parent").ok
    assert c.sync_put(lo, "c", b"child").ok
    assert cluster.range_of(lo) == child_rid


def test_split_uses_median_by_default():
    sim, cluster = make_cluster(n=3, num_keys=60)
    preload(cluster, 60)
    kr = cluster.ranges[0]
    leader = cluster.leader_replica(0)
    median = leader.store.median_key(kr.lo, kr.hi)
    assert cluster.admin_split(0)
    sim.run_for(2.0)
    assert cluster.ranges[0].hi == median


def test_parent_replica_redirects_moved_keys():
    sim, cluster = make_cluster()
    preload(cluster, 100)
    child_before = set(cluster.ranges)
    assert cluster.admin_split(0)
    sim.run_for(2.0)
    child_rid = (set(cluster.ranges) - child_before).pop()
    moved_key = cluster.ranges[child_rid].lo
    leader = cluster.leader_replica(0)
    out = []
    leader.client_read(moved_key, "c", True, out.append)
    assert out and out[0].code == ErrorCode.WRONG_RANGE
    out2 = []
    from repro.core.types import OpType, WriteOp
    leader.client_write(WriteOp(OpType.PUT, moved_key, "c", b"x"),
                        out2.append)
    assert out2 and out2[0].code == ErrorCode.WRONG_RANGE


def test_no_lost_acked_writes_through_split_under_load():
    """Writes keep flowing while the split commits; every acknowledged
    version stays readable afterwards."""
    sim, cluster = make_cluster(seed=3)
    acked = preload(cluster, 100)
    c = cluster.make_client("load")
    inflight = []

    def put(i):
        def done(r):
            if r.ok:
                acked[i] = max(acked.get(i, 0), r.version)
            inflight.remove(i)
        inflight.append(i)
        c.put(key_of(i), "c", b"post-split-%d" % i, done)

    # pipeline writes across the split point without waiting in between
    assert cluster.admin_split(0)
    for i in range(100):
        put(i)
        sim.run_for(0.002)
    sim.run_for(5.0)
    assert not inflight
    cluster.settle()
    reader = cluster.make_client("check")
    for i, ver in acked.items():
        r = reader.sync_get(key_of(i), "c")
        assert r.ok and r.version >= ver, (i, ver, r)


def test_timeline_monotonic_across_split():
    """Session monotonicity survives the key moving to a child range: the
    client never observes versions going backwards (satellite)."""
    sim, cluster = make_cluster(seed=4)
    preload(cluster, 100)
    c = cluster.make_client("mono")
    k = key_of(30)            # upper half of range 0's [0, 20) ... range 1
    rid = cluster.range_of(k)
    for _ in range(3):
        assert c.sync_put(k, "c", b"bump").ok
    # observe the latest version through a monotonic timeline read
    seen = []
    while not seen or seen[-1] < 4:  # preload wrote v1; 3 bumps -> v4
        r = c.sync(c.get, k, "c", False)
        assert r.ok
        seen.append(r.version)
    assert cluster.admin_split(rid, k)   # k becomes the child's first key
    sim.run_for(2.0)
    cluster.settle()
    assert cluster.range_of(k) != rid
    for _ in range(20):
        r = c.sync(c.get, k, "c", False)
        assert r.ok and r.version >= seen[-1], (r.version, seen[-1])
        seen.append(r.version)
    assert c.sync_put(k, "c", b"bump5").ok
    r = c.sync(c.get, k, "c", False)
    assert r.ok and r.version >= seen[-1]


def test_pipelined_conditional_puts_across_split_boundary():
    """A chain of conditional puts pipelined across the split barrier
    serializes without spurious VERSION_MISMATCH: versions continue on the
    child exactly where the parent left off (satellite)."""
    sim, cluster = make_cluster(seed=5)
    preload(cluster, 100)
    k = key_of(10)
    rid = cluster.range_of(k)
    c = cluster.make_client("cas")
    assert c.sync_get(k, "c").version == 1
    results = []
    # issue CAS v1->2, split at k, CAS v2->3 — all without draining the sim
    c.conditional_put(k, "c", b"cas2", 1, results.append)
    assert cluster.admin_split(rid, k)
    c.conditional_put(k, "c", b"cas3", 2, results.append)
    sim.run_for(5.0)
    assert len(results) == 2
    assert [r.code for r in results] == [ErrorCode.OK, ErrorCode.OK]
    assert [r.version for r in results] == [2, 3]
    cluster.settle()
    r = c.sync_get(k, "c")
    assert r.ok and r.version == 3 and r.value == b"cas3"
    assert cluster.range_of(k) != rid


# ------------------------------------------------------------------ migration

def test_replica_migration_snapshot_install():
    sim, cluster = make_cluster(n=4, seed=1, num_keys=80)
    preload(cluster, 80)
    leader = cluster.leader_replica(0)
    src = [m for m in cluster.members[0] if m != leader.node.node_id][0]
    assert cluster.admin_move(0, src, 3)
    sim.run_for(5.0)
    assert cluster.members[0] == tuple(sorted(
        set(cluster.members[0]) | {3}))  # dst joined
    assert src not in cluster.members[0]
    assert len(cluster.members[0]) == 3
    assert not cluster.zk.exists(ranges_mod.migration_path(0))
    assert 0 not in cluster.nodes[src].replicas        # src retired
    dst_rep = cluster.nodes[3].replicas[0]
    assert dst_rep.role is Role.FOLLOWER
    # destination holds the data: kill everyone else in the cohort and
    # timeline-read from the migrated replica
    for m in cluster.members[0]:
        if m != 3:
            cluster.crash_node(m)
    sim.run_for(0.5)
    c = cluster.make_client()
    r = c.sync(c.get, cluster.ranges[0].lo, "c", False)
    assert r.ok and r.value.startswith(b"v")


def test_leader_kill_mid_migration_recovers_unaided():
    sim, cluster = make_cluster(n=4, seed=2, num_keys=60)
    acked = preload(cluster, 60)
    leader = cluster.leader_replica(0)
    lid = leader.node.node_id
    src = [m for m in cluster.members[0] if m != lid][0]
    assert cluster.admin_move(0, src, 3)
    sim.run_for(0.2)                     # mid-migration ...
    cluster.crash_node(lid)              # ... kill the leader
    sim.run_for(10.0)
    cluster.settle(timeout=20.0)
    # the new leader resumed (or cleanly aborted) the migration from the
    # intent znode: cohort back to 3 members, no intent left
    assert len(cluster.members[0]) == 3
    assert not cluster.zk.exists(ranges_mod.migration_path(0))
    c = cluster.make_client()
    for i, ver in acked.items():
        r = c.sync_get(key_of(i), "c")
        assert r.ok and r.version >= ver, (i, ver, r)


def test_migration_guards():
    sim, cluster = make_cluster(n=4, seed=6, num_keys=40)
    preload(cluster, 40)
    leader = cluster.leader_replica(0)
    lid = leader.node.node_id
    members = cluster.members[0]
    # cannot move the leader's own replica, a non-member, or onto a member
    assert not leader.start_migration(lid, 3)
    assert not leader.start_migration(3, lid)
    follower = [m for m in members if m != lid][0]
    other = [m for m in members if m not in (lid, follower)][0]
    assert not leader.start_migration(follower, other)
    # a second concurrent migration is refused
    assert cluster.admin_move(0, follower, 3)
    assert not cluster.admin_move(0, other, 3)
    sim.run_for(5.0)
    assert not cluster.zk.exists(ranges_mod.migration_path(0))


# ---------------------------------------------------- recovery after a split

def test_node_down_through_split_rejoins_both_cohorts():
    """A node that sleeps through a split reconciles at boot: narrowed
    parent, a fresh child replica, data via snapshot catch-up."""
    sim, cluster = make_cluster(seed=7)
    preload(cluster, 100)
    victim = [m for m in cluster.members[0]
              if cluster.leader_replica(0).node.node_id != m][0]
    cluster.crash_node(victim)
    sim.run_for(0.5)
    assert cluster.admin_split(0)
    sim.run_for(3.0)
    child_rid = max(cluster.ranges)
    assert victim in cluster.members[child_rid]
    cluster.restart_node(victim)
    sim.run_for(5.0)
    cluster.settle()
    node = cluster.nodes[victim]
    assert child_rid in node.replicas
    rep = node.replicas[child_rid]
    assert rep.role in (Role.FOLLOWER, Role.LEADER)
    # narrowed parent replica on the restarted node
    assert node.replicas[0].range.hi == cluster.ranges[0].hi
    # the rejoined replica holds the forked data: serve a timeline read
    # from it after crashing the other members
    for m in cluster.members[child_rid]:
        if m != victim:
            cluster.crash_node(m)
    sim.run_for(2.0)
    c = cluster.make_client()
    r = c.sync(c.get, cluster.ranges[child_rid].lo, "c", False)
    assert r.ok and r.value.startswith(b"v")


def test_child_cohort_survives_leader_kill():
    sim, cluster = make_cluster(seed=8)
    acked = preload(cluster, 100)
    assert cluster.admin_split(0)
    sim.run_for(2.0)
    cluster.settle()
    child_rid = max(cluster.ranges)
    child_leader = cluster.leader_replica(child_rid)
    cluster.crash_node(child_leader.node.node_id)
    sim.run_for(8.0)
    cluster.settle(timeout=20.0)
    c = cluster.make_client()
    for i, ver in acked.items():
        r = c.sync_get(key_of(i), "c")
        assert r.ok and r.version >= ver, (i, ver, r)


# ------------------------------------------------------------------ balancer

def test_balancer_splits_hot_range():
    sim, cluster = make_cluster(seed=9)
    preload(cluster, 100)
    cluster.set_autobalance(True, BalancerConfig(
        period=0.2, split_threshold=100.0, cooldown=0.3,
        min_node_load=1e9))   # moves disabled; splits only
    c = cluster.make_client("hot")
    n_before = len(cluster.ranges)
    done = [0]

    def hammer(i=0):
        # hot keys all inside range 0
        c.put(key_of(i % 15), "c", b"hot", lambda r: done.__setitem__(
            0, done[0] + 1) or hammer(i + 1))

    for _ in range(4):
        hammer()
    sim.run_for(4.0)
    cluster.set_autobalance(False)
    assert len(cluster.ranges) > n_before
    assert any("split" in a for a in cluster.balancer.actions)


def test_balancer_moves_replica_off_hot_node():
    sim, cluster = make_cluster(n=4, seed=10, num_keys=80)
    preload(cluster, 80)
    cluster.set_autobalance(True, BalancerConfig(
        period=0.2, split_threshold=1e9,    # splits disabled; moves only
        min_node_load=50.0, move_imbalance=1.5, cooldown=0.3))
    members_before = cluster.members[0]
    c = cluster.make_client("hot")

    def hammer(i=0):
        c.put(key_of(i % 10), "c", b"hot",
              lambda r: hammer(i + 1))

    for _ in range(4):
        hammer()
    sim.run_for(6.0)
    cluster.set_autobalance(False)
    sim.run_for(3.0)
    assert any("move" in a for a in cluster.balancer.actions), \
        cluster.balancer.actions
    assert cluster.members[0] != members_before
    assert len(cluster.members[0]) == 3


# ------------------------------------------------- client routing + backoff

def test_client_backoff_grows_and_caps():
    sim, cluster = make_cluster(n=3, num_keys=30)
    c = cluster.make_client()
    delays = [c._retry_delay(t) for t in range(12)]
    # jittered exponential: bounded by 0.5x..1.5x of the capped series
    for t, d in enumerate(delays):
        exp = min(c.BACKOFF_CAP, c.BACKOFF_BASE * (2 ** t))
        assert 0.5 * exp <= d <= 1.5 * exp
    assert max(delays) <= 1.5 * c.BACKOFF_CAP


def test_client_routes_from_cached_range_table():
    sim, cluster = make_cluster(seed=11)
    preload(cluster, 100)
    c = cluster.make_client()
    assert c.sync_get(key_of(50), "c").ok
    loads_before = c.range_table.loads
    for i in range(0, 100, 7):
        assert c.sync_get(key_of(i), "c").ok
    assert c.range_table.loads == loads_before   # cache hit throughout
    # a split invalidates via the version watch; the next op reloads
    assert cluster.admin_split(0)
    sim.run_for(2.0)
    cluster.settle()
    assert c.sync_get(key_of(0), "c").ok
    assert c.range_table.loads > loads_before


# ------------------------------------------------------------ DSL + plumbing

def test_scenario_dsl_range_events():
    sched = parse_schedule("""
        at 1s   split range 0
        at 2.5s split range 1 at k000000000042
        at 3s   move range 2 from 1 to 4
        at 4s   move range 3
        at 5s   autobalance on
        at 6s   autobalance off
    """)
    acts = [(e.t, e.action) for e in sched.events]
    assert acts == [(1.0, "split"), (2.5, "split"), (3.0, "move"),
                    (4.0, "move"), (5.0, "autobalance"),
                    (6.0, "autobalance")]
    assert sched.events[1].key == "k000000000042"
    assert sched.events[2].src == 1 and sched.events[2].dst == 4
    assert sched.events[3].src is None and sched.events[3].dst is None
    assert sched.events[4].on and not sched.events[5].on


def test_scenario_split_event_fires_on_cluster():
    sim, cluster = make_cluster(seed=12)
    preload(cluster, 100)
    sched = parse_schedule("at 0.5s split range 0")
    sched.install(sim, cluster)
    sim.run_for(3.0)
    assert len(cluster.ranges) == 6
    assert any("split range 0" in a for a in sched.applied)


def test_presplit_alignment_warns(recwarn):
    from repro.workload import (ExperimentConfig, WorkloadSpec,
                                run_spinnaker_workload)
    spec = WorkloadSpec(num_keys=50, value_size=64, read_frac=0.5,
                        write_frac=0.5, rmw_frac=0, cond_frac=0)
    cfg = ExperimentConfig(n_nodes=3, disk="mem", n_clients=2,
                           warmup=0.1, duration=0.5, preload_cap=20)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        r = run_spinnaker_workload(spec, cfg)
        assert any("aligning cluster pre-split" in str(x.message) for x in w)
    assert r["writes"]["count"] > 0
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cfg.align_presplit = False
        run_spinnaker_workload(spec, cfg)
        assert any("does not match the cluster pre-split" in str(x.message)
                   for x in w)


@pytest.mark.slow
def test_rebalance_scenario_end_to_end():
    """Full rebalance run (the bench/smoke gate shape): split + migration
    + leader kill under zipfian write load, zero lost acked writes."""
    from repro.workload import (ExperimentConfig, WorkloadSpec,
                                run_spinnaker_rebalance)
    spec = WorkloadSpec(num_keys=500, key_dist="zipfian", zipf_theta=0.99,
                        read_frac=0.2, write_frac=0.8, rmw_frac=0,
                        cond_frac=0, value_size=512)
    cfg = ExperimentConfig(n_nodes=5, disk="mem", driver="open",
                           open_rate=1200, warmup=0.5, duration=8.0,
                           window=0.5, preload_cap=300)
    r = run_spinnaker_rebalance(spec, cfg, kill_leader=True)
    rb = r["rebalance"]
    assert not rb["lost_acked_writes"]
    assert rb["n_ranges_end"] > rb["n_ranges_start"]
    assert rb["all_ranges_serving_writes"]
    assert not rb["unresolved_migrations"]
    assert rb["write_availability"] >= 0.99
