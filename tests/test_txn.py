"""Cross-range 2PC transactions (core/txn.py): commit/abort atomicity,
the single-cohort fast path, range-aware multi_get, and the recovery
edges — coordinator killed at every 2PC stage, participant killed holding
locks, lock-table inheritance across log GC, and read isolation."""

import pytest

from repro.core import (ClusterConfig, ErrorCode, NodeConfig, OpType,
                        ReplicaConfig, Simulator, SpinnakerCluster, WriteOp,
                        key_of)
from repro.core.sim import DiskParams
from repro.core.types import TXN_OPS


def make_cluster(n=5, seed=0, num_keys=300, commit_period=0.05,
                 session_timeout=2.0, **node_kw):
    sim = Simulator(seed=seed)
    cfg = ClusterConfig(
        n_nodes=n, num_keys=num_keys, session_timeout=session_timeout,
        node=NodeConfig(replica=ReplicaConfig(commit_period=commit_period),
                        disk=DiskParams.memory(), **node_kw))
    cluster = SpinnakerCluster(sim, cfg)
    cluster.start()
    cluster.settle()
    return sim, cluster


def sync(sim, fn, *args, budget=12.0):
    box = []
    fn(*args, lambda r: box.append(r))
    deadline = sim.now + budget
    while not box and sim.now < deadline:
        sim.run(until=sim.now + 0.05)
    assert box, "op did not complete"
    return box[0]


def drive_until(sim, pred, budget=8.0):
    deadline = sim.now + budget
    while sim.now < deadline and not pred():
        if not sim.step():
            break
    assert pred(), "predicate never became true"


def two_range_keys(cluster):
    k1, k2 = key_of(10), key_of(200)
    assert cluster.range_of(k1) != cluster.range_of(k2)
    return k1, k2


def remote_partner_key(cluster, coord):
    """A key in another range whose leader is on a different *node* than
    `coord` (cohorts overlap under chained declustering, so a random pick
    may share the node and a coordinator kill would hit both roles)."""
    for i in (100, 160, 200, 280):
        k = key_of(i)
        rid = cluster.range_of(k)
        rep = cluster.leader_replica(rid)
        if rid != coord.rid and rep is not None \
                and rep.node.node_id != coord.node.node_id:
            return k
    raise RuntimeError("no disjoint-leader range found")


def all_txn_state(cluster):
    """(locks, prepared, intents) summed over every live replica."""
    locks = prepared = 0
    for node in cluster.nodes.values():
        if not node.up:
            continue
        for rep in node.replicas.values():
            locks += len(rep.txn.locks)
            prepared += len(rep.txn.prepared)
    return locks, prepared, sorted(cluster.zk.get_children("/txn"))


def assert_clean(cluster):
    locks, prepared, intents = all_txn_state(cluster)
    assert locks == 0, f"leftover locks: {locks}"
    assert prepared == 0, f"leftover prepared txns: {prepared}"
    assert intents == [], f"unresolved intents: {intents}"


def start_cross_txn(cluster, k1, k2, val=b"new"):
    """Inject a 2-participant transaction directly at the coordinator
    (bypassing client retries so each test controls exactly one 2PC
    instance).  Returns (coordinator replica, txid, result box)."""
    rid1, rid2 = cluster.range_of(k1), cluster.range_of(k2)
    coord = cluster.leader_replica(rid1)
    assert coord is not None
    box = []
    groups = {rid1: [WriteOp(OpType.PUT, k1, "a", val)],
              rid2: [WriteOp(OpType.PUT, k2, "a", val)]}
    coord.client_txn2(groups, box.append)
    assert len(coord.txn.active) == 1
    txid = next(iter(coord.txn.active))
    return coord, txid, box


# --------------------------------------------------------------- steady state

def test_cross_range_conditional_abort_is_atomic():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k1, k2 = two_range_keys(cluster)
    assert c.sync_put(k1, "a", b"base1").ok         # version 1
    assert c.sync_put(k2, "a", b"base2").ok
    ops = [WriteOp(OpType.COND_PUT, k1, "a", b"x", expected_version=1),
           WriteOp(OpType.COND_PUT, k2, "a", b"x", expected_version=99)]
    res = sync(sim, c.transaction, ops)
    assert res.code == ErrorCode.VERSION_MISMATCH
    # nothing from either leg is visible, versions unmoved
    assert c.sync_get(k1, "a").value == b"base1"
    assert c.sync_get(k1, "a").version == 1
    assert c.sync_get(k2, "a").value == b"base2"
    sim.run_for(2.0)
    assert_clean(cluster)


def test_cross_range_commit_reports_all_versions():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k1, k2 = two_range_keys(cluster)
    c.sync_put(k1, "a", b"v1")
    ops = [WriteOp(OpType.PUT, k1, "a", b"w1"),
           WriteOp(OpType.PUT, k2, "a", b"w2")]
    res = sync(sim, c.transaction, ops)
    assert res.ok
    versions = dict(((k, col), v) for k, col, v in res.value)
    assert versions[(k1, "a")] == 2      # on top of the preload
    assert versions[(k2, "a")] == 1
    # conditional pipelining stays correct after a 2PC commit: CAS at the
    # reported version must succeed exactly once
    assert c.sync_cond_put(k1, "a", b"w1b", 2).ok
    assert c.sync_cond_put(k1, "a", b"w1c", 2).code \
        == ErrorCode.VERSION_MISMATCH


def test_fastpath_engages_no_2pc_machinery():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k1, k2 = key_of(5), key_of(6)
    assert cluster.range_of(k1) == cluster.range_of(k2)
    res = sync(sim, c.transaction,
               [WriteOp(OpType.PUT, k1, "a", b"1"),
                WriteOp(OpType.PUT, k2, "a", b"2")])
    assert res.ok
    assert c.txn2_issued == 0
    assert not cluster.zk.get_children("/txn")
    for node in cluster.nodes.values():
        for rep in node.replicas.values():
            assert rep.txn.prepares == 0
            assert rep.txn.locks == {}
    # and the log carries no 2PC records at all
    for node in cluster.nodes.values():
        for e in node.wal.durable:
            assert getattr(e, "op", None) not in TXN_OPS


def test_multi_get_fans_out_once_per_range():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    idxs = [10, 15, 100, 160, 280]          # spans several base ranges
    for i in idxs:
        c.sync_put(key_of(i), "c", f"v{i}".encode())
    pairs = [(key_of(i), "c") for i in idxs]
    rids = {cluster.range_of(key_of(i)) for i in idxs}
    assert 2 < len(rids) < len(idxs)        # batching must be visible
    before = c.mread_batches
    rs = sync(sim, lambda cb: c.multi_get(pairs, True, cb))
    assert c.mread_batches - before == len(rids)
    assert [r.value for r in rs] == [f"v{i}".encode() for i in idxs]
    # absent keys surface as NOT_FOUND slots, present ones keep order
    rs = sync(sim, lambda cb: c.multi_get(
        [(key_of(10), "c"), (key_of(11), "c")], True, cb))
    assert rs[0].ok and rs[1].code == ErrorCode.NOT_FOUND


def test_multi_get_follows_split_redirects():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    idxs = [20, 30, 40, 50]
    for i in idxs:
        c.sync_put(key_of(i), "c", f"v{i}".encode())
    rid = cluster.range_of(key_of(20))
    c.multi_get([(key_of(i), "c") for i in idxs], True, lambda rs: None)
    assert cluster.admin_split(rid, key_of(35))
    sim.run_for(3.0)
    cluster.settle()
    rs = sync(sim, lambda cb: c.multi_get(
        [(key_of(i), "c") for i in idxs], True, cb))
    assert [r.value for r in rs] == [f"v{i}".encode() for i in idxs]
    assert cluster.range_of(key_of(20)) != cluster.range_of(key_of(50))


# ---------------------------------------------------------- recovery edges

def test_coordinator_killed_before_prepares_delivered():
    """Stage 1 kill: intent written, prepares still in flight — the
    in-flight messages die with the node, the next leader of the
    coordinator range presumed-aborts the orphan intent."""
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k1, k2 = two_range_keys(cluster)
    coord, txid, box = start_cross_txn(cluster, k1, k2)
    assert cluster.zk.exists(f"/txn/{txid}")
    cluster.crash_node(coord.node.node_id)     # prepares never delivered
    sim.run_for(10.0)
    cluster.settle()
    assert_clean(cluster)
    assert c.sync_get(k1, "a").code == ErrorCode.NOT_FOUND
    assert c.sync_get(k2, "a").code == ErrorCode.NOT_FOUND


def test_coordinator_killed_after_all_prepares():
    """Stage 2 kill: every participant holds a committed prepare (locks
    held, votes possibly in flight), the decision may or may not have
    reached the coordinator's log.  Whatever the interleaving, the
    outcome must be atomic and fully resolved without operator help."""
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k1, k2 = two_range_keys(cluster)
    rid2 = cluster.range_of(k2)
    coord, txid, box = start_cross_txn(cluster, k1, k2)

    def both_prepared():
        p1 = coord.txn.prepared.get(txid)
        rep2 = cluster.leader_replica(rid2)
        p2 = rep2.txn.prepared.get(txid) if rep2 else None
        return p1 is not None and p1.committed \
            and p2 is not None and p2.committed

    drive_until(sim, both_prepared)
    cluster.crash_node(coord.node.node_id)
    sim.run_for(12.0)
    cluster.settle()
    assert_clean(cluster)
    r1, r2 = c.sync_get(k1, "a"), c.sync_get(k2, "a")
    assert (r1.ok and r2.ok and r1.value == r2.value == b"new") \
        or (r1.code == ErrorCode.NOT_FOUND
            and r2.code == ErrorCode.NOT_FOUND), (r1.code, r2.code)


def test_coordinator_killed_after_decision_logged():
    """Stage 3 kill: the commit decision is in the coordinator range's
    log (the client was acked) but the decides are lost with the node.
    The next leader re-drives the commit from the log + intent znode —
    the acked transaction must not be lost."""
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k1, k2 = two_range_keys(cluster)
    coord, txid, box = start_cross_txn(cluster, k1, k2)
    drive_until(sim, lambda: txid in coord.txn.decided)
    assert box and box[0].ok          # decision applied => client acked
    cluster.crash_node(coord.node.node_id)
    sim.run_for(12.0)
    cluster.settle()
    assert_clean(cluster)
    assert c.sync_get(k1, "a").value == b"new"
    assert c.sync_get(k2, "a").value == b"new"


def test_participant_leader_killed_holding_locks():
    """Participant leader dies after logging its prepare: the promoted
    follower inherits locks + prepared state from the log and the
    transaction still resolves atomically."""
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k1, k2 = two_range_keys(cluster)
    rid2 = cluster.range_of(k2)
    coord, txid, box = start_cross_txn(cluster, k1, k2)
    rep2 = cluster.leader_replica(rid2)

    def p2_prepared():
        p = rep2.txn.prepared.get(txid)
        return p is not None and p.committed

    drive_until(sim, p2_prepared)
    assert rep2.txn.locks.get(k2) == txid
    victim = rep2.node.node_id
    cluster.crash_node(victim)
    # the prepared state the promoted leader will inherit lives in the
    # surviving cohort members' logs, not in anyone's memory
    survivors = [cluster.nodes[m] for m in cluster.members[rid2]
                 if m != victim and cluster.nodes[m].up]
    assert any(getattr(e, "txn", None) is not None and e.txn[0] == txid
               for node in survivors for e in node.wal.durable)
    sim.run_for(12.0)
    cluster.settle()
    assert_clean(cluster)
    r1, r2 = c.sync_get(k1, "a"), c.sync_get(k2, "a")
    assert (r1.ok and r2.ok) or (r1.code == ErrorCode.NOT_FOUND
                                 and r2.code == ErrorCode.NOT_FOUND)


def test_prepare_timeout_under_participant_partition():
    """A participant leader is cut off by a symmetric partition (not a
    crash) with the prepare in flight: the coordinator presumed-aborts
    within `txn_prepare_timeout` instead of blocking on the dead link,
    no lock or intent survives the heal, neither leg is visible, and a
    post-heal transfer over the same keys lands and persists."""
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k1 = key_of(10)
    coord0 = cluster.leader_replica(cluster.range_of(k1))
    k2 = remote_partner_key(cluster, coord0)
    rid2 = cluster.range_of(k2)
    victim = cluster.leader_replica(rid2).node.node_id
    coord, txid, box = start_cross_txn(cluster, k1, k2)
    # cut the participant leader from everyone (in-flight prepares die at
    # delivery time too); ZK heartbeats are out-of-band so the victim
    # keeps its session — only its lease can depose it
    cluster.partition({victim},
                      {n for n in cluster.nodes if n != victim})
    t0 = sim.now
    drive_until(sim, lambda: bool(box),
                budget=coord.cfg.txn_prepare_timeout + 2.0)
    assert box[0].code == ErrorCode.UNAVAILABLE
    assert sim.now - t0 <= coord.cfg.txn_prepare_timeout + 1.0
    assert txid not in coord.txn.active
    # atomicity: the coordinator-side leg must not be visible either
    assert c.sync_get(k1, "a").code == ErrorCode.NOT_FOUND
    cluster.heal()
    sim.run_for(6.0)
    cluster.settle()
    assert_clean(cluster)
    assert c.sync_get(k2, "a").code == ErrorCode.NOT_FOUND
    # the same cross-range write works once the partition is gone, and
    # the acked transfer is durable across a full resolution period
    res = sync(sim, c.transaction,
               [WriteOp(OpType.PUT, k1, "a", b"after"),
                WriteOp(OpType.PUT, k2, "a", b"after")])
    assert res.ok
    sim.run_for(2.0)
    assert c.sync_get(k1, "a").value == b"after"
    assert c.sync_get(k2, "a").value == b"after"
    assert_clean(cluster)


def test_timeline_and_strong_read_isolation_in_doubt():
    """While a transaction is in doubt (prepare committed, coordinator
    dead): timeline reads serve the old committed value — never staged
    data — and strong reads defer until resolution, then return the
    outcome-consistent value."""
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k1 = key_of(10)
    coord0 = cluster.leader_replica(cluster.range_of(k1))
    k2 = remote_partner_key(cluster, coord0)
    rid2 = cluster.range_of(k2)
    c.sync_put(k2, "a", b"old")
    coord, txid, box = start_cross_txn(cluster, k1, k2)
    rep2 = cluster.leader_replica(rid2)
    drive_until(sim, lambda: (p := rep2.txn.prepared.get(txid)) is not None
                and p.committed and txid not in coord.txn.decided)
    # crash without instant session expiry: the in-doubt window stays open
    # until the session times out and a new coordinator-range leader
    # presumed-aborts the intent
    cluster.crash_node(coord.node.node_id, expire_session=False)
    # timeline read: served immediately from committed state
    r = sync(sim, lambda cb: c.get(k2, "a", False, cb))
    assert r.ok and r.value == b"old"
    deferred_before = rep2.txn.reads_deferred
    # strong read: defers on the lock, resolves to the abort outcome
    r = sync(sim, lambda cb: c.get(k2, "a", True, cb), budget=15.0)
    assert r.ok and r.value == b"old" and r.version == 1
    assert rep2.txn.reads_deferred > deferred_before
    sim.run_for(3.0)
    assert_clean(cluster)


def test_write_to_locked_key_retries_until_lock_clears():
    """No-wait locks: a plain put against a locked key bounces with
    LOCKED, the client's backoff retries, and it lands once the
    transaction resolves — serialized after it."""
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k1, k2 = two_range_keys(cluster)
    coord, txid, box = start_cross_txn(cluster, k1, k2)
    rid2 = cluster.range_of(k2)
    rep2 = cluster.leader_replica(rid2)
    drive_until(sim, lambda: rep2.txn.locks.get(k2) == txid)
    res = sync(sim, c.put, k2, "a", b"after")
    assert res.ok
    assert res.version == 2            # serialized after the staged write
    assert c.lock_retries >= 1
    assert c.sync_get(k2, "a").value == b"after"


def test_concurrent_transfers_conserve_money():
    """Two clients hammer transfers over the same 4 accounts spanning 2
    ranges; no-wait aborts + retries must never lose or mint money."""
    sim, cluster = make_cluster()
    idxs = [10, 11, 200, 201]
    keys = [key_of(i) for i in idxs]
    clients = [cluster.make_client(f"c{i}") for i in range(2)]
    for k in keys:
        clients[0].sync_put(k, "c", 100)
    done = [0]
    rng = sim.rng

    def transfer(c, n_left):
        if n_left == 0:
            done[0] += 1
            return
        src, dst = rng.sample(keys, 2)

        def after_reads(rs):
            r1, r2 = rs
            if not (r1.ok and r2.ok):
                sim.schedule(0.01, transfer, c, n_left)
                return
            ops = [WriteOp(OpType.COND_PUT, src, "c", r1.value - 1,
                           expected_version=r1.version),
                   WriteOp(OpType.COND_PUT, dst, "c", r2.value + 1,
                           expected_version=r2.version)]
            c.transaction(ops, lambda res: transfer(c, n_left - 1))

        c.multi_get([(src, "c"), (dst, "c")], True, after_reads)

    for c in clients:
        transfer(c, 30)
    deadline = sim.now + 60.0
    while done[0] < 2 and sim.now < deadline:
        sim.run(until=sim.now + 0.25)
    assert done[0] == 2
    sim.run_for(3.0)
    total = sum(clients[0].sync_get(k, "c").value for k in keys)
    assert total == 400
    assert_clean(cluster)


def test_gc_floor_keeps_prepare_through_log_rollover():
    """An unresolved prepare pins the WAL GC floor: heavy churn rolls the
    log over around it, and a full node restart still recovers the
    prepared state (locks included) from the surviving record."""
    sim, cluster = make_cluster(wal_segment_bytes=8 << 10)
    for node in cluster.nodes.values():
        for rep in node.replicas.values():
            rep.store.flush_threshold = 4 << 10
    c = cluster.make_client()
    k1 = key_of(10)
    coord0 = cluster.leader_replica(cluster.range_of(k1))
    k2 = remote_partner_key(cluster, coord0)
    rid2 = cluster.range_of(k2)
    idx2 = int(k2[1:])
    lo_idx = (idx2 // 60) * 60          # base range width = 300 / 5
    churn = [i for i in range(lo_idx, lo_idx + 45) if i != idx2][:40]

    def churn_round():
        for i in churn:
            for _ in range(3):
                assert c.sync_put(key_of(i), "c", b"y" * 400).ok

    node2 = cluster.leader_replica(rid2).node
    churn_round()                       # pre-txn churn: normally GC-able
    assert node2.wal._gc_dropped_upto.get(rid2, 0) > 0, "GC never ran"
    coord, txid, box = start_cross_txn(cluster, k1, k2)
    rep2 = cluster.leader_replica(rid2)
    drive_until(sim, lambda: (p := rep2.txn.prepared.get(txid)) is not None
                and p.committed)
    cluster.crash_node(coord.node.node_id, expire_session=False)
    node2 = rep2.node
    prep_lsn = rep2.txn.prepared[txid].record.lsn
    assert node2.wal.gc_floor.get(rid2) == prep_lsn
    churn_round()                       # post-prepare churn: rolls the log
    assert any(getattr(e, "lsn", None) == prep_lsn
               for e in node2.wal.durable), "prepare record was GC'd"
    # full restart of the participant leader: prepared state must come
    # back from the log scan (boot-time recovery is synchronous, so the
    # check runs before the in-doubt abort can resolve it)
    cluster.crash_node(node2.node_id)
    sim.run_for(0.2)
    cluster.restart_node(node2.node_id)
    assert txid in node2.replicas[rid2].txn.prepared
    assert node2.replicas[rid2].txn.locks.get(k2) == txid
    # now let the system resolve the in-doubt txn (presumed abort) ...
    sim.run_for(12.0)
    cluster.settle()
    assert_clean(cluster)
    assert c.sync_get(k2, "a").code == ErrorCode.NOT_FOUND
    # ... which lifts the floor: later churn can GC past the prepare
    assert node2.wal.gc_floor.get(rid2) is None
    churn_round()
    assert node2.wal._gc_dropped_upto.get(rid2, 0) > prep_lsn


@pytest.mark.slow
def test_contention_sweep_conserves_money_under_leader_kills():
    """Long zipfian contention sweep with repeated coordinator kills:
    the balance sum closes and no acked transfer is lost."""
    import warnings
    warnings.filterwarnings("ignore")
    from repro.workload import (ExperimentConfig, WorkloadSpec,
                                run_spinnaker_txn)
    spec = WorkloadSpec(num_keys=500, key_dist="zipfian", zipf_theta=0.8,
                        read_frac=0.1, write_frac=0, rmw_frac=0,
                        cond_frac=0, txn_frac=0.9, value_size=64)
    cfg = ExperimentConfig(n_nodes=5, disk="mem", n_clients=24,
                           warmup=0.5, duration=12.0, window=0.5,
                           preload_cap=500)
    sched = "\n".join(["at 2.0s crash txn coordinator",
                       "at 5.0s restart crashed",
                       "at 7.0s crash txn coordinator",
                       "at 10.0s restart crashed"])
    r = run_spinnaker_txn(spec, cfg, cross_frac=0.6, schedule=sched)
    t = r["txn"]
    assert not t["lost_acked_txns"]
    assert not t["partial_commit"], (t["balance_read"],
                                     t["balance_expected"])
    assert not t["unresolved_intents"] and t["leftover_locks"] == 0
    assert t["txn_commits"] > 0
