"""Multi-operation transactions (§8.2) and monotonic timeline sessions."""

import pytest

from repro.core import (ClusterConfig, ErrorCode, NodeConfig, OpType,
                        ReplicaConfig, Simulator, SpinnakerCluster, WriteOp,
                        key_of)


def make_cluster(n=3, seed=0, commit_period=1.0):
    sim = Simulator(seed=seed)
    cfg = ClusterConfig(
        n_nodes=n,
        node=NodeConfig(replica=ReplicaConfig(commit_period=commit_period)))
    cluster = SpinnakerCluster(sim, cfg)
    cluster.start()
    cluster.settle()
    return sim, cluster


def sync(sim, fn, *args, budget=10.0):
    box = []
    fn(*args, lambda r: box.append(r))
    deadline = sim.now + budget
    while not box and sim.now < deadline:
        sim.run(until=sim.now + 0.05)
    assert box, "op did not complete"
    return box[0]


def test_transaction_commits_all_ops():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k1, k2 = key_of(5), key_of(6)
    ops = [WriteOp(OpType.PUT, k1, "a", b"1"),
           WriteOp(OpType.PUT, k1, "b", b"2"),
           WriteOp(OpType.PUT, k2, "a", b"3")]
    assert cluster.range_of(k1) == cluster.range_of(k2)
    res = sync(sim, c.transaction, ops)
    assert res.ok
    assert c.sync_get(k1, "a").value == b"1"
    assert c.sync_get(k1, "b").value == b"2"
    assert c.sync_get(k2, "a").value == b"3"


def test_transaction_conditional_abort_leaves_nothing():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k = key_of(5)
    c.sync_put(k, "x", b"base")            # version 1
    ops = [WriteOp(OpType.PUT, k, "y", b"new"),
           WriteOp(OpType.COND_PUT, k, "x", b"clobber",
                   expected_version=99)]   # mismatches -> abort
    res = sync(sim, c.transaction, ops)
    assert res.code == ErrorCode.VERSION_MISMATCH
    # nothing from the transaction is visible
    assert c.sync_get(k, "y").code == ErrorCode.NOT_FOUND
    assert c.sync_get(k, "x").value == b"base"


def test_transaction_spans_ranges_via_2pc():
    # PR 4: cross-range op sets no longer bounce — they run through the
    # Paxos-backed 2PC coordinator (core/txn.py) and commit atomically
    sim, cluster = make_cluster(n=5)
    c = cluster.make_client()
    keys = [key_of(1), key_of(99_000)]
    assert cluster.range_of(keys[0]) != cluster.range_of(keys[1])
    ops = [WriteOp(OpType.PUT, keys[0], "a", b"1"),
           WriteOp(OpType.PUT, keys[1], "a", b"2")]
    res = sync(sim, c.transaction, ops)
    assert res.ok
    assert c.txn2_issued >= 1           # took the 2PC path, not the fast one
    assert c.sync_get(keys[0], "a").value == b"1"
    assert c.sync_get(keys[1], "a").value == b"2"
    # fully resolved: no leftover locks, prepared state, or intent znodes
    sim.run_for(2.0)
    for node in cluster.nodes.values():
        for rep in node.replicas.values():
            assert not rep.txn.locks and not rep.txn.prepared
    assert not cluster.zk.get_children("/txn")


def test_transaction_survives_leader_failover():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    k = key_of(5)
    ops = [WriteOp(OpType.PUT, k, "a", b"1"),
           WriteOp(OpType.PUT, k, "b", b"2")]
    res = sync(sim, c.transaction, ops)
    assert res.ok
    rid = cluster.range_of(k)
    leader = cluster.leader_replica(rid)
    cluster.crash_node(leader.node.node_id)
    sim.run_for(6.0)
    # both columns survive the failover (they were quorum-committed)
    assert c.sync_get(k, "a").value == b"1"
    assert c.sync_get(k, "b").value == b"2"


def test_monotonic_timeline_session_never_goes_backwards():
    sim, cluster = make_cluster(commit_period=5.0)   # followers lag 5s
    c = cluster.make_client()
    k = key_of(5)
    c.sync_put(k, "c", b"v1")
    sim.run_for(6.0)                 # all replicas at v1
    c.sync_put(k, "c", b"v2")        # only the leader has v2 applied
    seen = []
    for _ in range(12):
        res = sync(sim, lambda cb: c.get(k, "c", False, cb, monotonic=True))
        if res.ok:
            seen.append(res.version)
    # plain timeline reads WOULD bounce 2,1,2,1...; the session must not
    for a, b in zip(seen, seen[1:]):
        assert b >= a, f"monotonic session regressed: {seen}"
    assert seen and seen[-1] >= 1


def test_plain_timeline_reads_can_be_stale_for_contrast():
    sim, cluster = make_cluster(commit_period=5.0)
    c = cluster.make_client()
    k = key_of(5)
    c.sync_put(k, "c", b"v1")
    sim.run_for(6.0)
    c.sync_put(k, "c", b"v2")
    versions = set()
    for _ in range(12):
        res = sync(sim, lambda cb: c.get(k, "c", False, cb))
        if res.ok:
            versions.add(res.version)
    # both the fresh and the stale version should be observable
    assert 2 in versions and 1 in versions
