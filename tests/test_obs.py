"""Observability layer: sim-time span tracer, per-node metrics registry,
structured cluster event log, and the write-path latency breakdown.

The load-bearing invariants:

- sampling is a deterministic error-diffusion accumulator (rate-exact,
  never touches the simulator RNG, so tracing cannot perturb a run);
- a complete trace's stage durations sum exactly to its end-to-end
  latency (the chain *partitions* the write path);
- every acked write on a live cluster carries the full
  propose -> quorum-ack -> commit -> apply chain, and every committed
  cross-range 2PC txn the full prepare -> vote -> decide -> resolve
  chain (`audit_writes` / `audit_txns`);
- a traced run is op-for-op identical to an untraced one.
"""

import json
import math

import pytest

from repro.core import (ClusterConfig, OpType, Simulator, SpinnakerCluster,
                        WriteOp, key_of)
from repro.core.ranges import BalancerConfig
from repro.obs import ObsConfig
from repro.obs.events import EventLog
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import OpTrace, Tracer, stage_breakdown
from repro.workload import (ExperimentConfig, WorkloadSpec,
                            run_spinnaker_workload)


def make_cluster(n=5, seed=0, **obs_kw):
    sim = Simulator(seed=seed)
    cfg = ClusterConfig(n_nodes=n, obs=ObsConfig(**obs_kw))
    cluster = SpinnakerCluster(sim, cfg)
    cluster.start()
    cluster.settle()
    return sim, cluster


def sync(sim, fn, *args, budget=10.0):
    box = []
    fn(*args, lambda r: box.append(r))
    deadline = sim.now + budget
    while not box and sim.now < deadline:
        sim.run(until=sim.now + 0.05)
    assert box, "op did not complete"
    return box[0]


# ---------------------------------------------------------------------------
# tracer mechanics (no cluster)
# ---------------------------------------------------------------------------


def test_sampling_is_deterministic_and_rate_exact():
    sim = Simulator(seed=0)
    for rate, want in ((1.0, 1000), (0.5, 500), (0.25, 250), (0.0, 0)):
        tr = Tracer(sim, "spinnaker", sample=rate)
        got = sum(tr.maybe_start("write", "write", "k") is not None
                  for _ in range(1000))
        # error diffusion: exact over any window (binary-exact rates),
        # not just in expectation
        assert got == want, (rate, got)
    tr = Tracer(sim, "spinnaker", sample=0.1)
    got = sum(tr.maybe_start("write", "write", "k") is not None
              for _ in range(1000))
    assert abs(got - 100) <= 1             # fp accumulation slack only
    # same sequence twice -> identical sampling decisions
    a = Tracer(sim, "spinnaker", sample=0.37)
    b = Tracer(sim, "spinnaker", sample=0.37)
    pa = [a.maybe_start("w", "write", "k") is not None for _ in range(500)]
    pb = [b.maybe_start("w", "write", "k") is not None for _ in range(500)]
    assert pa == pb
    assert sum(pa) == pytest.approx(0.37 * 500, abs=1)


def test_disabled_tracer_samples_nothing():
    sim = Simulator(seed=0)
    tr = Tracer(sim, "spinnaker", sample=1.0, enabled=False)
    assert tr.maybe_start("write", "write", "k") is None
    assert tr.txn_begin("tx1", 0, [0, 1]) is None
    tr.txn_mark("tx1", "vote", 0)          # no-op, must not raise
    assert tr.audit_writes()["ok"] and tr.audit_txns()["ok"]


def test_stages_partition_e2e_exactly():
    t = OpTrace(trace_id=1, kind="write", path="write", key="k",
                system="spinnaker", t_issue=1.0, t_send=1.001,
                t_recv=1.0015, t_cpu=1.0016, t_flush=1.0018,
                t_forced=1.0021, t_commit=1.0027, t_acked=1.0027,
                t_done=1.0031)
    t.ok = True
    assert t.complete()
    assert sum(t.stages().values()) == pytest.approx(t.e2e, abs=1e-12)
    assert set(t.stages()) == {"client_queue", "net_req", "cpu",
                               "batch_wait", "wal_force", "commit_wait",
                               "ack_coalesce", "reply_net"}


def test_audit_flags_incomplete_acked_write():
    sim = Simulator(seed=0)
    tr = Tracer(sim, "spinnaker", sample=1.0)
    good = tr.maybe_start("write", "write", "k1")
    good.t_send = good.t_recv = good.t_cpu = good.t_flush = 0.0
    good.t_forced = good.t_commit = good.t_acked = 0.0
    tr.finish(good, True, "OK")
    assert tr.audit_writes()["ok"]
    bad = tr.maybe_start("write", "write", "k2")
    bad.t_send = bad.t_recv = 0.0          # never reached the WAL
    tr.finish(bad, True, "OK")
    audit = tr.audit_writes()
    assert not audit["ok"] and audit["incomplete"] == 1
    assert "t_commit" in audit["violations"][0]["missing"]
    # failed ops are exempt: the chain only owes acked writes
    nak = tr.maybe_start("write", "write", "k3")
    tr.finish(nak, False, "TIMEOUT")
    assert tr.audit_writes()["incomplete"] == 1


def test_stage_breakdown_reconstructs_known_median():
    sim = Simulator(seed=0)
    tr = Tracer(sim, "spinnaker", sample=1.0)
    # 100 synthetic writes, all identical: every stage mean is exact
    for i in range(100):
        t = tr.maybe_start("write", "write", f"k{i}")
        t.t_send = t.t_issue + 0.0001
        t.t_recv = t.t_send + 0.0004
        t.t_cpu = t.t_recv + 0.0001
        t.t_flush = t.t_cpu + 0.0002
        t.t_forced = t.t_flush + 0.0001
        t.t_commit = t.t_forced + 0.0005
        t.t_acked = t.t_commit             # envelope flush is same-instant
        tr.finish(t, True, "OK")
        t.t_done = t.t_acked + 0.0004      # finish() stamped sim.now; undo
    bd = stage_breakdown(tr.traces, kind="write")
    assert bd["n_traces"] == 100
    assert bd["stage_sum_p50_ms"] == pytest.approx(bd["p50_ms"], rel=1e-6)
    assert bd["stages_p50_ms"]["net_req"] == pytest.approx(0.4, rel=1e-6)
    assert bd["stages_p50_ms"]["commit_wait"] == pytest.approx(0.5, rel=1e-6)
    assert len(bd["top_slowest"]) == 10
    assert stage_breakdown([], kind="write")["n_traces"] == 0


# ---------------------------------------------------------------------------
# live-cluster chains
# ---------------------------------------------------------------------------


def test_live_write_trace_complete_and_partitions_latency():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    res = sync(sim, c.put, key_of(3), "c", b"v")
    assert res.ok
    traces = [t for t in cluster.obs.tracer.traces if t.path == "write"]
    assert traces, "write was not sampled at trace_sample=1.0"
    t = traces[-1]
    assert t.complete(), t.missing()
    assert sum(t.stages().values()) == pytest.approx(t.e2e, abs=1e-12)
    assert t.attempts == 1 and t.lsn is not None
    assert cluster.obs.tracer.audit_writes()["ok"]


def test_live_cross_range_txn_chain_complete():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    keys = [key_of(1), key_of(99_000)]
    assert cluster.range_of(keys[0]) != cluster.range_of(keys[1])
    ops = [WriteOp(OpType.PUT, keys[0], "a", b"1"),
           WriteOp(OpType.PUT, keys[1], "a", b"2")]
    res = sync(sim, c.transaction, ops)
    assert res.ok and c.txn2_issued >= 1
    sim.run_for(2.0)                       # let resolves land everywhere
    audit = cluster.obs.tracer.audit_txns()
    assert audit["ok"], audit
    assert audit["committed_txns"] == 1 and audit["acked_txns"] == 1
    (txn,) = cluster.obs.tracer.txns.values()
    assert len(txn.participants) == 2
    assert txn.outcome == "commit"
    # chain ordering: prepares precede votes precede decide and resolves
    for rid in txn.participants:
        assert txn.prepare_sent[rid] <= txn.voted[rid] <= txn.t_decided
        assert txn.t_decided <= txn.resolved[rid]
    # the client op trace over the txn path also closed its chain
    assert cluster.obs.tracer.audit_writes()["ok"]


def test_tracing_does_not_perturb_the_run():
    spec = WorkloadSpec(num_keys=100, value_size=256,
                        read_frac=0.5, write_frac=0.5, rmw_frac=0,
                        cond_frac=0)
    outs = []
    for sample in (1.0, 0.0):
        cfg = ExperimentConfig(n_nodes=3, disk="mem", n_clients=2,
                               warmup=0.2, duration=1.5, preload_cap=50,
                               trace_sample=sample)
        outs.append(run_spinnaker_workload(spec, cfg))
    on, off = outs
    # zero modeled cost: the traced run is op-for-op the untraced run
    assert on["total_ops"] == off["total_ops"]
    assert on["writes"]["count"] == off["writes"]["count"]
    assert on["writes"]["p99_ms"] == pytest.approx(off["writes"]["p99_ms"])
    assert on["trace_audit"]["acked_writes_traced"] > 0
    assert on["trace_audit"]["ok"]
    assert off["trace_audit"]["acked_writes_traced"] == 0


# ---------------------------------------------------------------------------
# metrics registry + event log
# ---------------------------------------------------------------------------


def test_metrics_scrape_series_and_summary():
    sim = Simulator(seed=0)
    reg = MetricsRegistry(sim, interval=0.1)
    box = {"v": 0.0}
    reg.add_gauge(2, "queue_depth", lambda: box["v"])
    reg.add_gauge(3, "broken", lambda: 1 / 0)     # tolerated, not exported
    reg.start()
    for i in range(5):
        sim.schedule(0.1 * i + 0.01, lambda i=i: (
            reg.inc(1, "writes", 10), box.__setitem__("v", float(i))))
    sim.run(until=0.55)
    reg.stop()      # emits the final tail scrape at t=0.55
    exp = reg.export()
    assert "n3.broken" not in exp
    writes = exp["n1.writes"]
    assert len(writes) == 6
    assert writes[-1][0] == pytest.approx(0.55)
    # counters export cumulatively
    assert [v for _, v in writes] == [10.0, 20.0, 30.0, 40.0, 50.0, 50.0]
    gauge = exp["n2.queue_depth"]
    assert [v for _, v in gauge] == [0.0, 1.0, 2.0, 3.0, 4.0, 4.0]
    s = reg.summary()
    assert s["n1.writes"]["last"] == 50.0 and s["n1.writes"]["max"] == 50.0
    assert s["n2.queue_depth"]["mean"] == pytest.approx(14 / 6)


def test_metrics_ticker_not_armed_without_start():
    sim = Simulator(seed=0)
    reg = MetricsRegistry(sim, interval=0.0)
    reg.inc(0, "x")
    reg.start()                            # interval 0: stays unarmed
    sim.run_until_idle()                   # must terminate
    assert reg.export() == {}


def test_event_log_export_relative_and_filtered():
    sim = Simulator(seed=0)
    log = EventLog(sim, cap=3)
    for t, kind in ((0.5, "election"), (1.5, "split"), (2.5, "fault")):
        sim.schedule(t, lambda k=kind: log.emit(k, rid=0))
    for _ in range(3):
        sim.schedule(2.8, lambda: log.emit("overflow"))
    sim.run(until=3.0)
    assert log.dropped == 3                # cap=3 held
    out = log.export(t0=1.0)
    assert [e["kind"] for e in out] == ["split", "fault"]
    assert out[0]["t"] == pytest.approx(0.5) and out[0]["rid"] == 0
    only = log.export(kinds={"election"})
    assert [e["kind"] for e in only] == ["election"]


def test_cluster_emits_election_events():
    sim, cluster = make_cluster(n=3)
    kinds = {e["kind"] for e in cluster.obs.events.events}
    assert "leader_open" in kinds
    rid0 = cluster.leader_replica(0)
    cluster.crash_node(rid0.node.node_id)
    sim.run_for(6.0)
    kinds = {e["kind"] for e in cluster.obs.events.events}
    assert "node_crash" in kinds and "leader_takeover" in kinds


def test_node_gauges_registered_per_node():
    sim, cluster = make_cluster(n=3, metrics_interval=0.5)
    sim.run_for(1.2)
    exp = cluster.obs.metrics.export()
    for node_id in cluster.nodes:
        key = f"n{node_id}.wal_forces"
        assert key in exp and len(exp[key]) >= 2
    assert any(k.endswith(".cpu_queue_s") for k in exp)


def test_histogram_metric_observe_scrape_and_summary():
    sim = Simulator(seed=0)
    reg = MetricsRegistry(sim, interval=0.1)
    reg.start()
    samples = [0.001, 0.002, 0.004, 0.008, 0.0005]   # seconds
    for i, v in enumerate(samples):
        sim.schedule(0.05 + 0.1 * i,
                     lambda v=v: reg.observe(1, "lock_wait_s", v))
    sim.run(until=0.55)
    reg.stop()
    # histograms scrape their cumulative sample count like a counter
    series = reg.export()["n1.lock_wait_s"]
    assert [v for _, v in series] == [1, 2, 3, 4, 5, 5]
    s = reg.summary()["n1.lock_wait_s"]
    assert s["count"] == 5
    assert s["mean_ms"] == pytest.approx(
        sum(samples) / len(samples) * 1e3, rel=1e-9)
    # log-binned: p50 lands on the 2 ms sample's bin edge (≤3.3% error)
    assert 1.5 <= s["p50_ms"] <= 3.0
    assert s["p99_ms"] >= s["p50_ms"]


def test_event_log_to_jsonl_stable_field_order():
    sim = Simulator(seed=0)
    log = EventLog(sim)
    log.emit("split", rid=3, parent=0)
    sim.schedule(1.0, lambda: log.emit("move", z_last=1, a_first=2, rid=4))
    sim.run(until=2.0)
    out = log.to_jsonl()
    assert out.endswith("\n")
    lines = out.splitlines()
    assert len(lines) == 2
    # stable ordering: t, kind, then remaining fields sorted by name
    assert list(json.loads(lines[0])) == ["t", "kind", "parent", "rid"]
    assert list(json.loads(lines[1])) == ["t", "kind", "a_first", "rid",
                                          "z_last"]
    assert json.loads(lines[1])["kind"] == "move"
    assert log.to_jsonl(kinds={"move"}).splitlines() == [lines[1]]
    assert EventLog(sim).to_jsonl() == ""


# ---------------------------------------------------------------------------
# resource profiler
# ---------------------------------------------------------------------------


def test_profiler_attribution_matches_measured_busy():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    for i in range(30):
        assert sync(sim, c.put, key_of(i % 20), "c", b"v").ok
    for i in range(10):
        assert sync(sim, c.get, key_of(i), "c", True).ok
    prof = cluster.obs.profiler.summary()
    assert prof["nodes"]
    for nid, nb in prof["nodes"].items():
        # every modeled busy second carries a component label: attribution
        # sums match the servers' measured totals (the 5% gate, here exact
        # up to rounding)
        if nb["cpu_busy_s"] > 1e-9:
            assert nb["cpu_attributed_s"] == pytest.approx(
                nb["cpu_busy_s"], rel=0.05), (nid, nb)
        if nb["disk_busy_s"] > 1e-9:
            assert nb["disk_attributed_s"] == pytest.approx(
                nb["disk_busy_s"], rel=0.05), (nid, nb)
    shares = prof["cpu_share_by_component"]
    assert shares and sum(shares.values()) == pytest.approx(1.0, abs=0.01)
    assert any(c.startswith("paxos.") for c in shares)
    # per-range heat saw every served client op
    heat = prof["heat"]
    assert sum(h["ops"] for h in heat.values()) >= 40
    assert sum(h["bytes"] for h in heat.values()) > 0


def test_profiler_does_not_perturb_the_run():
    spec = WorkloadSpec(num_keys=100, value_size=256,
                        read_frac=0.5, write_frac=0.5, rmw_frac=0,
                        cond_frac=0)
    outs = []
    for profile in (True, False):
        cfg = ExperimentConfig(n_nodes=3, disk="mem", n_clients=2,
                               warmup=0.2, duration=1.5, preload_cap=50,
                               profile=profile,
                               profile_interval=0.25 if profile else 0.0)
        outs.append(run_spinnaker_workload(spec, cfg))
    on, off = outs
    # pure accounting: the profiled run is op-for-op the unprofiled run
    assert on["total_ops"] == off["total_ops"]
    assert on["writes"]["count"] == off["writes"]["count"]
    assert on["writes"]["p50_ms"] == off["writes"]["p50_ms"]
    assert on["reads"]["p99_ms"] == off["reads"]["p99_ms"]


def test_trace_continuity_across_wrong_range_redirect():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    for i in range(40):                    # populate + load the table
        assert sync(sim, c.put, key_of(i), "c", b"v").ok
    rt = c.range_table
    stale = (list(rt._los), list(rt._rids), dict(rt._members))
    parent = cluster.range_of(key_of(3))
    assert cluster.admin_split(parent)
    sim.run_for(2.0)
    cluster.settle()
    # wind the client cache back to the pre-split table: the next op on a
    # moved key routes to the old leader and bounces with WRONG_RANGE
    rt._los, rt._rids, rt._members = stale
    rt._loaded = True
    moved = next(key_of(i) for i in range(1000)
                 if rt.lookup(key_of(i)) == parent
                 and cluster.range_of(key_of(i)) != parent)
    before = c.wrong_range_redirects
    res = sync(sim, c.put, moved, "c", b"v2")
    assert res.ok
    assert c.wrong_range_redirects > before
    # the redirected op's trace still closes its full write chain
    audit = cluster.obs.tracer.audit_writes()
    assert audit["ok"], audit


def test_trace_continuity_across_mid_op_split():
    sim, cluster = make_cluster(seed=3)
    c = cluster.make_client()
    acked = []

    def put_i(i):
        c.put(key_of(i % 40), "c", b"x", lambda r: acked.append(r))

    for i in range(60):
        sim.schedule(0.01 * i, put_i, i)
    rid = cluster.range_of(key_of(0))
    sim.schedule(0.25, lambda: cluster.admin_split(rid))
    sim.run_for(8.0)
    assert len(acked) == 60 and all(r.ok for r in acked)
    assert len(cluster.ranges) > 1
    audit = cluster.obs.tracer.audit_writes()
    assert audit["ok"], audit


def test_balancer_decision_events_carry_heat():
    sim, cluster = make_cluster(seed=9)
    c = cluster.make_client("hot")
    for i in range(20):
        assert sync(sim, c.put, key_of(i % 15), "c", b"v").ok
    cluster.set_autobalance(True, BalancerConfig(
        period=0.2, split_threshold=100.0, cooldown=0.3,
        min_node_load=1e9))   # moves disabled; splits only

    def hammer(i=0):
        c.put(key_of(i % 15), "c", b"hot", lambda r: hammer(i + 1))

    for _ in range(4):
        hammer()
    sim.run_for(4.0)
    cluster.set_autobalance(False)
    evs = [e for e in cluster.obs.events.events
           if e["kind"] == "balancer_split_decision"]
    assert evs, [e["kind"] for e in cluster.obs.events.events]
    ev = evs[0]
    # the decision event records the triggering heat reading
    assert ev["load_ops_s"] > 0 and ev["threshold"] == 100.0
    assert set(ev["heat"]) == {"ops", "bytes", "lock_wait_s"}
    assert ev["heat"]["ops"] > 0
    # decision events serialize through the stable jsonl export
    line = cluster.obs.events.to_jsonl(
        kinds={"balancer_split_decision"}).splitlines()[0]
    assert list(json.loads(line))[:2] == ["t", "kind"]
