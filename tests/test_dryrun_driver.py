"""Integration test for the dry-run driver: one real cell end-to-end in a
subprocess (512 host devices, production 16×16 mesh), asserting the JSON
artifact has coherent roofline terms."""

import json
import subprocess
import sys
import tempfile
import textwrap
from pathlib import Path

SCRIPT = textwrap.dedent("""
    import sys, json
    sys.path.insert(0, "src")
    from pathlib import Path
    from repro.launch.dryrun import run_cell   # sets XLA_FLAGS on import

    out = Path(sys.argv[1])
    rec = run_cell("smollm-360m", "decode_32k", "pod", out)
    assert rec["status"] == "ok", rec
    r = rec["roofline"]
    assert rec["chips"] == 256
    assert r["compute_s"] > 0 and r["memory_s"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
    assert rec["memory"]["argument_bytes"] > 0
    print("DRYRUN_OK", r["dominant"])
""")


def test_dryrun_cell_end_to_end():
    with tempfile.TemporaryDirectory() as td:
        r = subprocess.run([sys.executable, "-c", SCRIPT, td],
                           capture_output=True, text=True, timeout=900,
                           cwd=".")
        assert "DRYRUN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
        cells = list(Path(td).glob("*.json"))
        assert len(cells) == 1
        rec = json.loads(cells[0].read_text())
        assert rec["arch"] == "smollm-360m"


def test_skip_cell_is_recorded():
    with tempfile.TemporaryDirectory() as td:
        script = SCRIPT.replace(
            'run_cell("smollm-360m", "decode_32k", "pod", out)',
            'run_cell("gemma-7b", "long_500k", "pod", out)').replace(
            'assert rec["status"] == "ok", rec',
            'assert rec["status"] == "skipped", rec').replace(
            'r = rec["roofline"]', 'r = None').replace(
            'assert rec["chips"] == 256', 'pass').replace(
            'assert r["compute_s"] > 0 and r["memory_s"] > 0', 'pass').replace(
            'assert r["dominant"] in ("compute", "memory", "collective")',
            'pass').replace(
            'assert rec["memory"]["argument_bytes"] > 0', 'pass').replace(
            'print("DRYRUN_OK", r["dominant"])', 'print("DRYRUN_OK skip")')
        r = subprocess.run([sys.executable, "-c", script, td],
                           capture_output=True, text=True, timeout=300,
                           cwd=".")
        assert "DRYRUN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
