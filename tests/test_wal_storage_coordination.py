"""Unit tests: WAL (group commit, logical truncation, GC), memtable/
SSTable engine, ZooKeeper-model coordination service."""

import pytest

from repro.core.coordination import Coordination, NodeExists, NoNode
from repro.core.sim import Disk, DiskParams, Simulator
from repro.core.storage import Store
from repro.core.types import CommitMarker, LogRecord, OpType, make_lsn
from repro.core.wal import WAL


def rec(rid, lsn, key="k", val=b"v", version=1):
    return LogRecord(rid, lsn, OpType.PUT, key, (("c", val, version),))


# ---------------------------------------------------------------------------
# WAL
# ---------------------------------------------------------------------------


def make_wal(seed=0, segment_bytes=1 << 20):
    sim = Simulator(seed=seed)
    disk = Disk(sim, DiskParams.ssd())
    return sim, WAL(sim, disk, segment_bytes=segment_bytes)


def test_forced_append_durable_after_force():
    sim, wal = make_wal()
    done = []
    wal.append(rec(0, make_lsn(1, 1)), force=True, cb=lambda: done.append(1))
    assert not done and not wal.durable
    sim.run_for(1.0)
    assert done and len(wal.durable) == 1


def test_group_commit_coalesces_nonforced_markers():
    sim, wal = make_wal()
    wal.append(CommitMarker(0, make_lsn(1, 1)), force=False)
    wal.append(rec(0, make_lsn(1, 2)), force=True)
    sim.run_for(1.0)
    # the non-forced marker rode along with the force
    assert len(wal.durable) == 2


def test_crash_loses_unforced_tail():
    sim, wal = make_wal()
    wal.append(rec(0, make_lsn(1, 1)), force=True)
    sim.run_for(1.0)
    wal.append(rec(0, make_lsn(1, 2)), force=False)   # buffered only
    wal.crash()
    records, cmt = wal.recover_range(0)
    assert [r.lsn for r in records] == [make_lsn(1, 1)]


def test_recover_range_interleaved_cohorts():
    sim, wal = make_wal()
    wal.append(rec(0, make_lsn(1, 1)), force=False)
    wal.append(rec(1, make_lsn(1, 1)), force=False)
    wal.append(rec(0, make_lsn(1, 2)), force=False)
    wal.append(CommitMarker(0, make_lsn(1, 2)), force=False)
    wal.append(rec(1, make_lsn(1, 2)), force=True)
    sim.run_for(1.0)
    r0, cmt0 = wal.recover_range(0)
    r1, cmt1 = wal.recover_range(1)
    assert [r.lsn for r in r0] == [make_lsn(1, 1), make_lsn(1, 2)]
    assert cmt0 == make_lsn(1, 2)
    assert [r.lsn for r in r1] == [make_lsn(1, 1), make_lsn(1, 2)]
    assert cmt1 == 0


def test_logical_truncation_and_unskip_on_reappend():
    sim, wal = make_wal()
    for s in (1, 2, 3):
        wal.append(rec(0, make_lsn(1, s)), force=False)
    wal.append(CommitMarker(0, make_lsn(1, 1)), force=True)
    sim.run_for(1.0)
    wal.logically_truncate(0, [make_lsn(1, 2), make_lsn(1, 3)])
    records, _ = wal.recover_range(0)
    assert [r.lsn for r in records] == [make_lsn(1, 1)]
    # catch-up re-appends 1.2 -> it must be replayable again
    wal.append(rec(0, make_lsn(1, 2)), force=True)
    sim.run_for(1.0)
    records, _ = wal.recover_range(0)
    assert make_lsn(1, 2) in [r.lsn for r in records]
    # 1.3 stays dead
    assert make_lsn(1, 3) not in [r.lsn for r in records]


def test_batch_riders_lost_on_crash_before_force():
    """A leader batch is appended record-by-record with force=False; if the
    node crashes before the covering force, EVERY rider is lost."""
    sim, wal = make_wal()
    wal.append(rec(0, make_lsn(1, 1)), force=True)
    sim.run_for(1.0)
    for s in (2, 3, 4):
        wal.append(rec(0, make_lsn(1, s)), force=False)   # staged batch
    wal.crash()
    records, _ = wal.recover_range(0)
    assert [r.lsn for r in records] == [make_lsn(1, 1)]


def test_batch_force_makes_all_riders_durable_atomically():
    sim, wal = make_wal()
    done = []
    for s in (1, 2, 3):
        wal.append(rec(0, make_lsn(1, s)), force=False)
    wal.force(cb=lambda: done.append(1))
    # nothing durable until the single device force completes ...
    assert not done and not wal.durable
    sim.run_for(1.0)
    # ... then the whole batch is durable at once, with ONE device force
    assert done
    records, _ = wal.recover_range(0)
    assert [r.lsn for r in records] == [make_lsn(1, s) for s in (1, 2, 3)]
    assert wal.disk.forces == 1


def test_batch_crash_mid_force_then_reappend_supersedes_truncation():
    """Crash with a batch force in flight: riders are lost, the force cb
    never fires.  After recovery the surviving regime logically truncates
    the window, and a catch-up re-append of one of those LSNs supersedes
    the skip (the fresh durable copy must replay)."""
    sim, wal = make_wal()
    wal.append(rec(0, make_lsn(1, 1)), force=True)
    sim.run_for(1.0)
    fired = []
    for s in (2, 3):
        wal.append(rec(0, make_lsn(1, s)), force=False)
    wal.force(cb=lambda: fired.append(1))
    wal.crash()                      # force in flight: riders + cb die
    sim.run_for(1.0)
    assert not fired
    records, _ = wal.recover_range(0)
    assert [r.lsn for r in records] == [make_lsn(1, 1)]
    # new regime truncates the ambiguous window ...
    wal.logically_truncate(0, [make_lsn(1, 2), make_lsn(1, 3)])
    # ... then catch-up re-sends 1.2 and it must be replayable again
    wal.append(rec(0, make_lsn(1, 2)), force=True)
    sim.run_for(1.0)
    records, _ = wal.recover_range(0)
    assert make_lsn(1, 2) in [r.lsn for r in records]
    assert make_lsn(1, 3) not in [r.lsn for r in records]


def test_empty_force_is_a_barrier_after_prior_force():
    """force() on an empty buffer still orders after in-flight forces."""
    sim, wal = make_wal()
    order = []
    wal.append(rec(0, make_lsn(1, 1)), force=True, cb=lambda: order.append("a"))
    wal.force(cb=lambda: order.append("barrier"))
    sim.run_for(1.0)
    assert order == ["a", "barrier"]


def test_gc_drops_flushed_segments_and_catchup_falls_back():
    sim, wal = make_wal(segment_bytes=500)
    for s in range(1, 40):
        wal.append(rec(0, make_lsn(1, s), val=b"x" * 64), force=(s % 4 == 0))
    sim.run_for(2.0)
    wal.note_flushed(0, make_lsn(1, 30))
    assert wal.records_between(0, 0, make_lsn(1, 20)) is None  # GC'd
    later = wal.records_between(0, make_lsn(1, 30), make_lsn(1, 36))
    assert later is not None and len(later) > 0


# ---------------------------------------------------------------------------
# storage engine
# ---------------------------------------------------------------------------


def test_memtable_flush_and_read_through_sstables():
    store = Store(flush_threshold_bytes=1)
    store.apply(rec(0, make_lsn(1, 1), key="a", val=b"1", version=1))
    store.flush(make_lsn(1, 1))
    store.apply(rec(0, make_lsn(1, 2), key="a", val=b"2", version=2))
    cell = store.get("a", "c")
    assert cell.value == b"2" and cell.version == 2
    store.flush(make_lsn(1, 2))
    assert store.get("a", "c").value == b"2"     # newest SSTable wins
    assert store.flushes == 2


def test_idempotent_replay():
    store = Store()
    r = rec(0, make_lsn(1, 5), key="a", val=b"x", version=3)
    store.apply(r)
    store.apply(r)                                # local recovery replay
    assert store.get("a", "c").version == 3


def test_tombstones_and_compaction():
    store = Store(flush_threshold_bytes=1, compact_fanin=2)
    for i in range(1, 10):
        op = OpType.DELETE if i % 3 == 0 else OpType.PUT
        val = None if i % 3 == 0 else f"v{i}".encode()
        store.apply(LogRecord(0, make_lsn(1, i), op, f"k{i % 2}",
                              (("c", val, i),)))
        store.flush(make_lsn(1, i))
    assert store.compactions > 0
    c = store.get("k0", "c")   # last write to k0 was i=8 -> put v8
    assert c is not None and c.value == b"v8"
    # k1: last write i=9 -> delete
    c1 = store.get("k1", "c")
    assert c1 is None or c1.deleted


def test_cells_with_lsn_above_for_catchup():
    store = Store(flush_threshold_bytes=1)
    for i in range(1, 6):
        store.apply(rec(0, make_lsn(1, i), key=f"k{i}", val=b"x", version=1))
    store.flush(make_lsn(1, 5))
    cells = store.cells_with_lsn_above(make_lsn(1, 3))
    keys = sorted(k for k, _, _ in cells)
    assert keys == ["k4", "k5"]


# ---------------------------------------------------------------------------
# coordination service
# ---------------------------------------------------------------------------


def test_znode_create_delete_exists():
    sim = Simulator()
    zk = Coordination(sim)
    zk.create("/a/b", data=1)
    assert zk.exists("/a/b") and zk.get("/a/b") == 1
    with pytest.raises(NodeExists):
        zk.create("/a/b")
    zk.delete("/a/b")
    assert not zk.exists("/a/b")
    with pytest.raises(NoNode):
        zk.delete("/a/b")


def test_sequential_znodes_monotonic():
    sim = Simulator()
    zk = Coordination(sim)
    p1 = zk.create("/r/c", sequential=True)
    p2 = zk.create("/r/c", sequential=True)
    assert p1 < p2
    kids = zk.get_children("/r")
    assert len(kids) == 2


def test_ephemeral_deleted_on_session_expiry_and_watch_fires():
    sim = Simulator()
    zk = Coordination(sim, session_timeout=1.0)
    sid = zk.create_session()
    zk.create("/n/1", ephemeral_session=sid)
    fired = []
    zk.watch_exists("/n/1", lambda p: fired.append(p))
    # no heartbeats -> expiry after timeout
    sim.run_for(2.5)
    assert not zk.exists("/n/1")
    assert fired


def test_heartbeats_keep_session_alive():
    sim = Simulator()
    zk = Coordination(sim, session_timeout=1.0)
    sid = zk.create_session()
    zk.create("/n/2", ephemeral_session=sid)

    def beat():
        zk.heartbeat(sid)
        sim.schedule(0.4, beat)
    beat()
    sim.run_for(5.0)
    assert zk.exists("/n/2")


def test_fetch_and_add_monotonic():
    sim = Simulator()
    zk = Coordination(sim)
    assert zk.fetch_and_add("/epoch", 1, initial=0) == 1
    assert zk.fetch_and_add("/epoch", 1) == 2
    assert zk.fetch_and_add("/epoch", 1) == 3


def test_child_watch_one_shot():
    sim = Simulator()
    zk = Coordination(sim)
    zk.create("/w/x")
    fired = []
    zk.watch_children("/w", lambda p: fired.append(p))
    zk.create("/w/y")
    sim.run_for(0.1)
    assert len(fired) == 1
    zk.create("/w/z")   # watch is one-shot: no second event
    sim.run_for(0.1)
    assert len(fired) == 1
