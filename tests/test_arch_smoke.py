"""Per-architecture smoke tests: reduced config, one forward/train/decode
step on CPU, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.launch.shapes import make_batch, make_decode_tokens
from repro.models import decode_step, init_cache, init_params, loss_fn, forward

ARCHS = list_archs()


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch, rng):
    cfg = smoke_config(arch).scaled(remat=False, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    seq = 32 if cfg.family != "hybrid" else 32
    batch = make_batch(cfg, rng, batch=2, seq=seq)
    logits, aux, mask = forward(params, batch, cfg)
    assert logits.shape == (2, seq, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = loss_fn(params, batch, cfg)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    assert metrics["ce"] > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_grad_step_no_nans(arch, rng):
    cfg = smoke_config(arch).scaled(remat=True, dtype="float32")
    params = init_params(jax.random.PRNGKey(1), cfg)
    batch = make_batch(cfg, rng, batch=2, seq=32)

    def scalar_loss(p):
        return loss_fn(p, batch, cfg)[0]

    loss, grads = jax.value_and_grad(scalar_loss)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert bool(jnp.all(jnp.isfinite(g))), "NaN/inf gradient"
    # at least some gradient signal
    total = sum(float(jnp.sum(jnp.abs(g))) for g in leaves)
    assert total > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch, rng):
    cfg = smoke_config(arch).scaled(remat=False, dtype="float32")
    params = init_params(jax.random.PRNGKey(2), cfg)
    B, max_seq = 2, 64
    cache = init_cache(cfg, B, max_seq)
    for step in range(3):
        tok = make_decode_tokens(cfg, rng, B)
        logits, cache = decode_step(params, cache, tok, cfg)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-7b", "smollm-360m"])
def test_decode_matches_forward_teacher_forcing(arch, rng):
    """Decoding token-by-token must match the parallel forward pass."""
    cfg = smoke_config(arch).scaled(remat=False, dtype="float32")
    if cfg.modality != "text":
        pytest.skip("teacher-forcing check for text archs")
    params = init_params(jax.random.PRNGKey(3), cfg)
    B, S = 2, 16
    batch = make_batch(cfg, rng, batch=B, seq=S)
    ref_logits, _, _ = forward(params, batch, cfg)

    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        tok = batch["tokens"][:, t:t + 1]
        logits, cache = decode_step(params, cache, tok, cfg)
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)  # (B,S,V)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(ref_logits.astype(jnp.float32)),
                               rtol=2e-3, atol=2e-3)
