"""Substrate tests: data pipeline, optimizers, gradient compression,
serving engine, FT manager."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, PipelineState, Prefetcher, TokenStream
from repro.dist.compression import compress_decompress, compress_with_feedback
from repro.ft.manager import (FTConfig, HostAgent, StragglerTracker,
                              TrainingController, plan_mesh)
from repro.core.coordination import Coordination
from repro.core.sim import Simulator
from repro.models import init_params
from repro.serve.engine import Request, ServeConfig, ServingEngine
from repro.train.optim import (OptimizerConfig, adafactor_init,
                               adafactor_update, apply_optimizer,
                               init_opt_state)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8,
                     num_shards=4, seed=7)
    s0 = TokenStream(cfg, shard=0)
    b1 = s0.batch_at(5)
    b2 = s0.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_shards_disjoint():
    cfg = DataConfig(vocab_size=50_000, seq_len=128, global_batch=8,
                     num_shards=2, seed=1, mixture_docs=False)
    a = TokenStream(cfg, 0).batch_at(0)["tokens"]
    b = TokenStream(cfg, 1).batch_at(0)["tokens"]
    assert not np.array_equal(a, b)


def test_pipeline_state_roundtrip():
    st = PipelineState(step=1234)
    assert PipelineState.from_bytes(st.to_bytes()).step == 1234


def test_prefetcher_order():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2, seed=3)
    pf = Prefetcher(TokenStream(cfg, 0), start_step=10)
    steps = [pf.next()[0] for _ in range(5)]
    assert steps == [10, 11, 12, 13, 14]


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def _quad_params():
    return {"w": jnp.asarray([[1.0, -2.0], [3.0, 0.5]]),
            "b": jnp.asarray([0.3, -0.1])}


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(name):
    cfg = OptimizerConfig(name=name, lr=0.05, weight_decay=0.0)
    params = _quad_params()
    state = init_opt_state(params, cfg)

    def loss(p):
        return jnp.sum(jnp.square(p["w"])) + jnp.sum(jnp.square(p["b"]))

    l0 = loss(params)
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state, _ = apply_optimizer(grads, state, params, cfg)
    assert loss(params) < 0.2 * l0


def test_adafactor_factored_stats_memory_shape():
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((8,))}
    st = adafactor_init(params)
    assert st["stats"]["big"]["vr"].shape == (256,)
    assert st["stats"]["big"]["vc"].shape == (512,)
    assert st["stats"]["small"]["v"].shape == (8,)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def test_compression_bounded_error():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((128, 130)), jnp.float32)}
    out = compress_decompress(g)
    err = jnp.max(jnp.abs(out["w"] - g["w"]))
    scale = jnp.max(jnp.abs(g["w"])) / 127.0
    assert err <= scale + 1e-6


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)) * 1e-3, jnp.float32)}
    # same gradient repeatedly: with feedback the *accumulated* quantized
    # sum approaches the accumulated true sum
    res = None
    acc = jnp.zeros_like(g["w"])
    for _ in range(50):
        out, res = compress_with_feedback(g, res)
        acc = acc + out["w"]
    true = g["w"] * 50
    rel = float(jnp.linalg.norm(acc - true) / jnp.linalg.norm(true))
    assert rel < 0.05


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


def test_serving_greedy_matches_sequential_decode():
    cfg = smoke_config("smollm-360m").scaled(remat=False, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(slots=2, max_seq=64,
                                                 eos_id=1))
    prompts = [[5, 6, 7], [9, 10, 11, 12]]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    eng.run_until_drained()
    assert set(eng.finished) == {0, 1}
    # oracle: single-slot engine must produce identical tokens
    for i, p in enumerate(prompts):
        solo = ServingEngine(cfg, params, ServeConfig(slots=1, max_seq=64,
                                                      eos_id=1))
        solo.submit(Request(rid=0, prompt=p, max_new_tokens=5))
        solo.run_until_drained()
        assert solo.finished[0].output == eng.finished[i].output


def test_serving_continuous_batching_admits_from_queue():
    cfg = smoke_config("smollm-360m").scaled(remat=False, dtype="float32")
    params = init_params(jax.random.PRNGKey(1), cfg)
    eng = ServingEngine(cfg, params, ServeConfig(slots=2, max_seq=32))
    for i in range(5):
        eng.submit(Request(rid=i, prompt=[3 + i, 4], max_new_tokens=3))
    eng.run_until_drained()
    assert len(eng.finished) == 5


# ---------------------------------------------------------------------------
# FT manager
# ---------------------------------------------------------------------------


def test_controller_replans_on_host_loss_and_fences_old_generation():
    sim = Simulator(seed=0)
    zk = Coordination(sim, session_timeout=1.0)
    cfg = FTConfig(session_timeout=1.0, heartbeat_interval=0.25)
    plans = []
    ctrl = TrainingController(sim, zk, "run0", cfg,
                              on_replan=lambda hosts, gen:
                              plans.append((hosts, gen)))
    agents = [HostAgent(sim, zk, "run0", i, cfg) for i in range(4)]
    sim.run_for(0.5)
    ctrl.bootstrap()
    assert plans and plans[-1][0] == [0, 1, 2, 3]
    gen0 = plans[-1][1]
    for a in agents:
        a.adopt_generation()
    assert not agents[0].fenced()

    agents[2].crash()
    sim.run_for(3.0)
    assert plans[-1][0] == [0, 1, 3]
    assert plans[-1][1] > gen0
    # survivors see the fence until they adopt the new generation
    assert agents[0].fenced()
    agents[0].adopt_generation()
    assert not agents[0].fenced()


def test_plan_mesh_shrinks_model_axis_cleanly():
    assert plan_mesh(64, 4, prefer_model=16) == (16, 16)
    assert plan_mesh(63, 4, prefer_model=16) == (18, 14)  # 252 chips
    assert plan_mesh(3, 4, prefer_model=16) == (1, 12)


def test_straggler_tracker_evicts_after_grace():
    t = StragglerTracker(FTConfig(step_deadline=1.0, straggler_grace=2))
    assert t.observe_step({0: 0.5, 1: 2.0}) == []
    assert t.observe_step({0: 0.5, 1: 2.0}) == [1]
    # recovery resets the counter
    assert t.observe_step({0: 0.5, 1: 0.5}) == []
    assert t.observe_step({0: 0.5, 1: 2.0}) == []
