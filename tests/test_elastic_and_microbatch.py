"""Elastic re-meshing (restore onto a different mesh) and gradient
accumulation equivalence."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, TokenStream
from repro.train.optim import OptimizerConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step


def test_microbatch_accumulation_matches_full_batch():
    cfg = smoke_config("smollm-360m").scaled(remat=False, dtype="float32")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      seed=5, mixture_docs=False)
    batch = {k: jnp.asarray(v)
             for k, v in TokenStream(dcfg, 0).batch_at(0).items()}

    t1 = TrainConfig(optimizer=OptimizerConfig(lr=1e-3), microbatches=1)
    t4 = TrainConfig(optimizer=OptimizerConfig(lr=1e-3), microbatches=4)
    s1 = init_train_state(jax.random.PRNGKey(0), cfg, t1)
    s4 = init_train_state(jax.random.PRNGKey(0), cfg, t4)
    s1b, m1 = jax.jit(make_train_step(cfg, t1))(s1, batch)
    s4b, m4 = jax.jit(make_train_step(cfg, t4))(s4, batch)
    assert m4["loss"] == pytest.approx(float(m1["loss"]), rel=1e-5)
    # atol covers f32 reduction-order noise in the per-microbatch grads,
    # amplified by Adam's rsqrt on near-zero second moments at step 1
    for a, b in zip(jax.tree.leaves(s1b["params"]),
                    jax.tree.leaves(s4b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_grad_compression_step_trains():
    cfg = smoke_config("smollm-360m").scaled(remat=False, dtype="float32")
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3),
                       grad_compression=True)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4,
                      seed=6)
    stream = TokenStream(dcfg, 0)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    losses = []
    for s in range(8):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(s).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config
    from repro.dist.sharding import MeshContext, ShardingPolicy
    from repro.checkpoint.store import SpinnakerCheckpointStore, StoreConfig
    from repro.data.pipeline import DataConfig, TokenStream
    from repro.train.optim import OptimizerConfig
    from repro.train.step import TrainConfig, init_train_state, make_train_step

    cfg = smoke_config("smollm-360m").scaled(remat=False, dtype="float32")
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      seed=9, mixture_docs=False)
    stream = TokenStream(dcfg, 0)

    def run_on_mesh(mesh, state, start, n):
        pol = ShardingPolicy.for_mesh(mesh)
        with MeshContext(mesh, cfg, pol) as ctx:
            shard = ctx.param_shardings(
                jax.eval_shape(lambda: state)["params"]) \
                if False else None
            step = jax.jit(make_train_step(cfg, tcfg))
            losses = []
            for s in range(start, start + n):
                batch = {k: jnp.asarray(v)
                         for k, v in stream.batch_at(s).items()}
                batch = jax.device_put(batch, NamedSharding(
                    mesh, P(("data",), None)))
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        return state, losses

    # phase 1: 8 devices as (4, 2)
    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    state, l1 = run_on_mesh(mesh_a, state, 0, 3)

    store = SpinnakerCheckpointStore(StoreConfig(chunk_bytes=1 << 16))
    store.save(3, jax.tree.map(np.asarray, state))

    # "node loss": elastic restart on a (2, 2) mesh of 4 surviving devices
    mesh_b = jax.sharding.Mesh(
        np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    fresh = init_train_state(jax.random.PRNGKey(1), cfg, tcfg)
    step0, restored = store.restore_tree(fresh)
    restored = jax.tree.map(jnp.asarray, restored)
    state_b, l2 = run_on_mesh(mesh_b, restored, step0, 3)

    # reference: uninterrupted single-mesh run
    ref = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    ref, lr1 = run_on_mesh(mesh_a, ref, 0, 3)
    ref, lr2 = run_on_mesh(mesh_a, ref, 3, 3)

    assert np.allclose(l1, lr1, rtol=1e-5), (l1, lr1)
    assert np.allclose(l2, lr2, rtol=1e-4, atol=1e-5), (l2, lr2)
    print("ELASTIC_OK", l2)
""")


def test_elastic_restart_on_smaller_mesh_subprocess():
    """Checkpoint on a (4,2) mesh, restore + resume on (2,2) of the
    survivors: losses must match the uninterrupted run (restore is by
    logical key, resharding-safe)."""
    r = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT],
                       capture_output=True, text=True, timeout=900, cwd=".")
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
