"""Spinnaker-backed checkpoint store: quorum commit, conditionalPut
fencing (split-brain protection), storage-node failure tolerance,
timeline reads for serving refresh, end-to-end train/crash/resume."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (SpinnakerCheckpointStore, StaleTrainerError,
                                    StoreConfig)
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, TokenStream
from repro.models import init_params
from repro.train.optim import OptimizerConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step


def small_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layer": {"w": rng.standard_normal((33, 17)).astype(np.float32),
                  "b": rng.standard_normal((17,)).astype(np.float32)},
        "step": np.int32(7),
    }


def trees_equal(a, b):
    fa = jax.tree.leaves(a)
    fb = jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb))


def test_save_restore_roundtrip():
    store = SpinnakerCheckpointStore(StoreConfig(chunk_bytes=512))
    tree = small_tree()
    store.save(10, tree)
    step, restored = store.restore_tree(tree)
    assert step == 10
    assert trees_equal(tree, restored)


def test_manifest_fences_zombie_trainer():
    """Two trainers share a run: the stale one must be fenced out by the
    conditionalPut (the paper's optimistic concurrency as split-brain
    protection)."""
    store = SpinnakerCheckpointStore(StoreConfig())
    t1 = small_tree(1)
    store.save(1, t1)

    # trainer B takes over the run (restores, then commits newer state)
    store_b = object.__new__(SpinnakerCheckpointStore)
    store_b.__dict__.update(store.__dict__)      # same cluster, own version
    store_b._manifest_version = None
    step, _ = store_b.restore_tree(t1)
    store_b.save(2, small_tree(2))

    # trainer A (zombie, stale manifest version) must NOT clobber step 2
    with pytest.raises(StaleTrainerError):
        store.save(3, small_tree(3))
    assert store_b.latest_step() == 2


def test_checkpoint_survives_storage_node_crash():
    store = SpinnakerCheckpointStore(StoreConfig(chunk_bytes=256))
    tree = small_tree(4)
    store.save(5, tree)
    # crash one storage node; quorum survives, strong reads still work
    store.crash_storage_node(1)
    store.sim.run_for(5.0)
    step, restored = store.restore_tree(tree)
    assert step == 5 and trees_equal(tree, restored)
    # and new checkpoints still commit (majority alive per cohort)
    store.save(6, small_tree(5))
    assert store.latest_step() == 6
    # node comes back and catches up; reads keep working
    store.restart_storage_node(1)
    step, _ = store.restore_tree(tree)
    assert step == 6


def test_timeline_read_for_serving_refresh():
    store = SpinnakerCheckpointStore(StoreConfig())
    store.save(1, small_tree(1))
    # timeline (stale-ok) read of the manifest works and returns a step
    step = store.latest_step(consistent=False)
    assert step == 1
    store.sim.run_for(2.0)
    step, _ = store.restore(consistent=False)
    assert step == 1


def test_train_crash_resume_bit_exact():
    """Train k steps + checkpoint, 'crash', restore into a fresh trainer,
    continue — must match an uninterrupted run bit-for-bit (deterministic
    data pipeline + pure train step)."""
    cfg = smoke_config("smollm-360m").scaled(remat=False, dtype="float32")
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4,
                      seed=11, mixture_docs=False)
    stream = TokenStream(dcfg, 0)
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    def run(state, start, n):
        losses = []
        for s in range(start, start + n):
            b = stream.batch_at(s)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        return state, losses

    # uninterrupted reference: 6 steps
    ref_state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    ref_state, ref_losses = run(ref_state, 0, 6)

    # interrupted: 3 steps, checkpoint, crash, restore, 3 more
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    state, l1 = run(state, 0, 3)
    store = SpinnakerCheckpointStore(StoreConfig(chunk_bytes=1 << 16))
    store.save(3, jax.tree.map(np.asarray, state))
    del state

    fresh = init_train_state(jax.random.PRNGKey(42), cfg, tcfg)  # wrong seed
    step, restored = store.restore_tree(fresh)
    assert step == 3
    restored = jax.tree.map(jnp.asarray, restored)
    restored_state, l2 = run(restored, 3, 3)

    assert l1 + l2 == pytest.approx(ref_losses, rel=1e-6)
    assert trees_equal(jax.tree.map(np.asarray, restored_state),
                       jax.tree.map(np.asarray, ref_state))
