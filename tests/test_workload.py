"""Workload engine tests: generators, drivers, fault-schedule DSL,
partitions, batched reads, and timeline-read monotonicity across a leader
failover (§8.1, Figs. 9-10)."""

import collections
import math

import numpy as np
import pytest

from repro.core import ClusterConfig, Simulator, SpinnakerCluster, key_of
from repro.core.sim import Network
from repro.workload import (ClosedLoopDriver, ExperimentConfig, OpKind,
                            OpLog, OpStream, OpenLoopDriver,
                            SpinnakerAdapter, WorkloadSpec, parse_schedule,
                            run_spinnaker_workload)
from repro.workload.generators import _coprime_multiplier
from repro.workload.metrics import LatencyHistogram


def make_cluster(n=5, seed=0, **kw):
    sim = Simulator(seed=seed)
    cluster = SpinnakerCluster(sim, ClusterConfig(n_nodes=n, **kw))
    cluster.start()
    cluster.settle()
    return sim, cluster


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------


def test_stream_deterministic_from_seed():
    spec = WorkloadSpec(num_keys=500)
    a = [OpStream(spec, seed=7).next_op() for _ in range(1)]
    s1, s2 = OpStream(spec, seed=7), OpStream(spec, seed=7)
    ops1 = [s1.next_op() for _ in range(5000)]
    ops2 = [s2.next_op() for _ in range(5000)]
    assert ops1 == ops2
    s3 = OpStream(spec, seed=8)
    assert [s3.next_op() for _ in range(5000)] != ops1


def test_op_mix_proportions():
    spec = WorkloadSpec(num_keys=100, read_frac=0.5, write_frac=0.3,
                        rmw_frac=0.1, cond_frac=0.1)
    s = OpStream(spec, seed=0)
    kinds = collections.Counter(s.next_op().kind for _ in range(20000))
    assert kinds[OpKind.READ] / 20000 == pytest.approx(0.5, abs=0.02)
    assert kinds[OpKind.WRITE] / 20000 == pytest.approx(0.3, abs=0.02)
    assert kinds[OpKind.RMW] / 20000 == pytest.approx(0.1, abs=0.01)
    assert kinds[OpKind.COND] / 20000 == pytest.approx(0.1, abs=0.01)


def test_zipfian_skew_and_scramble():
    n = 1000
    spec = WorkloadSpec(num_keys=n, key_dist="zipfian", zipf_theta=0.99)
    s = OpStream(spec, seed=3)
    keys = collections.Counter(s.next_op().key_index for _ in range(30000))
    top = keys.most_common(1)[0][1] / 30000
    # YCSB theta=0.99 over 1000 keys: hottest key ~1/H_n ≈ 13%
    assert 0.08 < top < 0.20
    # scramble spreads the hot ranks: hottest two keys are not adjacent
    (k1, _), (k2, _) = keys.most_common(2)
    assert abs(k1 - k2) > 1
    # uniform has no such skew
    u = OpStream(WorkloadSpec(num_keys=n, key_dist="uniform"), seed=3)
    ukeys = collections.Counter(u.next_op().key_index for _ in range(30000))
    assert ukeys.most_common(1)[0][1] / 30000 < 0.01
    assert all(0 <= k < n for k in keys)


def test_latest_distribution_tracks_horizon():
    spec = WorkloadSpec(num_keys=1000, key_dist="latest")
    s = OpStream(spec, seed=0)
    keys = [s.next_op().key_index for _ in range(5000)]
    # hot keys cluster at the top of the keyspace (most recent inserts)
    assert np.median(keys) > 800
    s.insert_horizon = 100     # pretend only 100 keys inserted so far
    keys2 = [s.next_op().key_index for _ in range(5000)]
    assert max(keys2) <= 99


def test_value_size_distributions():
    fixed = OpStream(WorkloadSpec(num_keys=10, value_size=777), seed=0)
    assert {fixed.next_op().value_size for _ in range(100)} == {777}
    uni = OpStream(WorkloadSpec(num_keys=10, value_size=4096,
                                value_size_dist="uniform",
                                value_size_min=100), seed=0)
    sizes = [uni.next_op().value_size for _ in range(2000)]
    assert min(sizes) >= 100 and max(sizes) <= 4096
    assert len(set(sizes)) > 100


def test_coprime_multiplier_bijective():
    for n in (2, 10, 97, 1000, 4096):
        a = _coprime_multiplier(n)
        assert len({(i * a) % n for i in range(n)}) == n


def test_poisson_gaps_mean():
    s = OpStream(WorkloadSpec(num_keys=10), seed=1)
    gaps = []
    for _ in range(5000):
        gaps.append(s.next_gap(rate=100.0))
        s.next_op()
    assert np.mean(gaps) == pytest.approx(1 / 100.0, rel=0.1)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_histogram_percentiles_bounded_error():
    h = LatencyHistogram()
    rng = np.random.default_rng(0)
    xs = rng.lognormal(mean=-7, sigma=1.0, size=20000)
    for x in xs:
        h.add(float(x))
    for p in (50, 95, 99):
        exact = float(np.percentile(xs, p))
        assert h.percentile(p) == pytest.approx(exact, rel=0.10)
    assert h.summary()["count"] == 20000


def test_histogram_percentile_within_one_log_bin():
    # the bin grid is 240/decade: any percentile answer must sit within
    # one bin-width factor (10^(1/240) ~ 1.0096x) of the exact sample
    # quantile, clamped to the observed [min, max]
    h = LatencyHistogram()
    rng = np.random.default_rng(7)
    xs = np.sort(rng.lognormal(mean=-6, sigma=1.5, size=50000))
    for x in xs:
        h.add(float(x))
    bin_factor = 10 ** (1 / 240)
    for p in (10, 50, 90, 95, 99, 99.9):
        exact = float(np.percentile(xs, p, method="inverted_cdf"))
        got = h.percentile(p)
        assert exact / bin_factor * 0.999 <= got <= exact * bin_factor \
            * 1.001, (p, got, exact)


def test_histogram_separates_close_percentiles():
    """Regression for the coarse-bin collapse: a 30/decade grid (~8%
    bins) folded latencies a few percent apart into one bin, so p50, p95
    and p99 of a tight distribution all read back as the same edge value
    (visible as bit-identical percentiles across unrelated runs).  The
    240/decade grid (<1% bins) must keep 5%-apart percentiles distinct,
    ordered, and within 1% of their true values."""
    h = LatencyHistogram()
    for _ in range(5000):
        h.add(1.00e-3)
    for _ in range(4500):
        h.add(1.05e-3)
    for _ in range(500):
        h.add(1.10e-3)
    p50, p95, p99 = (h.percentile(p) for p in (50, 95, 99))
    assert p50 < p95 < p99, (p50, p95, p99)
    assert p50 == pytest.approx(1.00e-3, rel=0.01)
    assert p95 == pytest.approx(1.05e-3, rel=0.01)
    assert p99 == pytest.approx(1.10e-3, rel=0.01)


def test_histogram_empty_summary():
    h = LatencyHistogram()
    s = h.summary()
    assert s["count"] == 0
    for k in ("mean_ms", "p50_ms", "p95_ms", "p99_ms", "min_ms"):
        assert math.isnan(s[k]), (k, s[k])
    assert s["max_ms"] == 0.0


def test_histogram_merge():
    a, b, ref = LatencyHistogram(), LatencyHistogram(), LatencyHistogram()
    rng = np.random.default_rng(1)
    xa = rng.lognormal(-7, 1.0, 5000)
    xb = rng.lognormal(-5, 0.5, 3000)
    for x in xa:
        a.add(float(x))
        ref.add(float(x))
    for x in xb:
        b.add(float(x))
        ref.add(float(x))
    # merging an empty histogram is the identity
    before = (a.total, a.sum, a.min, a.max, a.percentile(50))
    a.merge(LatencyHistogram())
    assert (a.total, a.sum, a.min, a.max, a.percentile(50)) == before
    # empty.merge(populated) adopts the populated stats wholesale
    e = LatencyHistogram()
    e.merge(b)
    assert e.total == b.total and e.percentile(95) == b.percentile(95)
    assert e.min == b.min and e.max == b.max
    # populated merge: identical to having added both populations
    a.merge(b)
    assert a.total == ref.total
    assert a.sum == pytest.approx(ref.sum)
    assert (a.min, a.max) == (ref.min, ref.max)
    for p in (50, 95, 99):
        assert a.percentile(p) == ref.percentile(p)


def test_oplog_windows():
    log = OpLog()
    for i in range(100):
        log.record(t_done=i * 0.01, kind="read", ok=(i % 10 != 0),
                   latency=0.001)
    ws = log.windows(0.5, kind="read", t0=0.0, t1=1.0)
    assert len(ws) == 2
    assert ws[0].throughput == pytest.approx(90.0, rel=0.15)
    assert 0.0 < ws[0].error_rate < 0.2


def test_oplog_final_window_clamped_to_t1():
    # 100 ops at a steady 100/s; a 0.4s window grid over [0, 1.0) leaves
    # a 0.2s tail, which must report the true 100/s, not half of it
    log = OpLog()
    for i in range(100):
        log.record(t_done=i * 0.01, kind="write", ok=True, latency=0.001)
    ws = log.windows(0.4, kind="write", t0=0.0, t1=1.0)
    assert len(ws) == 3
    assert ws[-1].t_end == pytest.approx(1.0)
    assert ws[-1].t_end - ws[-1].t_start == pytest.approx(0.2)
    for w in ws:
        assert w.throughput == pytest.approx(100.0)


def test_oplog_vectorized_count():
    log = OpLog()
    assert log.count() == 0 and log.count(kind="nope") == 0
    # push past the initial 1024 capacity to exercise array growth
    for i in range(3000):
        kind = ("read", "write", "rmw")[i % 3]
        log.record(t_done=i * 1e-3, kind=kind, ok=(i % 5 != 0),
                   latency=1e-4)
    assert len(log) == 3000
    assert log.count() == 3000
    assert log.count(kind="read") == 1000
    assert log.count(kind="write", ok=True) == 800
    assert log.count(kind="write", ok=False) == 200
    assert log.count(ok=False) == 600
    assert log.count(kind="unknown") == 0
    assert log.count(kind="unknown", ok=True) == 0


# ---------------------------------------------------------------------------
# scenario DSL
# ---------------------------------------------------------------------------


def test_parse_schedule_full_grammar():
    sched = parse_schedule("""
        # comment line
        at 1s crash node 2 lose_disk
        at 2.5s crash leader of 3 no_expire
        at 3s restart node 2
        at 4s restart crashed
        at 5s partition {0,1} | {2,3,4}
        at 6s heal
    """)
    acts = [e.action for e in sched.events]
    assert acts == ["crash", "crash_leader", "restart", "restart",
                    "partition", "heal"]
    assert sched.events[0].lose_disk and sched.events[0].expire_session
    assert not sched.events[1].expire_session
    assert sched.events[3].node is None          # 'restart crashed'
    assert sched.events[4].groups == ((0, 1), (2, 3, 4))


@pytest.mark.parametrize("bad", [
    "at crash node 1",
    "at 1s explode node 1",
    "at 1s crash node 1 gently",
    "at 1s partition {0,1}",
])
def test_parse_schedule_rejects_garbage(bad):
    with pytest.raises(ValueError):
        parse_schedule(bad)


def test_partition_blocks_cross_group_only():
    sim = Simulator(seed=0)
    net = Network(sim)
    got = []
    net.set_partition([{0, 1}, {2}])
    net.send(0, 1, lambda: got.append("01"))
    net.send(0, 2, lambda: got.append("02"))
    net.send(2, 1, lambda: got.append("21"))
    net.send("client", 2, lambda: got.append("c2"))   # ungrouped endpoint
    sim.run_until_idle()
    assert sorted(got) == ["01", "c2"]
    net.clear_partition()
    net.send(0, 2, lambda: got.append("02b"))
    sim.run_until_idle()
    assert "02b" in got


def test_partition_cuts_in_flight_messages():
    sim = Simulator(seed=0)
    net = Network(sim)
    got = []
    net.send(0, 2, lambda: got.append("d"))   # in flight ...
    net.set_partition([{0}, {2}])             # ... cut before delivery
    sim.run_until_idle()
    assert got == []


# ---------------------------------------------------------------------------
# drivers against a live cluster
# ---------------------------------------------------------------------------


def test_closed_loop_driver_records_ops():
    sim, cluster = make_cluster()
    stream = OpStream(WorkloadSpec(num_keys=50, value_size=128), seed=0)
    log = OpLog()
    drv = ClosedLoopDriver(sim, SpinnakerAdapter(cluster.make_client()),
                           stream, log, n_clients=4)
    drv.run(duration=1.0, warmup=0.2)
    assert len(log) > 100
    assert log.count(ok=False) == 0
    assert "read" in log.hists and log.hists["read"].mean > 0


def test_open_loop_driver_hits_target_rate():
    sim, cluster = make_cluster()
    stream = OpStream(WorkloadSpec(num_keys=50, value_size=128), seed=0)
    log = OpLog()
    drv = OpenLoopDriver(sim, SpinnakerAdapter(cluster.make_client()),
                         stream, log, rate=500.0)
    drv.run(duration=2.0, warmup=0.2)
    assert log.count(ok=True) / 2.0 == pytest.approx(500.0, rel=0.15)


def test_multi_get_batched_reads():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    for i in range(8):
        c.sync_put(key_of(i), "c", f"v{i}".encode())
    box = []
    c.multi_get([(key_of(i), "c") for i in range(8)], True,
                lambda rs: box.append(rs))
    sim.run_for(1.0)
    assert box and len(box[0]) == 8
    assert all(r.ok for r in box[0])
    assert [r.value for r in box[0]] == [f"v{i}".encode() for i in range(8)]
    # batched latency ≈ one round trip, not eight: cheaper than serial gets
    assert all(r.latency < 0.02 for r in box[0])


def test_client_latency_tagging_hooks():
    sim, cluster = make_cluster()
    c = cluster.make_client()
    seen = []
    c.op_hook = lambda kind, res: seen.append((kind, res.ok))
    c.sync_put(key_of(1), "c", b"x")
    c.sync_get(key_of(1), "c")
    assert ("write", True) in seen and ("read", True) in seen
    assert c.stats_by_kind["write"].count == 1
    assert c.stats_by_kind["read"].count == 1


# ---------------------------------------------------------------------------
# failover scenarios (Figs. 9-10)
# ---------------------------------------------------------------------------


def test_writes_resume_after_leader_crash_scenario():
    cfg = ExperimentConfig(duration=6.0, warmup=0.5, n_clients=4,
                           disk="mem", preload_cap=50, window=0.5)
    spec = WorkloadSpec(num_keys=50, value_size=256, read_frac=0.2,
                        write_frac=0.8, rmw_frac=0.0, cond_frac=0.0)
    r = run_spinnaker_workload(
        spec, cfg, schedule="at 1.0s crash leader of 0\n"
                            "at 4.5s restart crashed")
    assert any(e.startswith("t=1.0: crash node") for e in r["fault_events"])
    post = [w for w in r["timeline"]["write"] if w["t_start"] > 1.0]
    assert max(w["throughput"] for w in post) > 0, \
        "writes never resumed after the leader crash"


def test_timeline_reads_monotonic_across_leader_failover():
    """Satellite: a monotonic timeline-read client must never observe the
    version of a key go backwards while the fault schedule kills and
    restarts the leader serving it (PNUTS-style session guarantee)."""
    sim, cluster = make_cluster()
    key = key_of(7)
    rid = cluster.range_of(key)
    writer = cluster.make_client("writer")
    reader = cluster.make_client("reader")

    versions = []

    def keep_writing(i=0):
        if sim.now > 12.0:
            return
        writer.put(key, "c", f"v{i}".encode(),
                   lambda r: sim.schedule(0.01, keep_writing, i + 1))

    def keep_reading():
        if sim.now > 12.0:
            return
        def got(res):
            if res.ok and res.version is not None:
                versions.append(res.version)
            sim.schedule(0.005, keep_reading)
        reader.get(key, "c", consistent=False, cb=got, monotonic=True)

    sched = parse_schedule(f"""
        at 2.0s crash leader of {rid}
        at 6.0s restart crashed
        at 8.0s crash leader of {rid}
        at 10.0s restart crashed
    """)
    sched.install(sim, cluster)
    keep_writing()
    keep_reading()
    sim.run(until=13.0)

    assert len(versions) > 200, "reader starved during failover"
    diffs = np.diff(versions)
    assert (diffs >= 0).all(), \
        f"timeline monotonicity violated at {np.argmin(diffs)}"
    # versions actually advanced across both failovers (writes resumed)
    assert versions[-1] > versions[0] + 100
    assert len(sched.applied) == 4
