"""Store.get tombstone contract and the compaction GC edge (§4.1, §6.1)."""

from repro.core.storage import Store
from repro.core.types import LogRecord, OpType


def rec(lsn: int, key: str, value, version: int,
        op: OpType = OpType.PUT) -> LogRecord:
    return LogRecord(range_id=0, lsn=lsn, op=op, key=key,
                     columns=(("c", value, version),))


def put(store: Store, lsn: int, key: str, value, version: int) -> None:
    store.apply(rec(lsn, key, value, version))


def delete(store: Store, lsn: int, key: str, version: int) -> None:
    store.apply(rec(lsn, key, None, version, op=OpType.DELETE))


def test_get_returns_tombstone_not_none():
    s = Store()
    put(s, 1, "k", b"v1", 1)
    delete(s, 2, "k", 2)
    cell = s.get("k", "c")
    assert cell is not None and cell.deleted and cell.value is None
    # version arithmetic continues across the delete
    assert s.current_version("k", "c") == 2
    # a key never written is genuinely None
    assert s.get("nope", "c") is None


def test_tombstone_survives_flush_and_shadows_sstable_value():
    s = Store(flush_threshold_bytes=1)
    put(s, 1, "k", b"v1", 1)
    s.flush(committed_lsn=1)           # value now durable in an SSTable
    delete(s, 2, "k", 2)
    s.flush(committed_lsn=2)           # tombstone in a newer SSTable
    cell = s.get("k", "c")
    assert cell is not None and cell.deleted
    assert s.current_version("k", "c") == 2


def test_compaction_gc_drops_tombstone_without_resurrection():
    """The _maybe_compact GC edge: merging the oldest runs into the stack
    bottom must drop tombstones *and* the values they shadow together —
    a read afterwards is NOT_FOUND (None), never the old value."""
    s = Store(flush_threshold_bytes=1, compact_fanin=2)
    lsn = 0

    def bump():
        nonlocal lsn
        lsn += 1
        return lsn

    put(s, bump(), "dead", b"old", 1)
    s.flush(committed_lsn=lsn)
    delete(s, bump(), "dead", 2)
    s.flush(committed_lsn=lsn)
    # pile up runs until size-tiered compaction fires (fanin*2 = 4 runs)
    while s.compactions == 0:
        put(s, bump(), f"fill{lsn}", b"x", 1)
        s.flush(committed_lsn=lsn)
    # value and tombstone were both in the merged bottom run: gone together
    assert s.get("dead", "c") is None
    assert s.current_version("dead", "c") == 0
    # live fills are still readable after the merge
    live = [k for k in range(3, lsn + 1)]
    assert any(s.get(f"fill{k}", "c") is not None for k in live)


def test_compaction_keeps_tombstone_needed_above_merged_run():
    """A delete newer than the merged runs must keep shadowing their
    values: the tombstone lives in a non-victim run and still wins."""
    s = Store(flush_threshold_bytes=1, compact_fanin=2)
    put(s, 1, "k", b"old", 1)
    s.flush(committed_lsn=1)
    put(s, 2, "fill_a", b"x", 1)
    s.flush(committed_lsn=2)
    put(s, 3, "fill_b", b"x", 1)
    s.flush(committed_lsn=3)
    delete(s, 4, "k", 2)
    s.flush(committed_lsn=4)           # triggers compaction of runs 1+2
    assert s.compactions >= 1
    cell = s.get("k", "c")
    # the old value must NOT have resurrected
    assert cell is not None and cell.deleted and cell.value is None


def test_compaction_newest_cell_wins_within_victims():
    s = Store(flush_threshold_bytes=1, compact_fanin=2)
    put(s, 1, "k", b"v1", 1)
    s.flush(committed_lsn=1)
    put(s, 2, "k", b"v2", 2)
    s.flush(committed_lsn=2)
    put(s, 3, "a", b"x", 1)
    s.flush(committed_lsn=3)
    put(s, 4, "b", b"x", 1)
    s.flush(committed_lsn=4)
    assert s.compactions >= 1
    cell = s.get("k", "c")
    assert cell is not None and cell.value == b"v2" and cell.version == 2
