"""Chaos harness (PR 7): gray-failure injection primitives, the fault-DSL
verbs that drive them, the seeded schedule generator, the linearizability
and availability auditors, and the partition-aware leader leases the
harness exists to vet — including the signature scenario: a leader
partitioned into the minority while its ZooKeeper session survives fails
over within the lease bound instead of stalling the range until heal."""

import numpy as np
import pytest

from repro.chaos import (CohortHealthTimeline, HistOp, audit_availability,
                         check_linearizability, generate_chaos_schedule,
                         majority_healthy_windows)
from repro.core import (ClusterConfig, ErrorCode, NodeConfig, ReplicaConfig,
                        Simulator, SpinnakerCluster, key_of)
from repro.core.sim import DiskParams, Network
from repro.core.replica import Role
from repro.workload import parse_schedule
from repro.workload.experiment import (run_spinnaker_chaos,
                                       run_spinnaker_minority_leader)
from repro.workload.scenario import FaultEvent


def make_cluster(n=5, seed=0, num_keys=50, lease_enabled=True,
                 commit_period=0.05, **rep_kw):
    sim = Simulator(seed=seed)
    cfg = ClusterConfig(
        n_nodes=n, num_keys=num_keys,
        node=NodeConfig(replica=ReplicaConfig(commit_period=commit_period,
                                              lease_enabled=lease_enabled,
                                              **rep_kw),
                        disk=DiskParams.memory()))
    cluster = SpinnakerCluster(sim, cfg)
    cluster.start()
    cluster.settle()
    return sim, cluster


# ======================================================= network primitives

def net_pair():
    sim = Simulator(seed=1)
    net = Network(sim)
    got = []
    return sim, net, got


def test_oneway_partition_blocks_one_direction_only():
    sim, net, got = net_pair()
    net.set_oneway_partition({0}, {1})
    net.send(0, 1, lambda: got.append("0->1"))
    net.send(1, 0, lambda: got.append("1->0"))
    sim.run_until_idle()
    assert got == ["1->0"]
    net.clear_oneway_partitions()
    net.send(0, 1, lambda: got.append("0->1"))
    sim.run_until_idle()
    assert "0->1" in got


def test_link_drop_eats_messages_and_dup_duplicates():
    sim, net, got = net_pair()
    net.set_link_fault(0, 1, drop_p=1.0)
    for _ in range(10):
        net.send(0, 1, lambda: got.append("x"))
    sim.run_until_idle()
    assert got == [] and net.dropped == 10
    net.set_link_fault(0, 1, dup_p=1.0)
    net.send(0, 1, lambda: got.append("y"))
    sim.run_until_idle()
    assert got == ["y", "y"]       # every message delivered twice
    # the reverse direction was never faulted
    net.send(1, 0, lambda: got.append("z"))
    sim.run_until_idle()
    assert got[-1] == "z"


def test_link_delay_factor_stretches_latency():
    sim, net, got = net_pair()
    net.send(0, 1, lambda: got.append(sim.now))
    sim.run_until_idle()
    base = got.pop()
    t0 = sim.now
    net.set_link_fault(0, 1, delay_factor=50.0)
    net.send(0, 1, lambda: got.append(sim.now - t0))
    sim.run_until_idle()
    assert got[0] > 10 * base


def test_update_link_fault_merges_aspects():
    sim, net, _ = net_pair()
    net.update_link_fault(0, 1, drop_p=0.3)
    net.update_link_fault(0, 1, delay_factor=8.0)
    assert net._link_faults[(0, 1)] == (0.3, 0.0, 8.0)
    net.update_link_fault(0, 1, drop_p=0.0)   # explicit zero clears drop only
    assert net._link_faults[(0, 1)] == (0.0, 0.0, 8.0)


def test_cluster_heal_clears_every_gray_fault():
    """Satellite: `heal` restores symmetric + one-way partitions, link
    faults, and disk/CPU gray multipliers in one call."""
    sim, cluster = make_cluster(n=3)
    cluster.partition({0}, {1, 2})
    cluster.partition_oneway({1}, {2})
    cluster.set_link_fault(0, 1, drop_p=0.5)
    cluster.slow_disk(0, 10.0)
    cluster.slow_cpu(1, 10.0)
    assert cluster.net.partitioned(0, 1)
    cluster.heal()
    assert not cluster.net.partitioned(0, 1)
    assert not cluster.net.partitioned(1, 2)
    assert not cluster.net._link_faults
    assert cluster.nodes[0].disk.slow_factor == 1.0
    assert cluster.nodes[1].cpu.slow_factor == 1.0
    sim.run_for(2.0)
    cluster.settle()
    c = cluster.make_client()
    assert c.sync_put(key_of(1), "c", b"post-heal").ok


# ================================================================ fault DSL

GRAY_SCHEDULE = """
# every gray-failure verb once
at 1.0s partition oneway {0,1} -> {2}
at 2.0s drop link 0 2 p=0.25
at 3.0s dup link 2 0 p=0.1
at 4.0s slow link 1 2 x8
at 5.0s slow disk on 3 x20
at 6.0s slow cpu on 4 x15
at 7.0s flap session of 2 for 1.5s
at 8.0s heal
"""


def test_parse_and_describe_every_gray_verb():
    """Satellite: the DSL parses each new verb into the right FaultEvent
    and `describe` covers them all (no silent fall-through to 'heal')."""
    sched = parse_schedule(GRAY_SCHEDULE)
    by_action = {e.action: e for e in sched.events}
    ow = by_action["partition_oneway"]
    assert ow.groups == ((0, 1), (2,))
    drops = [e for e in sched.events if e.action == "link"]
    assert (drops[0].src, drops[0].dst, drops[0].drop_p) == (0, 2, 0.25)
    assert (drops[1].src, drops[1].dst, drops[1].dup_p) == (2, 0, 0.1)
    assert (drops[2].src, drops[2].dst, drops[2].factor) == (1, 2, 8.0)
    assert by_action["slow_disk"].node == 3
    assert by_action["slow_disk"].factor == 20.0
    assert by_action["slow_cpu"].node == 4
    assert by_action["flap"].node == 2 and by_action["flap"].outage == 1.5

    descs = [e.describe() for e in sched.events]
    assert any("partition oneway {0,1} -> {2}" in d for d in descs)
    assert any("link 0->2 drop p=0.25" in d for d in descs)
    assert any("link 2->0 dup p=0.1" in d for d in descs)
    assert any("link 1->2 delay x8" in d for d in descs)
    assert any("slow disk on node 3 x20" in d for d in descs)
    assert any("slow cpu on node 4 x15" in d for d in descs)
    assert any("flap session of node 2 for 1.5s" in d for d in descs)
    # no event's describe() degenerates to the bare-heal fallback
    assert sum(d.endswith("heal") for d in descs) == 1


def test_dsl_fires_gray_faults_against_cluster():
    sim, cluster = make_cluster()
    sched = parse_schedule(GRAY_SCHEDULE)
    sched.install(sim, cluster)
    sim.run(until=7.5)
    assert cluster.net._oneway            # oneway applied
    assert (0, 2) in cluster.net._link_faults
    assert cluster.nodes[3].disk.slow_factor == 20.0
    assert cluster.nodes[4].cpu.slow_factor == 15.0
    sim.run(until=8.5)                    # heal fired
    assert not cluster.net._oneway and not cluster.net._link_faults
    assert cluster.nodes[3].disk.slow_factor == 1.0
    assert cluster.nodes[4].cpu.slow_factor == 1.0
    assert len(sched.applied) == 8
    assert len(sched.applied_events) == 8
    sim.run_for(3.0)
    cluster.settle()                      # flapped node rejoined


def test_chaos_schedule_generator_deterministic_and_parses():
    a = generate_chaos_schedule(seed=11)
    b = generate_chaos_schedule(seed=11)
    assert a == b, "same seed must give the identical schedule"
    assert a != generate_chaos_schedule(seed=12)
    sched = parse_schedule(a)
    assert sched.events and sched.events[-1].t <= 18.0
    assert any(e.action == "heal" for e in sched.events)
    # across a seed band, every episode class appears at least once
    actions = set()
    for seed in range(8):
        actions |= {e.action for e in parse_schedule(
            generate_chaos_schedule(seed)).events}
    assert {"crash", "restart", "partition", "partition_oneway", "link",
            "slow_disk", "slow_cpu", "flap", "heal"} <= actions


# ==================================================== client retry ordering

def test_retry_gate_serializes_same_key_write_retries():
    """Two same-key write retries must re-issue in original order: the
    second one queues behind the first and is released only when the
    first resolves (prevents CAS overtaking after WRONG_RANGE bounces)."""
    sim, cluster = make_cluster(n=3)
    c = cluster.make_client()
    k = key_of(1)
    kw_a, kw_b, kw_other = {"a": 1}, {"b": 2}, {"c": 3}
    c._schedule_retry("write", k, kw_a, lambda r: None, True, 0.0, 0)
    c._schedule_retry("write", k, kw_b, lambda r: None, True, 0.0, 0)
    assert c._retry_gate[k] is kw_a
    assert len(c._retry_waiters[k]) == 1
    # reads are never gated
    c._schedule_retry("read", k, kw_other, lambda r: None, True, 0.0, 0)
    assert len(c._retry_waiters[k]) == 1
    # a non-owner completing must not release the gate
    c._gate_release("write", k, kw_other)
    assert c._retry_gate[k] is kw_a
    # the owner completing hands the gate to the queued retry, in order
    c._gate_release("write", k, kw_a)
    assert c._retry_gate[k] is kw_b
    assert k not in c._retry_waiters
    c._gate_release("write", k, kw_b)
    assert k not in c._retry_gate


# ======================================================= linearizability

def W(client, inv, resp, ver, val=None, ok=True, resolved=None, attempts=1):
    return HistOp(client, "write", "k", "c", inv, resp, ok, ver,
                  val if val is not None else f"{client}@{ver}",
                  resolved=ok if resolved is None else resolved,
                  attempts=attempts)


def R(client, inv, resp, ver, val=None):
    return HistOp(client, "read", "k", "c", inv, resp, True, ver, val)


def test_linearizability_clean_history_passes():
    h = [W("a", 0.0, 1.0, 1), W("b", 1.5, 2.0, 2),
         R("r", 2.1, 2.2, 2, "b@2"), R("r", 0.5, 0.9, 0)]
    assert check_linearizability(h) == []


def test_linearizability_flags_stale_read():
    h = [W("a", 0.0, 1.0, 1), R("r", 2.0, 2.1, 0)]
    v = check_linearizability(h)
    assert [x["rule"] for x in v] == ["R1"]


def test_linearizability_flags_duplicate_version_and_write_reorder():
    h = [W("a", 0.0, 1.0, 5), W("b", 2.0, 3.0, 5)]
    assert {x["rule"] for x in check_linearizability(h)} == {"W1", "W2"}
    h2 = [W("a", 0.0, 1.0, 2), W("b", 2.0, 3.0, 1)]
    assert [x["rule"] for x in check_linearizability(h2)] == ["W2"]


def test_linearizability_flags_future_read_and_value_mismatch():
    h = [W("a", 0.0, 1.0, 1), R("r", 1.2, 1.3, 7)]
    assert [x["rule"] for x in check_linearizability(h)] == ["R2"]
    h2 = [W("a", 0.0, 1.0, 1), R("r", 1.2, 1.3, 1, "not-a@1")]
    assert [x["rule"] for x in check_linearizability(h2)] == ["R3"]


def test_linearizability_unresolved_write_widens_ceiling_not_floor():
    # a timed-out write MAY have committed: reading its version is legal,
    # but it never forces later reads to see it
    h = [W("a", 0.0, 1.0, 1),
         W("b", 1.5, 9.0, None, ok=False, resolved=False),
         R("r", 2.0, 2.1, 2),            # allowed: the timeout may have landed
         R("r", 2.3, 2.4, 1, "a@1")]     # also allowed: or it may not have
    assert check_linearizability(h) == []


def test_linearizability_retry_attempts_raise_ceiling():
    # an acked write that took 3 attempts may have committed up to 3 times
    h = [W("a", 0.0, 1.0, 1, attempts=3), R("r", 1.2, 1.3, 3)]
    assert check_linearizability(h) == []
    # but with a single attempt the same read is from the future
    h2 = [W("a", 0.0, 1.0, 1), R("r", 1.2, 1.3, 3)]
    assert [x["rule"] for x in check_linearizability(h2)] == ["R2"]


def test_linearizability_respects_preload_base():
    h = [R("r", 0.1, 0.2, 1)]
    assert check_linearizability(h, {("k", "c"): 1}) == []
    assert [x["rule"] for x in check_linearizability(
        h, {("k", "c"): 2})] == ["R1"]
    # an acked write at or below the preload base is a double-commit
    h2 = [W("a", 0.0, 1.0, 1)]
    assert [x["rule"] for x in check_linearizability(
        h2, {("k", "c"): 1})] == ["W1"]


# ========================================================== availability

def test_majority_healthy_windows_full_partition_break():
    events = [FaultEvent(2.0, "partition", groups=((0,), (1,), (2, 3, 4))),
              FaultEvent(5.0, "heal")]
    w = majority_healthy_windows(events, (0, 1, 2), t_end=10.0, n_nodes=5)
    assert w == [[0.0, 2.0], [5.0, 10.0]]
    # a cohort with 2 members in the big group keeps its majority
    w2 = majority_healthy_windows(events, (2, 3, 4), t_end=10.0, n_nodes=5)
    assert w2 == [[0.0, 10.0]]


def test_majority_healthy_windows_crashes_and_oneway():
    events = [FaultEvent(1.0, "crash", node=0),
              FaultEvent(2.0, "crash", node=1),
              FaultEvent(6.0, "restart", node=1),
              FaultEvent(8.0, "partition_oneway", groups=((1,), (2,)))]
    # with node 0 down the cohort's only live majority is {1,2}; the
    # one-way cut 1->2 severs that pair, so health ends at 8s
    w = majority_healthy_windows(events, (0, 1, 2), t_end=10.0, n_nodes=5)
    assert w == [[0.0, 2.0], [6.0, 8.0]]
    # a one-way cut that leaves some mutually-connected majority ({3,4})
    # does NOT break the window — someone there can lead
    ow = [FaultEvent(2.0, "partition_oneway", groups=((2,), (3, 4)))]
    w2 = majority_healthy_windows(ow, (2, 3, 4), t_end=10.0, n_nodes=5)
    assert w2 == [[0.0, 10.0]]


def test_availability_audit_detects_probe_stall():
    events = []   # fully healthy throughout
    cohorts = {0: (0, 1, 2)}
    dense = {0: [round(0.2 * i, 3) for i in range(90)]}   # acks to 17.8s
    r = audit_availability(events, cohorts, dense, t_end=18.0,
                           recovery_bound=4.0, n_nodes=5)
    assert r["ok"], r["violations"]
    stalled = {0: [0.2, 0.4, 0.6]}    # silence from 0.6s onwards
    r2 = audit_availability(events, cohorts, stalled, t_end=18.0,
                            recovery_bound=4.0, n_nodes=5)
    assert not r2["ok"]
    assert r2["violations"][0]["rid"] == 0


# =============================================== leases: the actual fix

def test_minority_partitioned_leader_fails_over_within_lease_bound():
    """The chaos harness's red-flag scenario, fixed: leader cut into the
    minority (ZK session alive) => majority deposes it and fails over
    within lease + election; the old leader self-fences."""
    r = run_spinnaker_minority_leader(lease_enabled=True)
    bound = r["lease_duration_s"] + 1.0
    assert r["failover_s"] is not None, "majority never failed over"
    assert r["failover_s"] <= bound, (r["failover_s"], bound)
    assert not r["stalled_until_heal"]
    assert r["first_ack_gap_s"] <= bound + 0.5, r["first_ack_gap_s"]
    assert not r["old_leader_lease_valid"]
    assert r["old_leader_role"] != "LEADER"


def test_minority_partitioned_leader_stalls_without_leases():
    """Contrast run: with leases off the stale leader keeps the znode and
    the healthy majority serves nothing until the partition heals."""
    r = run_spinnaker_minority_leader(lease_enabled=False)
    assert r["failover_s"] is None
    assert r["stalled_until_heal"]
    assert r["first_ack_gap_s"] >= r["heal_at_s"] - r["partition_at_s"] - 0.5
    assert r["old_leader_role"] == "LEADER"   # still squatting


def test_partitioned_leader_fences_writes_after_lease_lapse():
    """Direct fencing check: once its lease lapses, the cut-off leader
    refuses strong writes locally (NOT_LEADER) instead of queueing them."""
    sim, cluster = make_cluster()
    k = key_of(3)
    rid = cluster.range_of(k)
    rep = cluster.leader_replica(rid)
    lid = rep.node.node_id
    cluster.partition({lid}, {n for n in cluster.nodes if n != lid})
    sim.run_for(rep.cfg.lease_duration + 0.5)
    assert not rep.lease_valid()
    from repro.core import OpType, WriteOp
    box = []
    rep.client_write(WriteOp(OpType.PUT, k, "c", b"zombie"), box.append)
    assert box and box[0].code == ErrorCode.NOT_LEADER
    cluster.heal()
    sim.run_for(3.0)
    cluster.settle()


def test_leaseholder_strong_reads_skip_read_index_round():
    """With a valid lease, strong reads are served locally; with leases
    disabled every one pays the read-index majority round trip."""
    def strong_read_latency(lease_enabled):
        sim, cluster = make_cluster(lease_enabled=lease_enabled)
        c = cluster.make_client()
        k = key_of(5)
        assert c.sync_put(k, "c", b"v").ok
        sim.run_for(1.0)
        lats = []
        for _ in range(20):
            r = c.sync_get(k, "c", consistent=True)
            assert r.ok
            lats.append(r.latency)
        return float(np.median(lats))

    with_lease = strong_read_latency(True)
    without = strong_read_latency(False)
    assert with_lease < without, (with_lease, without)


def test_timeline_monotonic_and_strong_fresh_across_asymmetric_partition():
    """Satellite: under an asymmetric (one-way) partition of the leader,
    lease expiry, and failover — monotonic timeline reads never regress
    and strong reads never return a version older than the last acked
    write at their invocation (lease-bounded staleness)."""
    sim, cluster = make_cluster(num_keys=50)
    k = key_of(7)
    rid = cluster.range_of(k)
    old = cluster.leader_replica(rid)
    old_leader, old_epoch = old.node.node_id, old.epoch

    writer = cluster.make_client("writer")
    sreader = cluster.make_client("strong")
    treader = cluster.make_client("timeline")
    acked = []          # (t_done, version)
    strong = []         # (t_invoke, version)
    timeline = []

    def write_loop(i=0):
        if sim.now > 10.0:
            return
        writer.put(k, "c", f"v{i}".encode(),
                   lambda r: (r.ok and acked.append((sim.now, r.version)),
                              sim.schedule(0.02, write_loop, i + 1))[-1])

    def strong_loop():
        if sim.now > 10.0:
            return
        t_inv = sim.now

        def got(res):
            if res.ok and res.version is not None:
                strong.append((t_inv, res.version))
            sim.schedule(0.03, strong_loop)
        sreader.get(k, "c", True, got)

    def timeline_loop():
        if sim.now > 10.0:
            return

        def got(res):
            if res.ok and res.version is not None:
                timeline.append(res.version)
            sim.schedule(0.01, timeline_loop)
        treader.get(k, "c", False, got, monotonic=True)

    write_loop(), strong_loop(), timeline_loop()
    others = {n for n in cluster.nodes if n != old_leader}
    sim.schedule(2.0, lambda: cluster.partition_oneway({old_leader}, others))
    sim.schedule(6.0, cluster.heal)
    sim.run(until=11.0)
    cluster.settle()

    # failover actually happened (the one-way cut starves lease renewals)
    now_leader = cluster.leader_replica(rid)
    assert now_leader.epoch > old_epoch
    # writes kept flowing on the majority side
    assert acked, "no writes acked at all"
    post = [v for t, v in acked if t > 4.0]
    assert post and max(post) > max(v for t, v in acked if t <= 2.0)

    # timeline monotonicity across the failover
    assert len(timeline) > 100, "timeline reader starved"
    diffs = np.diff(timeline)
    assert (diffs >= 0).all(), f"regressed at {int(np.argmin(diffs))}"

    # strong reads: never stale w.r.t. writes acked before their invoke
    assert strong, "no strong reads completed"
    ack_sorted = sorted(acked)
    import bisect as _b
    times = [t for t, _ in ack_sorted]
    pmax = []
    for _t, v in ack_sorted:
        pmax.append(max(pmax[-1], v) if pmax else v)
    for t_inv, ver in strong:
        i = _b.bisect_left(times, t_inv)
        floor = pmax[i - 1] if i else 0
        assert ver >= floor, (t_inv, ver, floor)


# ========================================================== end to end

def test_chaos_run_single_seed_all_audits_green():
    r = run_spinnaker_chaos(seed=3, duration=8.0)
    assert r["linearizability"]["ok"], r["linearizability"]["violations"][:3]
    assert r["availability"]["ok"], r["availability"]["violations"][:3]
    assert not r["lost_acked_writes"], r["lost_acked_writes"][:3]
    assert r["trace_audit"]["ok"], r["trace_audit"]
    assert r["ok"]
    assert r["history_ops"] > 1000
    assert len(r["fault_events"]) >= 5
    # every cohort's probe writer made it through the run
    assert all(n > 10 for n in r["probe_writes_acked"].values())
