"""Batched replication protocol tests: adaptive leader-side proposal
batching, cumulative acks, idle-commit suppression, and batch behaviour
across failover (the perf_opt PR's correctness surface)."""

import pytest

from repro.core import (ClusterConfig, ErrorCode, NodeConfig, ReplicaConfig,
                        Simulator, SpinnakerCluster, key_of)
from repro.core.replica import Role
from repro.core.sim import DiskParams
from repro.core.types import CommitMarker


def make_cluster(n=5, seed=0, batch="adaptive", commit_period=0.05,
                 disk="ssd", **replica_kw):
    sim = Simulator(seed=seed)
    cfg = ClusterConfig(
        n_nodes=n,
        node=NodeConfig(
            replica=ReplicaConfig(commit_period=commit_period, batch=batch,
                                  **replica_kw),
            disk=getattr(DiskParams, disk)()))
    cluster = SpinnakerCluster(sim, cfg)
    cluster.start()
    cluster.settle()
    return sim, cluster


def burst(sim, c, key, n, prefix="v"):
    results = []
    for i in range(n):
        c.put(key, "c", f"{prefix}{i}".encode(), lambda r: results.append(r))
    sim.run_for(10.0)
    return results


# ---------------------------------------------------------------------------
# batch formation and equivalence
# ---------------------------------------------------------------------------


def test_pipelined_burst_forms_batches_and_serializes():
    sim, cluster = make_cluster(batch="adaptive")
    c = cluster.make_client()
    key = key_of(5)
    results = burst(sim, c, key, 100)
    assert len(results) == 100 and all(r.ok for r in results)
    assert sorted(r.version for r in results) == list(range(1, 101))
    rep = cluster.leader_replica(cluster.range_of(key))
    # batching actually engaged: fewer flushes than records
    assert rep.batches_flushed < rep.batched_records
    assert rep.batched_records >= 100


def test_batch_off_flushes_per_record():
    sim, cluster = make_cluster(batch="off")
    c = cluster.make_client()
    key = key_of(5)
    results = burst(sim, c, key, 30)
    assert all(r.ok for r in results)
    rep = cluster.leader_replica(cluster.range_of(key))
    assert rep.batches_flushed == rep.batched_records


def test_adaptive_and_off_reach_identical_state():
    finals = {}
    for mode in ("adaptive", "off"):
        sim, cluster = make_cluster(batch=mode, seed=7)
        c = cluster.make_client()
        for i in range(40):
            c.put(key_of(i % 8), "c", f"m{i}".encode(), lambda r: None)
        sim.run_for(10.0)
        finals[mode] = {
            i: (c.sync_get(key_of(i), "c").value,
                c.sync_get(key_of(i), "c").version)
            for i in range(8)
        }
    assert finals["adaptive"] == finals["off"]


def test_cumulative_ack_supersedes_per_record_acks():
    """A follower acks once per batch with its durability watermark, so
    under a pipelined burst it sends far fewer acks than records."""
    sim, cluster = make_cluster(batch="adaptive")
    c = cluster.make_client()
    key = key_of(5)
    rid = cluster.range_of(key)
    results = burst(sim, c, key, 100)
    assert all(r.ok for r in results)
    leader = cluster.leader_replica(rid)
    followers = [cluster.nodes[m].replicas[rid] for m in cluster.cohort(rid)
                 if cluster.nodes[m].replicas[rid].role is Role.FOLLOWER]
    assert followers
    for f in followers:
        assert f.acks_sent < 100          # cumulative, not per record
        # the watermark converged to everything the leader proposed
        assert f._follower_forced == leader.lst


# ---------------------------------------------------------------------------
# conditional writes inside one batch (satellite: proposed_version checks)
# ---------------------------------------------------------------------------


def test_pipelined_conditionals_same_batch_serialize_via_proposed_version():
    """put + conditional_put pipelined back-to-back land in one batch; the
    conditional must validate against the *proposed* (not yet committed)
    version and succeed."""
    sim, cluster = make_cluster(batch="adaptive")
    c = cluster.make_client()
    key = key_of(5)
    results = []
    c.put(key, "c", b"base", lambda r: results.append(("put", r)))
    # expected_version=1 only holds if the pipelined put's proposed version
    # is visible to the conditional check
    c.conditional_put(key, "c", b"cas", 1, lambda r: results.append(("cas", r)))
    sim.run_for(5.0)
    assert dict(results)["put"].ok
    assert dict(results)["cas"].ok and dict(results)["cas"].version == 2
    got = c.sync_get(key, "c")
    assert got.value == b"cas" and got.version == 2


def test_conditional_mismatch_in_batch_rejected_without_consuming_lsn():
    sim, cluster = make_cluster(batch="adaptive")
    c = cluster.make_client()
    key = key_of(5)
    rid = cluster.range_of(key)
    assert c.sync_put(key, "c", b"v1").version == 1
    leader = cluster.leader_replica(rid)
    lst_before = leader.lst
    seq_before = leader._next_seq
    results = []
    # two CAS's expecting version 1, pipelined: only the first can win; the
    # loser is rejected synchronously, consuming no LSN
    c.conditional_put(key, "c", b"a", 1, lambda r: results.append(r))
    c.conditional_put(key, "c", b"b", 1, lambda r: results.append(r))
    sim.run_for(5.0)
    codes = sorted((r.code for r in results), key=lambda e: e.value)
    assert codes == [ErrorCode.OK, ErrorCode.VERSION_MISMATCH]
    assert leader._next_seq == seq_before + 1       # exactly one LSN consumed
    assert leader.lst == lst_before + 1
    got = c.sync_get(key, "c")
    assert got.value == b"a" and got.version == 2


# ---------------------------------------------------------------------------
# idle-commit suppression (satellites: _commit_tick / on_commit)
# ---------------------------------------------------------------------------


def test_commit_tick_silent_while_cmt_idle():
    sim, cluster = make_cluster(commit_period=0.05)
    c = cluster.make_client()
    key = key_of(5)
    rid = cluster.range_of(key)
    assert c.sync_put(key, "c", b"x").ok
    sim.run_for(1.0)        # let the post-write broadcast round happen
    leader = cluster.leader_replica(rid)
    markers_before = sum(
        1 for e in leader.node.wal.durable + [p.entry for p in
                                              leader.node.wal._buffer]
        if isinstance(e, CommitMarker) and e.range_id == rid)
    appends_before = leader.node.wal.appends
    msgs_before = cluster.net.msgs_sent
    sim.run_for(5.0)        # 100 commit periods with zero writes
    markers_after = sum(
        1 for e in leader.node.wal.durable + [p.entry for p in
                                              leader.node.wal._buffer]
        if isinstance(e, CommitMarker) and e.range_id == rid)
    assert markers_after == markers_before, "idle range appended markers"
    assert leader.node.wal.appends == appends_before
    # the only steady-state traffic left is heartbeats plus lease renewals
    # (4 small messages per range per lease tick: 2 on_lease + 2 acks,
    # 5s / 0.25s ticks x 5 ranges = 400), not on_commit spam: 5s of 0.05s
    # commit periods over 5 ranges would be >1000 on_commit messages alone
    assert cluster.net.msgs_sent - msgs_before < 300 + 450


def test_follower_skips_redundant_commit_marker():
    sim, cluster = make_cluster(commit_period=0.05)
    c = cluster.make_client()
    key = key_of(5)
    rid = cluster.range_of(key)
    assert c.sync_put(key, "c", b"x").ok
    sim.run_for(1.0)
    follower = next(cluster.nodes[m].replicas[rid]
                    for m in cluster.cohort(rid)
                    if cluster.nodes[m].replicas[rid].role is Role.FOLLOWER)
    appends_before = follower.node.wal.appends
    # duplicate broadcast of the same commit LSN must not re-append
    follower.on_commit(follower.epoch, follower.cmt)
    follower.on_commit(follower.epoch, follower.cmt)
    assert follower.node.wal.appends == appends_before


def test_idle_keepalive_heals_missed_commit_broadcast():
    """A follower that holds a committed record but missed the (single)
    progress broadcast through a brief partition must still converge via
    the slow idle keepalive — idle-skip must not mean stale-forever."""
    sim, cluster = make_cluster(commit_period=0.05)
    c = cluster.make_client()
    key = key_of(5)
    rid = cluster.range_of(key)
    follower_id = next(m for m in cluster.cohort(rid)
                       if cluster.nodes[m].replicas[rid].role is Role.FOLLOWER)
    # commit a write (followers hold + acked the record), then cut the
    # follower off before the commit broadcast fires
    assert c.sync_put(key, "c", b"x").ok
    others = {n for n in range(5) if n != follower_id}
    cluster.partition({follower_id}, others)
    sim.run_for(0.3)            # progress broadcast dropped on the floor
    cluster.heal()
    sim.run_for(3.0)            # > _IDLE_REBCAST_TICKS * commit_period
    rep = cluster.nodes[follower_id].replicas[rid]
    cell = rep.store.get(key, "c")
    assert cell is not None and cell.value == b"x", \
        "follower never learned the commit despite the idle keepalive"


# ---------------------------------------------------------------------------
# failover with batches in flight (Fig. 9 correctness)
# ---------------------------------------------------------------------------


def test_leader_kill_with_inflight_batches_no_acked_write_lost():
    sim, cluster = make_cluster(batch="adaptive", seed=11)
    c = cluster.make_client()
    key = key_of(5)
    rid = cluster.range_of(key)
    old_leader = cluster.leader_replica(rid)
    acked = []
    for i in range(60):
        c.put(key, "c", f"w{i}".encode(), lambda r, i=i: acked.append((i, r)))
    sim.run_for(0.02)   # mid-burst: batches staged/in flight
    cluster.crash_node(old_leader.node.node_id)
    sim.run_for(20.0)
    new_leader = cluster.leader_replica(rid)
    assert new_leader is not None
    assert new_leader.node.node_id != old_leader.node.node_id
    committed = [i for i, r in acked if r.ok]
    assert committed, "no write survived the failover burst"
    got = c.sync_get(key, "c", consistent=True)
    assert got.ok
    # every acked write is durable: version count matches acked count and
    # the latest acked value (or a later one the new regime re-committed)
    # is visible
    assert got.version >= len(committed)
    # monotonic versions: re-proposed batch must not double-apply
    assert sorted(r.version for _, r in acked if r.ok) == \
        sorted(set(r.version for _, r in acked if r.ok))


def test_leader_kill_between_watermark_ack_and_commit_broadcast():
    """PR 10 ack-coalescing window: the leader acks a write the moment its
    majority-durability watermark covers it, and the commit marker reaches
    followers only later (piggybacked on the next proposal batch or the
    commit tick).  Kill the leader inside that window: the acked write is
    durable on a follower majority, so the new regime must surface it —
    exactly one ack, zero lost, and the invariant watchdog (notably
    acked_durable / acked_committed_majority) stays silent throughout."""
    from repro.obs import ObsConfig

    sim = Simulator(seed=13)
    cfg = ClusterConfig(
        n_nodes=5,
        node=NodeConfig(replica=ReplicaConfig(
            batch="adaptive", commit_period=0.5)),   # lagging commit tick
        obs=ObsConfig(journal=True, watchdog=True))
    cluster = SpinnakerCluster(sim, cfg)
    cluster.start()
    cluster.settle()
    c = cluster.make_client()
    key = key_of(5)
    rid = cluster.range_of(key)
    leader = cluster.leader_replica(rid)
    acks = []
    c.put(key, "c", b"windowed", acks.append)
    # step until the client holds the ack, then stop immediately — the
    # long commit period guarantees the marker broadcast has not fired
    for _ in range(10_000):
        sim.step()
        if acks:
            break
    assert [r.ok for r in acks] == [True], acks
    lsn = leader.lst
    followers = [cluster.nodes[m].replicas[rid] for m in cluster.cohort(rid)
                 if cluster.nodes[m].replicas[rid].role is Role.FOLLOWER]
    # precondition: we really are inside the window — the cohort holds the
    # record durably but nobody learned the commit marker yet
    assert leader.cmt >= lsn
    assert all(f.cmt < lsn for f in followers), \
        "commit marker already broadcast; window missed"
    assert sum(f._follower_forced >= lsn for f in followers) \
        >= len(followers) - 1
    cluster.crash_node(leader.node.node_id)
    sim.run_for(20.0)
    new_leader = cluster.leader_replica(rid)
    assert new_leader is not None
    assert new_leader.node.node_id != leader.node.node_id
    # the acked write survived the failover and was committed exactly once
    got = c.sync_get(key, "c", consistent=True)
    assert got.ok and got.value == b"windowed" and got.version == 1
    assert len(acks) == 1, "client must see exactly one ack"
    wd = cluster.obs.watchdog.summary()
    assert wd["ok"], wd["violations"][:3]


def test_crash_drops_staged_batch_cleanly():
    """Crash a leader with a record still staged in the accumulator (the
    deadline flush never fired): the staged batch dies with the leader's
    volatile state and the cohort keeps a single consistent history."""
    from repro.core.types import OpType, WriteOp

    sim, cluster = make_cluster(batch="adaptive", seed=3,
                                batch_deadline=50e-3)
    c = cluster.make_client()
    key = key_of(5)
    rid = cluster.range_of(key)
    leader = cluster.leader_replica(rid)
    assert c.sync_put(key, "c", b"committed").ok
    # stage a record while the CPU looks queued so it accumulates instead
    # of flushing immediately (direct call: the point is protocol state)
    leader.node.cpu.busy_until = sim.now + 1.0      # simulate queueing
    replies = []
    leader.client_write(WriteOp(OpType.PUT, key, "c", b"staged"),
                        lambda r: replies.append(r))
    assert len(leader._batch) == 1, "record should be staged, not flushed"
    # crash before the deadline flush: the batch dies with the leader
    cluster.crash_node(leader.node.node_id)
    sim.run_for(20.0)
    new_leader = cluster.leader_replica(rid)
    assert new_leader is not None
    assert not any(r.ok for r in replies), "staged write must not ack"
    got = c.sync_get(key, "c", consistent=True)
    assert got.ok and got.value == b"committed" and got.version == 1
    res = c.sync_put(key, "c", b"after")
    assert res.ok and res.version == 2
