"""End-to-end model forward through the Pallas kernels (interpret mode):
cfg.attn_impl='pallas' must match the XLA path on whole-model outputs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.shapes import make_batch
from repro.models import forward, init_params


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma-7b"])
def test_model_forward_pallas_flash_attention(arch):
    cfg = smoke_config(arch).scaled(remat=False, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = make_batch(cfg, rng, batch=2, seq=64)
    ref, _, _ = forward(params, batch, cfg)
    out, _, _ = forward(params, batch, cfg.scaled(attn_impl="pallas"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_model_forward_pallas_ssd(arch="mamba2-2.7b"):
    cfg = smoke_config(arch).scaled(remat=False, dtype="float32",
                                    ssm_chunk=16)
    params = init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    batch = make_batch(cfg, rng, batch=2, seq=64)
    ref, _, _ = forward(params, batch, cfg)
    out, _, _ = forward(params, batch, cfg.scaled(attn_impl="pallas"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_model_forward_chunked_attention_matches():
    cfg = smoke_config("gemma-7b").scaled(remat=False, dtype="float32")
    params = init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    batch = make_batch(cfg, rng, batch=2, seq=48)
    ref, _, _ = forward(params, batch, cfg)
    out, _, _ = forward(params, batch, cfg.scaled(attn_impl="xla_chunked"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_model_forward_bhsd_matches():
    cfg = smoke_config("musicgen-large").scaled(remat=False, dtype="float32")
    params = init_params(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    batch = make_batch(cfg, rng, batch=2, seq=32)
    ref, _, _ = forward(params, batch, cfg)
    out, _, _ = forward(params, batch, cfg.scaled(attn_impl="xla_bhsd"))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
