"""Generate the EXPERIMENTS.md roofline tables from dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

ARCH_ORDER = ["mistral-large-123b", "smollm-360m", "gemma-7b",
              "deepseek-coder-33b", "phi-3-vision-4.2b", "kimi-k2-1t-a32b",
              "phi3.5-moe-42b-a6.6b", "zamba2-7b", "musicgen-large",
              "mamba2-2.7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def load(dirpath: Path, mesh: str, tag: str = "") -> dict:
    out = {}
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            cell = f"{arch}__{shape}__{mesh}" + (f"__{tag}" if tag else "")
            p = dirpath / f"{cell}.json"
            if p.exists():
                out[(arch, shape)] = json.loads(p.read_text())
    return out


def one_sentence(rec) -> str:
    r = rec["roofline"]
    dom = r["dominant"]
    if dom == "memory":
        return ("chunked/flash attention + bf16 cache cuts HBM traffic"
                if rec["shape"] != "train_4k"
                else "remove naive-attention score materialisation (chunked"
                     "/flash) to cut HBM bytes")
    if dom == "collective":
        if rec["arch"].startswith(("kimi", "phi3.5")):
            return "shard_map all-to-all MoE dispatch instead of GSPMD " \
                   "gather (drops token all-gathers)"
        return "reshard: batch-only TP for small models / bigger per-" \
               "device batch to amortise gradient reduce"
    return "larger per-chip tile (batch/seq) or fewer remat recomputes"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    d = Path(args.dir)
    cells = load(d, args.mesh, args.tag)

    print("| arch | shape | chips | compute | memory | collective | "
          "dominant | MODEL_FLOPS | useful | MFU@roofline | mem/chip |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = cells.get((arch, shape))
            if rec is None:
                continue
            if rec.get("status") == "skipped":
                print(f"| {arch} | {shape} | — | — | — | — | skipped | — |"
                      f" — | — | — |")
                continue
            r = rec["roofline"]
            mem = rec.get("memory") or {}
            per_dev = ((mem.get("argument_bytes") or 0)
                       + (mem.get("temp_bytes") or 0)) / 1e9
            print(f"| {arch} | {shape} | {rec['chips']} "
                  f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                  f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
                  f"| {r['model_flops']:.2e} | {r['useful_ratio']:.2f} "
                  f"| {r['mfu']*100:.2f}% | {per_dev:.1f}GB |")
    print()
    print("### Bottleneck notes (what would move the dominant term)")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = cells.get((arch, shape))
            if rec is None or rec.get("status") == "skipped":
                continue
            print(f"- **{arch} × {shape}**: {one_sentence(rec)}")


if __name__ == "__main__":
    main()
