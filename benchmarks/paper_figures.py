"""One benchmark per paper table/figure (§9 + App. D).

Each function returns (rows, validation) where rows are CSV lines and
validation is a dict of claim-checks against the paper's stated results.
"""

from __future__ import annotations

import numpy as np

from repro.core import key_of

from .common import (VALUE_4K, fmt_curve, make_cassandra, make_spinnaker,
                     preload, preload_cassandra, rand_keys, run_closed_loop)

THREADS = (1, 2, 4, 8, 16, 32)


def _spin_read_issue(cluster, client, keys, consistent):
    rng = np.random.default_rng(1)
    idx = rng.integers(0, len(keys), 1 << 20)
    ctr = [0]

    def issue(tid, cb):
        ctr[0] += 1
        client.get(keys[idx[ctr[0] % len(idx)]], "c", consistent, cb)
    return issue


def _cass_read_issue(cluster, client, keys, quorum):
    rng = np.random.default_rng(1)
    idx = rng.integers(0, len(keys), 1 << 20)
    ctr = [0]

    def issue(tid, cb):
        ctr[0] += 1
        client.read(keys[idx[ctr[0] % len(idx)]], "c", quorum, cb)
    return issue


# ---------------------------------------------------------------------------
# Figure 8: read latency vs load
# ---------------------------------------------------------------------------


def fig8_read_latency(threads=THREADS):
    rows, curves = [], {}
    keys = rand_keys(0, 800)
    for name, consistent in (("spinnaker_consistent", True),
                             ("spinnaker_timeline", False)):
        pts = []
        for t in threads:
            sim, cluster = make_spinnaker(seed=10 + t)
            client = cluster.make_client()
            preload(cluster, client, keys)
            pts.append(run_closed_loop(
                sim, _spin_read_issue(cluster, client, keys, consistent), t))
        curves[name] = pts
        rows.append(fmt_curve(f"fig8/{name}", pts))
    for name, quorum in (("cassandra_weak", False),
                         ("cassandra_quorum", True)):
        pts = []
        for t in threads:
            sim, cluster = make_cassandra(seed=10 + t)
            client = cluster.make_client()
            preload_cassandra(cluster, client, keys)
            pts.append(run_closed_loop(
                sim, _cass_read_issue(cluster, client, keys, quorum), t))
        curves[name] = pts
        rows.append(fmt_curve(f"fig8/{name}", pts))

    # paper claims: quorum read 1.5–3.0x worse than consistent read;
    # timeline ≈ weak read
    mid = len(threads) // 2
    ratio_q = np.mean([curves["cassandra_quorum"][i].mean_ms
                       / curves["spinnaker_consistent"][i].mean_ms
                       for i in range(mid, len(threads))])
    ratio_t = np.mean([curves["spinnaker_timeline"][i].mean_ms
                       / curves["cassandra_weak"][i].mean_ms
                       for i in range(len(threads))])
    validation = {
        "quorum_vs_consistent_ratio(understress)": round(float(ratio_q), 2),
        "paper_range": "1.5-3.0",
        "timeline_vs_weak_ratio": round(float(ratio_t), 2),
        "paper_timeline≈weak": "≈1.0",
    }
    return rows, validation


# ---------------------------------------------------------------------------
# Figure 9: write latency vs load
# ---------------------------------------------------------------------------


def _spin_write_issue(client, keys):
    ctr = [0]

    def issue(tid, cb):
        ctr[0] += 1
        client.put(keys[(ctr[0] * 7 + tid) % len(keys)], "c", VALUE_4K, cb)
    return issue


def _cass_write_issue(client, keys, quorum=True):
    ctr = [0]

    def issue(tid, cb):
        ctr[0] += 1
        client.write(keys[(ctr[0] * 7 + tid) % len(keys)], "c", VALUE_4K,
                     quorum, cb)
    return issue


def fig9_write_latency(threads=THREADS, disk="hdd"):
    rows, curves = [], {}
    keys = [key_of(i * 16) for i in range(2000)]   # consecutive rows (§9.2)
    pts = []
    for t in threads:
        sim, cluster = make_spinnaker(seed=20 + t, disk=disk)
        client = cluster.make_client()
        pts.append(run_closed_loop(sim, _spin_write_issue(client, keys), t))
    curves["spinnaker_write"] = pts
    rows.append(fmt_curve(f"fig9/spinnaker_write[{disk}]", pts))
    pts = []
    for t in threads:
        sim, cluster = make_cassandra(seed=20 + t, disk=disk)
        client = cluster.make_client()
        pts.append(run_closed_loop(sim, _cass_write_issue(client, keys), t))
    curves["cassandra_quorum_write"] = pts
    rows.append(fmt_curve(f"fig9/cassandra_quorum_write[{disk}]", pts))

    overhead = np.mean([curves["spinnaker_write"][i].mean_ms
                        / curves["cassandra_quorum_write"][i].mean_ms
                        for i in range(len(threads))]) - 1.0
    validation = {
        "spinnaker_write_overhead_vs_cassandra_quorum":
            f"{overhead * 100:+.1f}%",
        "paper_claim": "+5% to +10%",
    }
    return rows, validation


# ---------------------------------------------------------------------------
# Table 1: cohort recovery time vs commit period
# ---------------------------------------------------------------------------


def table1_recovery(commit_periods=(1.0, 5.0, 10.0, 15.0), load_threads=24):
    rows = []
    times = {}
    for cp in commit_periods:
        sim, cluster = make_spinnaker(n_nodes=3, seed=30, commit_period=cp)
        client = cluster.make_client()
        # §D.1: writes routed to a single cohort's leader
        rid = 0
        keys = [key_of(i) for i in range(500)]

        def issue(tid, cb, keys=keys):
            issue.c = getattr(issue, "c", 0) + 1
            client.put(keys[(issue.c + tid) % len(keys)], "c", VALUE_4K, cb)

        for t in range(load_threads):
            def loop(tid=t):
                def cb(res):
                    loop()
                issue(tid, cb)
            loop()
        # crash lands (2 + 0.5·cp) mod cp ≈ proportionally deep into the
        # commit period, so the un-commit-messaged backlog scales with cp
        sim.run_for(2.0 + cp * 1.5)

        leader = cluster.leader_replica(rid)
        t_kill = sim.now
        # §D.1 excludes the ZK detection timeout: expire session immediately
        cluster.crash_node(leader.node.node_id, expire_session=True)

        # recovery time = until the cohort is open for writes again (new
        # leader elected, unresolved window re-committed — Fig. 6 line 10)
        deadline = sim.now + 120.0
        while sim.now < deadline:
            if cluster.leader_replica(rid) is not None:
                break
            sim.run(until=sim.now + 0.001)
        rec_t = (sim.now - t_kill) \
            if cluster.leader_replica(rid) is not None else float("nan")
        times[cp] = rec_t
        rows.append(f"table1/recovery,commit_period={cp:.0f}s,"
                    f"recovery_time={rec_t:.3f}s")
    cps = list(commit_periods)
    monotone = all(times[cps[i]] <= times[cps[i + 1]] + 0.05
                   for i in range(len(cps) - 1))
    validation = {
        "recovery_times_s": {f"{cp:.0f}": round(times[cp], 3) for cp in cps},
        "paper_times_s": {"1": 0.4, "5": 1.5, "10": 2.6, "15": 4.0},
        "proportional_to_commit_period": monotone,
        "sub_second_at_1s_commit_period": times[cps[0]] < 1.0,
    }
    return rows, validation


# ---------------------------------------------------------------------------
# Figure 11: scaling (cluster size)
# ---------------------------------------------------------------------------


def fig11_scaling(sizes=(20, 40, 80), threads_per_node=2):
    rows = []
    means = {}
    for n in sizes:
        sim, cluster = make_spinnaker(n_nodes=n, seed=40)
        client = cluster.make_client()
        keys = rand_keys(2, 1000, num_keys=100_000)
        p = run_closed_loop(sim, _spin_write_issue(client, keys),
                            threads_per_node * n // 10, warmup=1.0,
                            measure=3.0)
        means[n] = p.mean_ms
        rows.append(f"fig11/spinnaker,nodes={n},mean={p.mean_ms:.2f}ms,"
                    f"tput={p.tput:.0f}/s")
    flat = max(means.values()) / min(means.values())
    validation = {
        "latency_spread_across_sizes": f"{flat:.2f}x",
        "paper_claim": "roughly constant (write touches 3 nodes regardless "
                       "of cluster size)",
        "flat_within_30pct": flat < 1.3,
    }
    return rows, validation


# ---------------------------------------------------------------------------
# Figure 12: mixed reads/writes
# ---------------------------------------------------------------------------


def fig12_mixed(write_pcts=(10, 30, 50), threads=2):
    rows = []
    curves = {}
    keys = rand_keys(3, 800)
    for name in ("spin_consistent", "spin_timeline", "cass_quorum",
                 "cass_weak"):
        curves[name] = {}
    for wp in write_pcts:
        for name in curves:
            spin = name.startswith("spin")
            if spin:
                sim, cluster = make_spinnaker(seed=50 + wp)
                client = cluster.make_client()
                preload(cluster, client, keys)
            else:
                sim, cluster = make_cassandra(seed=50 + wp)
                client = cluster.make_client()
                preload_cassandra(cluster, client, keys)
            rng = np.random.default_rng(wp)
            choices = rng.integers(0, 100, 1 << 16)
            ctr = [0]

            def issue(tid, cb, spin=spin, name=name, client=client):
                ctr[0] += 1
                k = keys[(ctr[0] * 13 + tid) % len(keys)]
                write = choices[ctr[0] % len(choices)] < wp
                if spin:
                    if write:
                        client.put(k, "c", VALUE_4K, cb)
                    else:
                        client.get(k, "c", name.endswith("consistent"), cb)
                else:
                    if write:
                        client.write(k, "c", VALUE_4K, True, cb)
                    else:
                        client.read(k, "c", name.endswith("quorum"), cb)

            p = run_closed_loop(sim, issue, threads, warmup=1.0, measure=4.0)
            curves[name][wp] = p.mean_ms
            rows.append(f"fig12/{name},write_pct={wp},mean={p.mean_ms:.2f}ms")
    v10 = curves["spin_consistent"][write_pcts[0]] \
        / curves["cass_quorum"][write_pcts[0]]
    v50 = curves["spin_consistent"][write_pcts[-1]] \
        / curves["cass_quorum"][write_pcts[-1]]
    validation = {
        "consistent_vs_quorum@10%writes": f"{(v10 - 1) * 100:+.0f}%",
        "consistent_vs_quorum@50%writes": f"{(v50 - 1) * 100:+.0f}%",
        "paper": "spinnaker ~10% better @10% writes; ~7% worse @50%",
    }
    return rows, validation


# ---------------------------------------------------------------------------
# Figure 13 / 16: SSD log and main-memory log
# ---------------------------------------------------------------------------


def fig13_ssd_log(threads=(2, 8, 16)):
    rows, validation = fig9_write_latency(threads=threads, disk="ssd")
    rows = [r.replace("fig9/", "fig13/") for r in rows]
    # paper: ≤ 6 ms writes in most cases on SSD
    mean_vals = [float(part.split("mean=")[1].split("ms")[0])
                 for r in rows for part in r.split("\n")]
    validation = {"max_mean_ms": max(mean_vals), "paper_claim": "<=6ms",
                  "meets": max(mean_vals) <= 6.0}
    return rows, validation


def fig16_memlog(threads=(2, 8, 16)):
    rows = []
    keys = [key_of(i * 16) for i in range(2000)]
    pts = []
    for t in threads:
        sim, cluster = make_spinnaker(seed=60 + t, disk="mem")
        client = cluster.make_client()
        pts.append(run_closed_loop(sim, _spin_write_issue(client, keys), t))
    rows.append(fmt_curve("fig16/spinnaker_memlog_write", pts))
    mean2 = pts[0].mean_ms
    validation = {"mean_ms_low_load": round(mean2, 2),
                  "paper_claim": "~2ms", "within_2x": mean2 < 4.0}
    return rows, validation


# ---------------------------------------------------------------------------
# Figure 14: conditional put
# ---------------------------------------------------------------------------


def fig14_conditional_put(threads=(2, 8, 16)):
    rows = []
    keys = [key_of(i * 16) for i in range(1000)]
    curves = {}
    for name in ("put", "conditional_put"):
        pts = []
        for t in threads:
            sim, cluster = make_spinnaker(seed=70 + t)
            client = cluster.make_client()
            preload(cluster, client, keys)
            versions = {k: 1 for k in keys}
            ctr = [0]

            def issue(tid, cb, name=name, client=client, versions=versions):
                ctr[0] += 1
                k = keys[(ctr[0] * 3 + tid) % len(keys)]
                if name == "put":
                    client.put(k, "c", VALUE_4K, cb)
                else:
                    def on_done(res, k=k):
                        if res.ok:
                            versions[k] = res.version
                        cb(res)
                    client.conditional_put(k, "c", VALUE_4K, versions[k],
                                           on_done)
            pts.append(run_closed_loop(sim, issue, t))
        curves[name] = pts
        rows.append(fmt_curve(f"fig14/{name}", pts))
    overhead = np.mean([curves["conditional_put"][i].mean_ms
                        / curves["put"][i].mean_ms
                        for i in range(len(threads))]) - 1.0
    validation = {"conditional_put_overhead": f"{overhead * 100:+.1f}%",
                  "paper_claim": "marginally worse than put"}
    return rows, validation


# ---------------------------------------------------------------------------
# Figure 15: weak vs quorum writes in Cassandra
# ---------------------------------------------------------------------------


def fig15_weak_writes(threads=(2, 8, 16)):
    rows = []
    keys = [key_of(i * 16) for i in range(2000)]
    curves = {}
    for name, quorum in (("weak", False), ("quorum", True)):
        pts = []
        for t in threads:
            sim, cluster = make_cassandra(seed=80 + t)
            client = cluster.make_client()
            pts.append(run_closed_loop(
                sim, _cass_write_issue(client, keys, quorum), t))
        curves[name] = pts
        rows.append(fmt_curve(f"fig15/cassandra_{name}_write", pts))
    slowdown = np.mean([curves["quorum"][i].mean_ms
                        / curves["weak"][i].mean_ms
                        for i in range(len(threads))]) - 1.0
    validation = {"quorum_slower_than_weak": f"{slowdown * 100:+.0f}%",
                  "paper_claim": "+40% to +50%"}
    return rows, validation
