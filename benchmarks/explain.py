"""Offline protocol-journal replayer and root-cause explainer.

    PYTHONPATH=src python benchmarks/explain.py DUMP.jsonl [--rid N]
        [--stall T_LO T_HI] [--json]
    PYTHONPATH=src python benchmarks/explain.py --demo takeover_wedge
    PYTHONPATH=src python benchmarks/explain.py --demo chaos --seed 3

Consumes a protocol-journal dump (`ProtocolJournal.to_jsonl`) — the
flight recorder every run keeps for free — and answers the debugging
questions a failed run raises:

- **per-range timeline**: leadership regimes reconstructed from the
  journal (takeover → open-for-writes → commits → how the regime ended:
  abdication, lease lapse, deposal, crash), with election context
  (candidate set, winner's LST vs the cohort max);
- **failover narratives**: one paragraph per regime change — who took
  over, why the predecessor fell, how long until writes reopened;
- **stall explanations**: for a `[t_lo, t_hi]` window, which spans had
  no open leader and what the range was doing instead (elections,
  catch-up, a wedged takeover);
- **anomaly signatures**: named patterns matched against the whole dump
  — `takeover_wedge` (a takeover advertising records it cannot re-send,
  or a range cycling through regimes that never reopen),
  `catchup_starvation` (a CATCHUP replica hearing a live leader's lease
  beats yet making no progress), `split_brain_precursor` (overlapping
  lease claims, classified benign-handoff vs genuine overlap);
- **invariant replay**: the online watchdog re-run offline over the
  dump (`InvariantWatchdog.replay`), so a dump from a run that had the
  watchdog disabled still gets the full invariant sweep.

`--demo` runs a known scenario in-process (a seeded chaos schedule or a
mutation-corpus bug), dumps its journal to a JSONL file, and explains
that dump — the end-to-end path a real postmortem takes.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs.journal import ProtocolJournal  # noqa: E402
from repro.obs.watchdog import InvariantWatchdog  # noqa: E402

LSN_SEQ_BITS = 40


def _fmt_lsn(lsn) -> str:
    if lsn is None:
        return "-"
    return f"{lsn >> LSN_SEQ_BITS}.{lsn & ((1 << LSN_SEQ_BITS) - 1)}"


def load_entries(path: str) -> list[dict]:
    return ProtocolJournal.load_jsonl(Path(path).read_text())


def journal_rids(entries: list[dict]) -> list[int]:
    return sorted({e["rid"] for e in entries if "rid" in e})


# -- per-range regime reconstruction ----------------------------------------


def regimes(entries: list[dict], rid: int) -> list[dict]:
    """Leadership regimes of one range, in order.  A regime starts at a
    `takeover` entry and ends at the leader's abdication / lease lapse /
    deposal / crash (or when a later takeover supersedes it)."""
    regs: list[dict] = []
    cur: dict | None = None
    election: dict | None = None
    for e in entries:
        erid = e.get("rid")
        if erid is not None and erid != rid:
            continue
        k = e["kind"]
        if k == "elect_decide" and erid == rid:
            # stash election context for the takeover that follows it
            election = e
        elif k == "takeover":
            if cur is not None and cur["t_end"] is None:
                cur["t_end"] = e["t"]
                cur["end_reason"] = "superseded by next takeover"
            cur = {
                "rid": rid, "epoch": e.get("epoch"), "leader": e["node"],
                "t_takeover": e["t"], "t_open": None, "t_end": None,
                "end_reason": None, "n_commits": 0, "last_commit_lsn": None,
                "cmt_at_takeover": e.get("cmt"),
                "lst_at_takeover": e.get("lst"),
                "unresolved": e.get("unresolved", 0),
                "missing": e.get("missing", 0),
                "election": None,
            }
            if election is not None and election.get("winner") == e["node"]:
                cur["election"] = {
                    "candidates": election.get("candidates"),
                    "winner_lst": election.get("winner_lst"),
                    "max_lst": election.get("max_lst"),
                }
            regs.append(cur)
        elif cur is None:
            continue
        elif (k == "leader_open" and e["node"] == cur["leader"]
              and e.get("epoch") == cur["epoch"]):
            if cur["t_open"] is None:
                cur["t_open"] = e["t"]
        elif k == "commit" and e["node"] == cur["leader"]:
            cur["n_commits"] += 1
            cur["last_commit_lsn"] = e.get("lsn")
        elif cur["t_end"] is not None:
            continue
        elif (k in ("abdicate", "lease_lapse")
              and e["node"] == cur["leader"]):
            why = e.get("why", "")
            cur["t_end"] = e["t"]
            cur["end_reason"] = f"{k}({why})" if why else k
        elif k == "deposed" and e.get("leader") == cur["leader"]:
            cur["t_end"] = e["t"]
            cur["end_reason"] = f"deposed by node {e['node']}"
        elif (k == "node_crash" and erid is None
              and e["node"] == cur["leader"]):
            cur["t_end"] = e["t"]
            cur["end_reason"] = ("leader crashed (disk lost)"
                                 if e.get("lose_disk") else "leader crashed")
    return regs


def explain_failover(entries: list[dict], rid: int) -> list[str]:
    """One narrative paragraph per leadership regime of `rid`."""
    lines: list[str] = []
    prev = None
    for reg in regimes(entries, rid):
        head = (f"t={reg['t_takeover']:.3f}s range {rid} epoch "
                f"{reg['epoch']}: node {reg['leader']} took over "
                f"(cmt={_fmt_lsn(reg['cmt_at_takeover'])}, "
                f"lst={_fmt_lsn(reg['lst_at_takeover'])}, "
                f"{reg['unresolved']} unresolved)")
        if prev is not None and prev["t_end"] is not None:
            gap = (reg["t_takeover"] - prev["t_end"]) * 1e3
            head += (f" — {gap:.0f}ms after epoch {prev['epoch']} ended "
                     f"[{prev['end_reason']}]")
        lines.append(head)
        if reg["election"]:
            el = reg["election"]
            lines.append(
                f"    elected from candidates {el['candidates']} "
                f"(winner lst={_fmt_lsn(el['winner_lst'])}, cohort max "
                f"lst={_fmt_lsn(el['max_lst'])})")
        if reg["missing"]:
            lines.append(
                f"    TAKEOVER INCOMPLETE: {reg['missing']} durable "
                f"record(s) of the unresolved window were not reloaded — "
                f"the regime advertises an LST it can never re-send "
                f"(takeover-wedge signature)")
        if reg["t_open"] is not None:
            dt = (reg["t_open"] - reg["t_takeover"]) * 1e3
            lines.append(f"    opened for writes +{dt:.0f}ms; "
                         f"{reg['n_commits']} commit advance(s), last "
                         f"cmt={_fmt_lsn(reg['last_commit_lsn'])}")
        else:
            lines.append("    NEVER OPENED for writes"
                         + (f"; ended t={reg['t_end']:.3f}s "
                            f"[{reg['end_reason']}]"
                            if reg["t_end"] is not None else
                            " (still closed at end of dump)"))
        if reg["t_open"] is not None and reg["t_end"] is not None:
            lines.append(f"    ended t={reg['t_end']:.3f}s "
                         f"[{reg['end_reason']}]")
        prev = reg
    if not lines:
        lines.append(f"range {rid}: no takeover entries in dump")
    return lines


def explain_stall(entries: list[dict], rid: int,
                  t_lo: float, t_hi: float) -> list[str]:
    """Why did range `rid` stall in [t_lo, t_hi]?  Reports the sub-spans
    with no open leader and what the cohort was doing instead."""
    regs = regimes(entries, rid)
    lines = [f"range {rid}, window [{t_lo:.3f}s, {t_hi:.3f}s]:"]
    # open intervals: [t_open, t_end-or-inf) per regime
    open_spans = [(r["t_open"], r["t_end"] if r["t_end"] is not None
                   else float("inf"), r)
                  for r in regs if r["t_open"] is not None]
    t = t_lo
    covered = []
    for lo, hi, r in sorted(open_spans):
        if hi <= t_lo or lo >= t_hi:
            continue
        covered.append((max(lo, t_lo), min(hi, t_hi), r))
    if not covered:
        lines.append("  no open leader at any point in the window")
    gaps = []
    for lo, hi, r in covered:
        if lo > t + 1e-9:
            gaps.append((t, lo))
        lines.append(f"  [{lo:.3f}, {hi:.3f}] node {r['leader']} open "
                     f"(epoch {r['epoch']})")
        t = max(t, hi)
    if t < t_hi - 1e-9:
        gaps.append((t, t_hi))
    for lo, hi in gaps:
        lines.append(f"  [{lo:.3f}, {hi:.3f}] NO LEADER OPEN "
                     f"({(hi - lo) * 1e3:.0f}ms write stall)")
        # what was the cohort doing during the gap?
        doing: dict[str, int] = {}
        for e in entries:
            if e.get("rid") == rid and lo <= e["t"] <= hi:
                doing[e["kind"]] = doing.get(e["kind"], 0) + 1
        busy = {k: n for k, n in sorted(doing.items())
                if k not in ("append", "flush", "ack", "commit",
                             "commit_idx", "lease_renew", "lease_heard")}
        if busy:
            lines.append(f"    cohort activity: "
                         + ", ".join(f"{k}×{n}" for k, n in busy.items()))
    return lines


# -- anomaly signatures ------------------------------------------------------


def sig_takeover_wedge(entries: list[dict]) -> list[dict]:
    """A takeover that advertised durable records it cannot re-send
    (`missing` > 0), or a range cycling through regimes that never
    reopen for writes."""
    out = []
    for rid in journal_rids(entries):
        regs = regimes(entries, rid)
        for r in regs:
            if r["missing"]:
                out.append({
                    "rid": rid, "t": r["t_takeover"], "severity": "bug",
                    "detail": f"epoch {r['epoch']} takeover by node "
                              f"{r['leader']} is missing {r['missing']} "
                              f"durable record(s) of its unresolved window",
                })
        never_open = [r for r in regs if r["t_open"] is None
                      and r["t_end"] is not None]
        if len(never_open) >= 2:
            out.append({
                "rid": rid, "t": never_open[0]["t_takeover"],
                "severity": "warning",
                "detail": f"{len(never_open)} successive regimes (epochs "
                          f"{[r['epoch'] for r in never_open]}) never "
                          f"reopened for writes — the range is wedged",
            })
    return out


def sig_catchup_starvation(entries: list[dict],
                           stall_s: float = 2.0) -> list[dict]:
    """A CATCHUP replica hearing a live leader's lease beats yet never
    completing catch-up — the retry clock is not firing."""
    out = []
    episodes: dict[tuple, dict] = {}
    for e in entries:
        key = (e["node"], e.get("rid"))
        k = e["kind"]
        if k == "catchup_enter":
            episodes[key] = {"t_enter": e["t"], "t_last_req": e["t"],
                             "beats": 0, "t_last_beat": None}
        elif key not in episodes:
            continue
        elif k == "catchup_retry":
            episodes[key]["t_last_req"] = e["t"]
        elif k == "lease_heard" and e.get("role") == "CATCHUP":
            ep = episodes[key]
            ep["beats"] += 1
            ep["t_last_beat"] = e["t"]
        elif k in ("catchup_exit", "node_crash", "replica_retired"):
            episodes.pop(key, None)
    for (node, rid), ep in sorted(episodes.items()):
        if ep["beats"] < 3 or ep["t_last_beat"] is None:
            continue
        starved = ep["t_last_beat"] - max(ep["t_enter"], ep["t_last_req"])
        if starved > stall_s:
            out.append({
                "rid": rid, "t": ep["t_last_beat"], "severity": "bug",
                "detail": f"node {node} sat in CATCHUP for {starved:.2f}s "
                          f"hearing {ep['beats']} leader lease beats "
                          f"without re-requesting data",
            })
    return out


def sig_split_brain_precursor(entries: list[dict]) -> list[dict]:
    """Two simultaneously-live lease claims on one range.  A claim by a
    strictly newer epoch overlapping the old one is the bounded takeover
    handoff (benign, epoch-fenced); same-or-older epoch overlap is the
    genuine precursor the watchdog's `lease_disjoint` hardens against."""
    out = []
    claims: dict[tuple, dict] = {}        # (rid, node) -> lease entry
    for e in entries:
        k = e["kind"]
        rid = e.get("rid")
        if k == "lease_acquire":
            t, until = e["t"], e.get("until", 0.0)
            for (crid, cnode), c in list(claims.items()):
                if crid != rid or cnode == e["node"]:
                    continue
                if c.get("until", 0.0) > t + 1e-9:
                    newer = (e.get("epoch") or 0) > (c.get("epoch") or 0)
                    out.append({
                        "rid": rid, "t": t,
                        "severity": "benign-handoff" if newer
                        else "precursor",
                        "detail": f"node {e['node']} (epoch {e.get('epoch')})"
                                  f" acquired a lease while node {cnode} "
                                  f"(epoch {c.get('epoch')}) holds one for "
                                  f"another {(c['until'] - t) * 1e3:.0f}ms",
                    })
            claims[(rid, e["node"])] = e
        elif k in ("lease_lapse", "abdicate"):
            claims.pop((rid, e["node"]), None)
        elif k == "deposed":
            claims.pop((rid, e.get("leader")), None)
        elif k in ("node_crash", "session_flap"):
            for key in [key for key in claims if key[1] == e["node"]]:
                claims.pop(key, None)
    return out


SIGNATURES = {
    "takeover_wedge": sig_takeover_wedge,
    "catchup_starvation": sig_catchup_starvation,
    "split_brain_precursor": sig_split_brain_precursor,
}


def scan_signatures(entries: list[dict]) -> dict[str, list[dict]]:
    return {name: fn(entries) for name, fn in SIGNATURES.items()}


# -- slow-op narrative (merges a trace's spans with its journal window) -----


def explain_slow_op(trace: dict, entries: list[dict] | None = None
                    ) -> list[str]:
    """Narrate one slow traced op: dominant stage from its span chain,
    plus what its range's protocol journal shows for the op's lifetime.
    `trace` is a `top_slowest` dict (bench breakdown block); `entries`
    overrides the embedded window summary with a full dump."""
    stages = trace.get("stages_ms", {})
    worst = max(stages, key=stages.get) if stages else None
    lines = [f"trace {trace.get('trace_id')} key={trace.get('key')} "
             f"e2e={trace.get('e2e_ms', 0.0):.3f}ms "
             f"attempts={trace.get('attempts')}"
             + (f" — dominant stage {worst} ({stages[worst]:.3f}ms)"
                if worst else "")]
    rid = trace.get("rid")
    t0, t1 = trace.get("t_issue"), trace.get("t_done")
    if entries is not None and rid is not None and t0 is not None:
        win = [e for e in entries
               if e.get("rid") == rid and t0 <= e["t"] <= t1]
        notable = [e for e in win if e["kind"] in
                   ("takeover", "leader_open", "abdicate", "deposed",
                    "lease_lapse", "elect_decide", "catchup_enter",
                    "node_crash", "node_restart")]
        summary = {"n_entries": len(win), "notable": notable}
    else:
        summary = trace.get("journal") or {}
    if summary:
        lines.append(f"    journal window: {summary.get('n_entries', 0)} "
                     f"range-{rid} entries during the op")
        for e in summary.get("notable", []):
            extra = e.get("why") or e.get("winner")
            lines.append(f"      t={e['t']:.3f}s {e['kind']} "
                         f"node={e['node']}"
                         + (f" ({extra})" if extra is not None else ""))
        if not summary.get("notable"):
            lines.append("      no regime changes — latency is queueing/"
                         "service time, not a protocol stall")
    return lines


# -- whole-dump analysis -----------------------------------------------------


def analyze(entries: list[dict]) -> dict:
    """Structured report over a dump: per-range regimes, the watchdog
    replayed offline, and the anomaly-signature scan."""
    rids = journal_rids(entries)
    return {
        "n_entries": len(entries),
        "t_span": [entries[0]["t"], entries[-1]["t"]] if entries else [0, 0],
        "ranges": {rid: regimes(entries, rid) for rid in rids},
        "watchdog": InvariantWatchdog.replay(entries).summary(),
        "signatures": scan_signatures(entries),
    }


def narrate(entries: list[dict], rid: int | None = None,
            stall: tuple[float, float] | None = None) -> str:
    rep = analyze(entries)
    out = [f"journal: {rep['n_entries']} entries over "
           f"[{rep['t_span'][0]:.3f}s, {rep['t_span'][1]:.3f}s], "
           f"ranges {list(rep['ranges'])}"]
    rids = [rid] if rid is not None else list(rep["ranges"])
    for r in rids:
        out.append(f"\n== range {r}: failover timeline ==")
        out.extend(explain_failover(entries, r))
        if stall is not None:
            out.append(f"\n== range {r}: stall window ==")
            out.extend(explain_stall(entries, r, *stall))
    out.append("\n== anomaly signatures ==")
    any_sig = False
    for name, findings in rep["signatures"].items():
        for f in findings:
            any_sig = True
            out.append(f"  [{f['severity']}] {name} rid={f['rid']} "
                       f"t={f['t']:.3f}s: {f['detail']}")
    if not any_sig:
        out.append("  none matched")
    wd = rep["watchdog"]
    out.append(f"\n== invariant replay ==")
    out.append(f"  {'ok' if wd['ok'] else 'VIOLATIONS'}: "
               f"{wd['entries_checked']} entries checked, "
               f"{wd['n_violations']} violation(s)")
    for v in wd["violations"][:10]:
        out.append(f"  [{v['invariant']}] t={v['t']:.3f}s rid={v['rid']} "
                   f"node={v['node']} at {v['kind']}: {v['detail']}")
    return "\n".join(out)


# -- demo mode ---------------------------------------------------------------


def _demo_entries(name: str, seed: int) -> list[dict]:
    if name == "chaos":
        from repro.workload.experiment import run_spinnaker_chaos
        r = run_spinnaker_chaos(seed=seed, duration=10.0,
                                export_journal=True)
        return ProtocolJournal.load_jsonl(r["journal_jsonl"])
    from repro.chaos.mutations import MUTATIONS, run_mutation
    if name not in MUTATIONS:
        raise SystemExit(f"unknown demo '{name}' (choose from chaos, "
                         f"{', '.join(MUTATIONS)})")
    r = run_mutation(name, mutated=True, seed=seed, export_journal=True)
    return ProtocolJournal.load_jsonl(r["journal_jsonl"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("dump", nargs="?", help="journal JSONL dump to explain")
    ap.add_argument("--rid", type=int, default=None,
                    help="restrict the narrative to one range")
    ap.add_argument("--stall", nargs=2, type=float, metavar=("T_LO", "T_HI"),
                    help="explain a write-stall window [T_LO, T_HI] (sim s)")
    ap.add_argument("--json", action="store_true",
                    help="emit the structured report instead of prose")
    ap.add_argument("--demo", default=None,
                    help="run a scenario in-process and explain its journal:"
                         " chaos | takeover_wedge | catchup_starvation |"
                         " ack_before_force")
    ap.add_argument("--seed", type=int, default=0, help="demo seed")
    ap.add_argument("--out", default=None,
                    help="also write the (demo) journal dump here")
    args = ap.parse_args(argv)

    if args.demo:
        entries = _demo_entries(args.demo, args.seed)
        if args.out:
            Path(args.out).write_text(
                "\n".join(json.dumps(e) for e in entries) + "\n")
            print(f"journal dump written to {args.out}")
    elif args.dump:
        entries = load_entries(args.dump)
    else:
        ap.error("need a DUMP file or --demo")
        return 2

    if args.json:
        print(json.dumps(analyze(entries), indent=2, default=str))
    else:
        print(narrate(entries, rid=args.rid,
                      stall=tuple(args.stall) if args.stall else None))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
