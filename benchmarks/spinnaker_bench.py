"""Paper-§9 experiment runner: Spinnaker vs the Cassandra baseline.

    PYTHONPATH=src python benchmarks/spinnaker_bench.py \
        --scenario figs8-10 [--quick] [--out BENCH_spinnaker.json]

Scenarios:

- `fig8`    — read/write latency + throughput under a steady 80/15 YCSB-
  style zipfian mix, for Spinnaker strong reads, Spinnaker timeline reads,
  Cassandra quorum, and Cassandra eventual consistency;
- `fig9`    — kill the leader of range 0 mid-load with the fault-schedule
  DSL and record sliding-window write availability (writes must resume
  without manual intervention once a follower takes over);
- `fig10`   — same failure, timeline-read availability (reads keep being
  served by the surviving replicas throughout);
- `figs8-10`— all of the above in one JSON artifact.

Emits `BENCH_spinnaker.json` plus claim checks against the paper's
headline: comparable read latency, writes within ~5-10% of eventual
consistency's throughput cost envelope, and post-failover recovery.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.workload import (ExperimentConfig, WorkloadSpec,  # noqa: E402
                            run_cassandra_workload, run_spinnaker_workload)

LEADER_KILL = """
# Fig. 9/10: kill whichever node currently leads range 0, mid-load;
# bring it back later.  No operator intervention in between.
at {t_kill}s crash leader of 0
at {t_back}s restart crashed
"""


def base_spec(quick: bool) -> WorkloadSpec:
    return WorkloadSpec(
        num_keys=1000 if quick else 5000,
        key_dist="zipfian", zipf_theta=0.99,
        read_frac=0.80, write_frac=0.15, rmw_frac=0.03, cond_frac=0.02,
        value_size=4096)


def base_cfg(quick: bool, seed: int = 0) -> ExperimentConfig:
    return ExperimentConfig(
        n_nodes=5, disk="ssd", seed=seed,
        n_clients=8 if quick else 32,
        warmup=0.5 if quick else 2.0,
        duration=3.0 if quick else 15.0,
        preload_cap=1000 if quick else 5000)


def run_fig8(quick: bool) -> dict:
    spec, cfg = base_spec(quick), base_cfg(quick)
    print("fig8: steady-state comparison ...", flush=True)
    out = {
        "spinnaker_strong": run_spinnaker_workload(
            spec, cfg, consistent_reads=True),
        "spinnaker_timeline": run_spinnaker_workload(
            spec, cfg, consistent_reads=False, monotonic=True),
        "cassandra_quorum": run_cassandra_workload(spec, cfg, quorum=True),
        "cassandra_eventual": run_cassandra_workload(spec, cfg, quorum=False),
    }
    for name, r in out.items():
        print(f"  {name}: reads p50={r['reads']['p50_ms']:.2f}ms "
              f"p99={r['reads']['p99_ms']:.2f}ms "
              f"writes p50={r['writes']['p50_ms']:.2f}ms "
              f"tput={r['throughput']:.0f}/s", flush=True)
    return out


def run_failover(quick: bool, consistent_reads: bool) -> dict:
    cfg = base_cfg(quick, seed=1)
    cfg.duration = 8.0 if quick else 30.0
    cfg.window = 0.5
    t_kill = 2.0 if quick else 8.0
    t_back = cfg.duration * 0.75
    spec = base_spec(quick)
    sched = LEADER_KILL.format(t_kill=t_kill, t_back=t_back)
    r = run_spinnaker_workload(spec, cfg, consistent_reads=consistent_reads,
                               monotonic=not consistent_reads,
                               schedule=sched)
    r["t_kill"] = t_kill
    r["t_restart"] = t_back
    return r


def check_writes_resume(fig9: dict) -> dict:
    """Writes must come back after the leader kill with nobody touching
    the cluster (§6: a follower takes over within the session timeout)."""
    t_kill = fig9["t_kill"]
    post = [w for w in fig9["timeline"]["write"] if w["t_start"] > t_kill]
    resumed = [w for w in post if w["throughput"] > 0]
    # recovery time = first window after the kill with successful writes
    recovery_s = (resumed[0]["t_start"] - t_kill) if resumed else None
    ok = bool(resumed) and max(w["throughput"] for w in resumed) > 0
    return {"writes_resumed": ok,
            "recovery_window_start_s_after_kill": recovery_s,
            "post_kill_peak_write_tput": max(
                (w["throughput"] for w in post), default=0.0)}


def check_paper_claims(fig8: dict) -> list[str]:
    claims = []
    sp, ce = fig8["spinnaker_strong"], fig8["cassandra_eventual"]
    cq = fig8["cassandra_quorum"]
    r_ratio = sp["reads"]["p50_ms"] / max(cq["reads"]["p50_ms"], 1e-9)
    claims.append(
        f"strong reads vs quorum reads p50 ratio = {r_ratio:.2f} "
        f"(paper: 'as fast or even faster', expect <= ~1.0)")
    w_ratio = sp["writes"]["p50_ms"] / max(ce["writes"]["p50_ms"], 1e-9)
    claims.append(
        f"spinnaker writes vs eventual writes p50 ratio = {w_ratio:.2f} "
        f"(paper: '5% to 10% slower', expect ~1.05-1.10)")
    t_ratio = sp["throughput"] / max(ce["throughput"], 1e-9)
    claims.append(f"throughput ratio spinnaker/eventual = {t_ratio:.2f}")
    return claims


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scenario", default="figs8-10",
                    choices=["fig8", "fig9", "fig10", "figs8-10"])
    ap.add_argument("--quick", action="store_true",
                    help="short runs (CI / smoke mode)")
    ap.add_argument("--out", default="BENCH_spinnaker.json")
    args = ap.parse_args(argv)

    rec: dict = {"scenario": args.scenario, "quick": args.quick}
    if args.scenario in ("fig8", "figs8-10"):
        rec["fig8"] = run_fig8(args.quick)
        rec["claims"] = check_paper_claims(rec["fig8"])
    if args.scenario in ("fig9", "figs8-10"):
        print("fig9: leader kill under write load ...", flush=True)
        rec["fig9"] = run_failover(args.quick, consistent_reads=True)
        rec["fig9_check"] = check_writes_resume(rec["fig9"])
        print(f"  {rec['fig9_check']}", flush=True)
    if args.scenario in ("fig10", "figs8-10"):
        print("fig10: leader kill under timeline reads ...", flush=True)
        rec["fig10"] = run_failover(args.quick, consistent_reads=False)

    Path(args.out).write_text(json.dumps(rec, indent=2))
    print(f"wrote {args.out}")
    for c in rec.get("claims", []):
        print("claim:", c)
    if "fig9_check" in rec and not rec["fig9_check"]["writes_resumed"]:
        print("FAIL: writes did not resume after leader crash")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
